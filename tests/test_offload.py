"""Stash arena + offload engine gates.

The contract this file enforces (ISSUE 4 acceptance):

* arena round-trip parity — ``stash_read(stash_write(ct))`` returns the
  per-tensor residual bit for bit (packed words, zero/range, rp_seed)
  for mixed bits {1, 2, 4, 8}, uniform + VM levels, ragged blocks, and
  ``impl ∈ {jnp, interp}``;
* the arena-routed GNN backward reproduces the per-tensor custom_vjp
  gradients, and ``offload="host"`` matches ``offload="device"``
  *exactly* (loss trajectory and params) on the Cora smoke config;
* the callback host store drains to empty after every backward walk.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.core.compressor import compress, decompress
from repro.graph import GNNConfig, cora_like, train_gnn, train_gnn_batched
from repro.graph.models import gnn_forward, graph_tuple, init_gnn_params
from repro.graph.train import _loss_fn, activation_memory_report
from repro.offload import arena as ar
from repro.offload import engine
from repro.offload.gnn import plan_gnn_stashes


@pytest.fixture(scope="module")
def graph():
    return cora_like(scale=0.2, seed=0)


@pytest.fixture(autouse=True)
def _store_drains():
    engine.host_store_clear()
    yield
    assert engine.host_store_bytes() == 0, "callback host store leaked"


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------- round-trip parity
@pytest.mark.parametrize("impl", ["jnp", "interp"])
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("vm", [False, True])
def test_arena_roundtrip_bit_identical(impl, bits, vm):
    """stash_read(stash_write(x)) == compress(x) field-for-field, and the
    decompression matches decompress(compress(x)) exactly — ragged tail
    blocks included ((37, 53) elements over G=96 leaves a partial block,
    and G=96 is ragged against the 8-bit pack width only for bits=8)."""
    if vm and bits > 4:
        pytest.skip("VM level tables only optimized for bits <= 4")
    cfg = CompressionConfig(bits=bits, group_size=96, vm=vm, vm_dim=12,
                            impl=impl)
    x = jax.random.normal(jax.random.PRNGKey(bits), (37, 53))
    ct = compress(x, cfg, jnp.uint32(11))
    plan = ar.plan_stashes((tuple(x.shape),), (cfg,))
    arenas = ar.stash_write(ar.arena_init(plan), plan, 0, ct)
    ct2 = ar.stash_read(arenas, plan, 0)
    assert ct2.packed.shape == ct.packed.shape
    np.testing.assert_array_equal(np.asarray(ct2.packed),
                                  np.asarray(ct.packed))
    np.testing.assert_array_equal(np.asarray(ct2.zero), np.asarray(ct.zero))
    np.testing.assert_array_equal(np.asarray(ct2.rng), np.asarray(ct.rng))
    assert int(ct2.rp_seed) == int(ct.rp_seed)
    np.testing.assert_array_equal(np.asarray(decompress(ct2)),
                                  np.asarray(decompress(ct)))


def test_arena_roundtrip_mixed_bits_with_rp():
    """One plan holding four layers at different widths + RP: segments must
    not alias and each layer must round-trip bit-identically."""
    shapes = ((64, 128), (48, 64), (33, 64), (17, 128))
    cfgs = tuple(CompressionConfig(bits=b, group_size=64, rp_ratio=8)
                 for b in (1, 2, 4, 8))
    plan = ar.plan_stashes(shapes, cfgs)
    arenas = ar.arena_init(plan)
    cts = []
    for li, (shape, cfg) in enumerate(zip(shapes, cfgs)):
        x = jax.random.normal(jax.random.PRNGKey(li), shape)
        ct = compress(x, cfg, jnp.uint32(li * 1013))
        arenas = ar.stash_write(arenas, plan, li, ct)
        cts.append(ct)
    for li, ct in enumerate(cts):
        ct2 = ar.stash_read(arenas, plan, li)
        np.testing.assert_array_equal(np.asarray(ct2.packed),
                                      np.asarray(ct.packed))
        np.testing.assert_array_equal(np.asarray(decompress(ct2)),
                                      np.asarray(decompress(ct)))


def test_plan_ledger_matches_residual_bytes():
    """The arena ledger equals the per-tensor residual bytes exactly (no
    padding, no drift): Σ segment bytes == Σ CompressedTensor.nbytes."""
    shapes = ((64, 128), (40, 64))
    cfgs = (CompressionConfig(bits=2, group_size=64, rp_ratio=8),
            CompressionConfig(bits=4, group_size=96))
    plan = ar.plan_stashes(shapes, cfgs)
    expect = 0
    for li, (shape, cfg) in enumerate(zip(shapes, cfgs)):
        x = jax.random.normal(jax.random.PRNGKey(li), shape)
        expect += compress(x, cfg, 0).nbytes
        assert plan.layers[li].nbytes == compress(x, cfg, 0).nbytes
    assert plan.total_bytes == expect


def test_plan_raw_and_mask_segments():
    """None layers plan raw f32 segments; masks round-trip word-aligned."""
    plan = ar.plan_stashes(((10, 7),), (None,), mask_elems=(33,))
    arenas = ar.arena_init(plan)
    x = jax.random.normal(jax.random.PRNGKey(0), (10, 7))
    arenas = ar.write_raw(arenas, plan, 0, x)
    mask = jnp.arange(2, dtype=jnp.uint32).reshape(1, 2)  # ceil(33/32) words
    arenas = ar.write_mask(arenas, plan, 0, mask)
    np.testing.assert_array_equal(np.asarray(ar.read_raw(arenas, plan, 0)),
                                  np.asarray(x))
    np.testing.assert_array_equal(np.asarray(ar.read_mask(arenas, plan, 0)),
                                  np.asarray(mask))
    assert plan.layers[0].mask.size == 2


# ------------------------------------------------- GNN arena-routed VJP
@pytest.mark.parametrize("arch", ["gcn", "sage"])
def test_arena_forward_and_grads_match_per_tensor(graph, arch):
    """Forward is bit-identical; grads match the per-tensor custom_vjp
    stack (same decompressed stashes, same estimator math)."""
    g = graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch=arch, hidden=(32, 32), n_classes=g.num_classes,
                    compression=comp)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    gt = graph_tuple(g)
    mask = g.train_mask.astype(jnp.float32)
    plan = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    seed = jnp.uint32(7919)

    y0 = gnn_forward(params, gt, cfg, seed=seed)
    y1 = gnn_forward(params, gt, cfg, seed=seed, plan=plan, offload="device")
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))

    gfn = jax.jit(jax.grad(_loss_fn), static_argnums=(4,),
                  static_argnames=("plan", "offload"))
    g_std = gfn(params, gt, g.labels, mask, cfg, seed)
    g_dev = gfn(params, gt, g.labels, mask, cfg, seed, plan=plan,
                offload="device")
    for a, b in zip(jax.tree.leaves(g_std), jax.tree.leaves(g_dev)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_arena_grads_host_equals_device_bitwise(graph):
    """The acceptance gate's strong form: every gradient leaf identical."""
    g = graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8, vm=True)
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=comp)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    gt = graph_tuple(g)
    mask = g.train_mask.astype(jnp.float32)
    plan = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    gfn = jax.jit(jax.grad(_loss_fn), static_argnums=(4,),
                  static_argnames=("plan", "offload"))
    g_dev = gfn(params, gt, g.labels, mask, cfg, jnp.uint32(3), plan=plan,
                offload="device")
    g_host = gfn(params, gt, g.labels, mask, cfg, jnp.uint32(3), plan=plan,
                 offload="host")
    _tree_equal(g_dev, g_host)


def test_arena_mixed_precision_and_uncompressed_layer(graph):
    """Heterogeneous widths (autoprec-style tuple) + a raw-f32 layer all
    route through one plan; host == device exactly."""
    g = graph
    base = CompressionConfig(bits=2, group_size=96, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=(dataclasses.replace(base, bits=1),
                                 None,
                                 dataclasses.replace(base, bits=8)))
    params = init_gnn_params(jax.random.PRNGKey(1), cfg, g.n_feats)
    gt = graph_tuple(g)
    mask = g.train_mask.astype(jnp.float32)
    plan = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    assert plan.layers[1].raw is not None  # uncompressed layer planned raw
    gfn = jax.jit(jax.grad(_loss_fn), static_argnums=(4,),
                  static_argnames=("plan", "offload"))
    g_std = gfn(params, gt, g.labels, mask, cfg, jnp.uint32(9))
    g_dev = gfn(params, gt, g.labels, mask, cfg, jnp.uint32(9), plan=plan,
                offload="device")
    g_host = gfn(params, gt, g.labels, mask, cfg, jnp.uint32(9), plan=plan,
                 offload="host")
    _tree_equal(g_dev, g_host)
    for a, b in zip(jax.tree.leaves(g_std), jax.tree.leaves(g_dev)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


# ------------------------------------------------------- training engines
def test_train_gnn_offload_host_matches_device_exactly(graph):
    """One-step-and-beyond: the whole Cora-smoke loss trajectory and the
    final params are identical across offload policies."""
    g = graph
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=CompressionConfig(bits=2, group_size=64,
                                                  rp_ratio=8))
    r_dev = train_gnn(g, cfg, n_epochs=3, seed=0, offload="device",
                      verbose=True, eval_every=1)
    r_host = train_gnn(g, cfg, n_epochs=3, seed=0, offload="host",
                       verbose=True, eval_every=1)
    assert [l for _, l, _ in r_dev["history"]] == \
        [l for _, l, _ in r_host["history"]]
    _tree_equal(r_dev["params"], r_host["params"])
    assert r_dev["test_acc"] == r_host["test_acc"]


def test_train_gnn_offload_matches_per_tensor_path(graph):
    """The arena path is a storage refactor, not a numerics change: the
    per-tensor engine and offload="device" land on the same trajectory."""
    g = graph
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=CompressionConfig(bits=2, group_size=64,
                                                  rp_ratio=8))
    r_std = train_gnn(g, cfg, n_epochs=3, seed=0)
    r_dev = train_gnn(g, cfg, n_epochs=3, seed=0, offload="device")
    for a, b in zip(jax.tree.leaves(r_std["params"]),
                    jax.tree.leaves(r_dev["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-5, atol=1e-6)


def test_train_gnn_batched_offload_parity(graph):
    """vmap/scan composition: the batched engine under host offload equals
    device offload bit for bit (per-batch keys can't collide)."""
    g = graph
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=CompressionConfig(bits=2, group_size=64,
                                                  rp_ratio=8))
    r_dev = train_gnn_batched(g, cfg, n_parts=2, n_epochs=2, seed=0,
                              shuffle=False, offload="device")
    r_host = train_gnn_batched(g, cfg, n_parts=2, n_epochs=2, seed=0,
                               shuffle=False, offload="host")
    _tree_equal(r_dev["params"], r_host["params"])


def test_invalid_policy_rejected(graph):
    with pytest.raises(ValueError, match="offload"):
        train_gnn(graph, GNNConfig(n_classes=graph.num_classes),
                  n_epochs=1, offload="hsot")


# ------------------------------------------------------- report + ledger
def test_memory_report_arena_column(graph):
    g = graph
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=CompressionConfig(bits=2, group_size=64,
                                                  rp_ratio=8))
    rep = activation_memory_report(g, cfg, offload="host")
    a = rep["arena"]
    assert a["policy"] == "host"
    assert a["planned_bytes"] == a["u32_bytes"] + a["f32_bytes"]
    # host policy keeps at most the two-layer prefetch window on device
    assert a["device_resident_bytes"] < a["planned_bytes"]
    assert a["measured_live_bytes"] >= 0
    rep_dev = activation_memory_report(g, cfg, offload="device")
    assert rep_dev["arena"]["device_resident_bytes"] == \
        rep_dev["arena"]["planned_bytes"]
    # the pooled ledger never exceeds the per-tensor compressed model
    # (same bytes, no allocator slack) — ReLU masks are in the arena too
    assert rep_dev["arena"]["planned_bytes"] <= rep["compressed_bytes"]


# --------------------------------------------- per-tensor residual offload
def test_compressed_matmul_host_offload_matches_inline():
    """The primitive-level knob: a host-stash residual yields the exact
    gradients of the inline CompressedTensor residual."""
    from repro.core.act_compress import compressed_matmul

    cfg = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    x = jax.random.normal(jax.random.PRNGKey(0), (48, 128))
    w = jax.random.normal(jax.random.PRNGKey(1), (128, 16))
    seed = jnp.uint32(31)

    def loss(x, w, offload):
        return compressed_matmul(x, w, seed, cfg, offload).sum()

    g_in = jax.grad(partial(loss, offload=None), argnums=(0, 1))(x, w)
    g_off = jax.grad(partial(loss, offload="host"), argnums=(0, 1))(x, w)
    _tree_equal(g_in, g_off)
    # and under jit, where the write/read callbacks share one program
    # (compare jit vs jit: eager and jit legitimately differ in matmul
    # accumulation order, offload or not)
    g_jit_in = jax.jit(jax.grad(partial(loss, offload=None),
                                argnums=(0, 1)))(x, w)
    g_jit_off = jax.jit(jax.grad(partial(loss, offload="host"),
                                 argnums=(0, 1)))(x, w)
    _tree_equal(g_jit_in, g_jit_off)


# --------------------------------------------- transformer scan residuals
def test_compressed_block_host_offload_matches_inline():
    """The LM scan path: host-stash residual tickets give the exact same
    losses as inline CompressedTensor residuals."""
    import dataclasses as dc

    from repro.configs import ARCHS, reduce_for_smoke
    from repro.data import batch_for_step
    from repro.launch.steps import make_train_step
    from repro.models import Model
    from repro.optim import AdamWConfig, adamw_init

    losses = {}
    for off in (None, "host"):
        c = dc.replace(reduce_for_smoke(ARCHS["qwen3-32b"]), act_mode="act",
                       act_compression=CompressionConfig(bits=2,
                                                         group_size=64),
                       act_offload=off)
        model = Model(c)
        opt = AdamWConfig(lr=3e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        params = model.init(jax.random.PRNGKey(0))
        state = adamw_init(params, opt)
        ls = []
        for s in range(2):
            toks = jnp.asarray(batch_for_step(c.vocab, 2, 32, s))
            params, state, m = step(params, state, {"tokens": toks})
            ls.append(float(m["loss"]))
        losses[off] = ls
    assert losses[None] == losses["host"], losses
