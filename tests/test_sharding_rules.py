"""Sharding-rule validity: every spec'd dim divides its mesh axis, and the
rules express the intended TP/EP/FSDP layout (no devices needed — rules
read only mesh.shape)."""
import dataclasses

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, SHAPES, input_specs, reduce_for_smoke
from repro.models import Model
from repro.parallel.sharding import cache_pspecs, param_pspecs


class FakeMesh:
    def __init__(self, shape):
        self.shape = dict(shape)


MESH1 = FakeMesh({"data": 16, "model": 16})
MESH2 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _axis_sizes(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, str):
        return mesh.shape[entry]
    n = 1
    for e in entry:
        n *= mesh.shape[e]
    return n


@pytest.mark.parametrize("arch", sorted(ARCHS))
@pytest.mark.parametrize("mesh", [MESH1, MESH2], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = ARCHS[arch]
    model = Model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, params_shape, mesh)

    def check(path, leaf, spec):
        assert len(spec) <= len(leaf.shape), (path, spec, leaf.shape)
        for dim, entry in zip(leaf.shape, tuple(spec)):
            size = _axis_sizes(mesh, entry)
            assert dim % size == 0, \
                f"{arch} {path}: dim {dim} not divisible by {entry}={size}"

    jax.tree_util.tree_map_with_path(
        check, params_shape, specs,
        is_leaf=lambda x: isinstance(x, P))


def test_tp_layout_dense():
    cfg = ARCHS["qwen3-32b"]
    model = Model(cfg)
    ps = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, ps, MESH1)
    lay = specs["layers"]
    assert tuple(lay["attn"]["wq"]) == (None, "data", "model")
    assert tuple(lay["attn"]["wo"]) == (None, "model", "data")
    assert tuple(lay["mlp"]["w_gate"]) == (None, "data", "model")
    assert tuple(lay["mlp"]["w_down"]) == (None, "model", "data")
    assert tuple(specs["lm_head"]) == ("data", "model")


def test_ep_layout_moe():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    model = Model(cfg)
    ps = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, ps, MESH1)
    moe = specs["layers"]["moe"]
    assert tuple(moe["w_gate"]) == (None, "model", "data", None)   # EP + FSDP
    assert tuple(moe["w_down"]) == (None, "model", None, "data")


def test_nondivisible_vocab_replicated():
    cfg = ARCHS["internvl2-2b"]        # vocab 92553 — not divisible by 16
    model = Model(cfg)
    ps = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    specs = param_pspecs(cfg, ps, MESH1)
    assert tuple(specs["embed"])[0] is None
    assert tuple(specs["lm_head"])[1] is None


def test_cache_specs_long_context():
    cfg = ARCHS["zamba2-1.2b"]
    model = Model(cfg)
    cache = jax.eval_shape(lambda: model.init_cache(1, 524288))
    specs = cache_pspecs(cfg, cache, MESH1, batch=1, seq=524288)
    sk = tuple(specs["shared_k"])
    assert sk[2] == ("data", "model"), "long-ctx cache must shard sequence"
    ssd = tuple(specs["ssd"])
    assert ssd[2] == "model", "ssm state heads shard over model"


def test_input_specs_all_cells():
    """input_specs builds ShapeDtypeStructs for all 40 cells w/o allocation."""
    n = 0
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES.items():
            from repro.configs import cell_applicable
            ok, _ = cell_applicable(cfg, shape)
            if not ok:
                continue
            spec = input_specs(cfg, shape)
            assert "tokens" in spec or "cache" in spec
            n += 1
    # 10 archs x 4 shapes = 40 cells, minus 8 full-attention long_500k skips
    assert n == 32