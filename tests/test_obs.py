"""repro.obs: obs-on training is bit-identical to obs-off across every
engine, spans nest well-formed and export to valid Chrome/JSONL traces,
the quant-health channel's measured SR variance agrees with its own
conditional expectation and with the Eq. 10 prediction, and the pager's
windowed overlap stat is live."""
import dataclasses
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import quant as quantmod
from repro.core import random_projection as rpmod
from repro.core.act_compress import CompressionConfig
from repro.core.autoprec import LayerStats, expected_layer_variance
from repro.engine import run
from repro.engine.plan import (ExecutionPlan, KernelPolicy, ObsPolicy,
                               PrecisionPolicy, SamplingPolicy)
from repro.engine.seeds import layer_seed
from repro.graph import GNNConfig, cora_like
from repro.graph.models import graph_tuple, init_gnn_params
from repro.obs.metrics import (NULL_COUNTER, NULL_HISTOGRAM, Counter, Gauge,
                               Histogram, MetricsRegistry, get_metrics)
from repro.obs.quantstats import (QuantHealthMonitor, health_rows,
                                  layer_health, measure_quant_health,
                                  measured_sensitivity)
from repro.obs.session import NULL_SESSION, ObsSession
from repro.obs.trace import Tracer, set_tracer, stopwatch


@pytest.fixture(scope="module")
def g():
    return cora_like(scale=0.2, seed=0)


COMP = CompressionConfig(bits=2, group_size=64, rp_ratio=8)

#: The full-surface policy the bit-identity matrix runs under.
OBS = ObsPolicy(enabled=True, trace=True, metrics=True, quant_stats=True,
                quant_stats_every=2)


def _cfg(g, comp=COMP, hidden=(32,)):
    return GNNConfig(arch="sage", hidden=hidden, n_classes=g.num_classes,
                     compression=comp)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _plans(impl):
    kp = KernelPolicy(impl=impl)
    return {
        "full": ExecutionPlan(kernel=kp),
        "partition": ExecutionPlan(
            sampling=SamplingPolicy(kind="partition", n_parts=2), kernel=kp),
        "mesh": ExecutionPlan(
            sampling=SamplingPolicy(kind="mesh", n_parts=2, shuffle=False),
            kernel=kp),
    }


# ----------------------------------------------------------- bit-identity
@pytest.mark.parametrize("impl", ["jnp", "interp"])
@pytest.mark.parametrize("kind", ["full", "partition", "mesh"])
def test_obs_on_is_bit_identical(g, kind, impl):
    """The HARD gate: the full obs surface (spans + metrics + the quant
    probe on a 2-epoch cadence) must not move a single bit of the
    training trajectory — obs lives outside the training jaxpr."""
    cfg = _cfg(g)
    plan_off = _plans(impl)[kind]
    plan_on = dataclasses.replace(plan_off, obs=OBS)
    r_off = run(g, cfg, plan_off, n_epochs=3, seed=0)
    r_on = run(g, cfg, plan_on, n_epochs=3, seed=0)
    _tree_equal(r_off["params"], r_on["params"])
    assert r_off["test_acc"] == r_on["test_acc"]
    assert "obs" not in r_off
    obs = r_on["obs"]
    assert obs.enabled
    # the probe ran on its cadence and produced measured-vs-Eq.10 rows
    rows = obs.quant_rows()
    assert rows and rows[0]["epoch"] == 2
    assert all(r["predicted_var"] > 0 and r["measured_var"] > 0
               for r in rows)


# ------------------------------------------------------------------ spans
def test_span_tree_well_formed(g):
    cfg = _cfg(g)
    plan = dataclasses.replace(_plans("jnp")["full"], obs=OBS)
    r = run(g, cfg, plan, n_epochs=3, seed=0)
    spans = r["obs"].tracer.spans
    names = [s.name for s in spans]
    assert names.count("epoch") == 3
    assert "plan/compile" in names and "train/epochs" in names
    assert names.count("obs/quant_probe") == 2  # epochs 0 and 2
    for s in spans:
        assert s.dur >= 0.0
        if s.parent == -1:
            assert s.depth == 0
            continue
        p = spans[s.parent]
        assert s.depth == p.depth + 1
        # child interval nested in the parent's
        assert s.t0 >= p.t0
        assert s.t0 + s.dur <= p.t0 + p.dur + 1e-6
    # every epoch span hangs off the train/epochs stopwatch span
    root = names.index("train/epochs")
    assert all(spans[i].parent == root
               for i, n in enumerate(names) if n == "epoch")


def test_mesh_round_spans_and_halo_counter(g):
    plan = dataclasses.replace(_plans("jnp")["mesh"], obs=OBS)
    r = run(g, _cfg(g), plan, n_epochs=2, seed=0)
    obs = r["obs"]
    names = [s.name for s in obs.tracer.spans]
    rounds = r["updates_per_epoch"]
    assert names.count("mesh/round") == 2 * rounds
    assert names.count("pager/fetch") == 2 * rounds
    snap = obs.registry.snapshot()
    assert snap["pager/fetches"] == 2 * rounds
    assert "halo/bytes" in snap
    # single-device mesh over 2 partitions => 2 sequential rounds with a
    # real halo; the counter prices rounds * per-round bytes
    assert snap["halo/bytes"] == r["halo_bytes_per_epoch"] * 2
    ov = snap["pager/overlap_frac"]
    assert ov["count"] == 2 * rounds
    assert 0.0 <= ov["window_mean"] <= 1.0


def test_trace_exports_are_schema_valid(g, tmp_path):
    plan = dataclasses.replace(_plans("jnp")["full"], obs=OBS)
    r = run(g, _cfg(g), plan, n_epochs=2, seed=0)
    paths = r["obs"].export(tmp_path / "trace")
    # JSONL: one json object per line, span schema
    lines = (tmp_path / "trace.jsonl").read_text().strip().split("\n")
    events = [json.loads(ln) for ln in lines]
    assert len(events) == len(r["obs"].tracer.spans)
    for e in events:
        assert set(e) == {"name", "ts_s", "dur_s", "depth", "parent", "args"}
    # Chrome trace_event: what Perfetto loads
    chrome = json.loads((tmp_path / "trace.trace.json").read_text())
    assert set(chrome) == {"traceEvents", "displayTimeUnit"}
    for ev in chrome["traceEvents"]:
        assert ev["ph"] == "X" and ev["cat"] == "repro"
        assert isinstance(ev["ts"], float) and isinstance(ev["dur"], float)
        assert ev["ts"] >= 0.0 and ev["dur"] >= 0.0
    assert paths["chrome"].endswith(".trace.json")


def test_stopwatch_measures_without_tracer():
    assert set_tracer(None) is None or True  # ensure no active tracer
    with stopwatch() as sw:
        sum(range(1000))
    assert sw.elapsed_s > 0.0
    # named stopwatch lands a span when a tracer is active
    t = Tracer()
    prev = set_tracer(t)
    try:
        with stopwatch("work", k=1) as sw:
            sum(range(1000))
    finally:
        set_tracer(prev)
    assert [s.name for s in t.spans] == ["work"]
    assert t.spans[0].args == {"k": 1}
    assert abs(t.spans[0].dur - sw.elapsed_s) < 0.05


# ---------------------------------------------------------------- metrics
def test_metrics_primitives():
    c, ga, h = Counter(), Gauge(), Histogram(window=4)
    c.inc(), c.inc(5)
    assert c.value == 6
    ga.set(3.0), ga.max(2.0), ga.max(7.0)
    assert ga.value == 7.0
    for v in (1.0, 2.0, 3.0, 4.0, 5.0, 6.0):
        h.observe(v)
    assert h.count == 6 and h.mean == 3.5
    assert h.window_size == 4
    assert h.window_mean == 4.5       # last four: 3,4,5,6
    assert h.window_min == 3.0 and h.window_max == 6.0
    assert h.vmin == 1.0 and h.vmax == 6.0


def test_disabled_registry_hands_out_nulls():
    reg = MetricsRegistry(enabled=False)
    assert reg.counter("x") is NULL_COUNTER
    assert reg.histogram("y") is NULL_HISTOGRAM
    reg.counter("x").inc()
    assert reg.snapshot() == {}
    # the module default is disabled: unconditional producer calls are
    # no-ops until a session activates its registry
    get_metrics().counter("anything").inc()


def test_session_activation_restores_previous_actives():
    sess = ObsSession(ObsPolicy(enabled=True))
    before = get_metrics()
    with sess.activate():
        assert get_metrics() is sess.registry
    assert get_metrics() is before
    assert NULL_SESSION.registry is None and NULL_SESSION.tracer is None


# ----------------------------------------------------------- quant health
def _replay_pipeline(x, comp, seed=0, li=0):
    ls = layer_seed(jnp.uint32(seed), li)
    xs = rpmod.rp(x, ls ^ jnp.uint32(0xA5A5A5A5),
                  x.shape[1] // comp.rp_ratio)
    blocks, n_valid = quantmod.group_reshape(xs, comp.group_size)
    lv = comp.levels() or quantmod.uniform_levels(comp.bits)
    codes, zero, rng = quantmod.quantize_grouped(blocks, comp.bits, ls, lv)
    return blocks, int(n_valid), codes, zero, rng, lv


def test_measured_variance_is_the_conditional_expectation():
    """The probe's sq_err is a single SR draw; over ~4k elements it must
    concentrate on the analytic conditional expectation
    Σ frac·(1−frac)·(rng/B)² of the very same blocks."""
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    x = jax.random.normal(jax.random.PRNGKey(3), (512, 64), jnp.float32)
    stats = jax.jit(
        lambda x: layer_health(x, comp, jnp.uint32(0), 0))(x)
    measured = float(stats[2])
    blocks, n_valid, codes, zero, rng, lv = _replay_pipeline(x, comp)
    assert n_valid == blocks.size  # no padded tail in this geometry
    B = 2 ** comp.bits - 1
    t = jnp.clip((blocks - zero) / rng, 0.0, 1.0) * B
    frac = t - jnp.floor(t)
    expected = float(jnp.sum(frac * (1 - frac) * (rng / B) ** 2))
    assert expected > 0.0
    np.testing.assert_allclose(measured, expected, rtol=0.1)
    # saturation rate: endpoint codes of the same draw, exactly
    sat = float(jnp.mean(((codes == 0) | (codes == B)).astype(jnp.float32)))
    np.testing.assert_allclose(float(stats[5]), sat, rtol=1e-6)


def test_measured_variance_agrees_with_eq10_on_synthetic_gaussian(g):
    """Gaussian activations through RP are the regime the CN_[1/D] model
    (Eq. 10) was derived for: measured and predicted variance must agree
    to well within 2x (the allocator only needs the *relative* per-layer
    scale, but the runtime monitor's ratio column should sit near 1)."""
    cfg = _cfg(g)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    measured = measure_quant_health(params, graph_tuple(g), cfg, seed=0)
    rows = health_rows(measured, cfg.layer_compression())
    assert len(rows) == cfg.n_layers
    for r in rows:
        assert 0.4 < r["ratio"] < 2.5, r
    # sensitivities: measured_var / bit-scaling curve, None where
    # uncompressed
    sens = measured_sensitivity(measured, cfg.layer_compression())
    assert all(s is not None and s > 0 for s in sens)


def test_quant_monitor_history_and_epoch_tags(g):
    cfg = _cfg(g)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    mon = QuantHealthMonitor(cfg)
    gt = graph_tuple(g)
    mon.probe(params, gt, 0)
    mon.probe(params, gt, 5)
    rows = mon.rows()
    assert rows and all(r["epoch"] == 5 for r in rows)
    hist = mon.history()
    assert [e for e, _ in hist] == [0, 5]
    # same params, same seed -> the probe replays bit-identically
    assert hist[0][1][0]["measured_var"] == hist[1][1][0]["measured_var"]


# -------------------------------------------------------- obs calibration
def test_autoprec_obs_calibration_allocates(g):
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=COMP)
    base = ExecutionPlan(
        precision=PrecisionPolicy(kind="autoprec", bit_budget=2.0,
                                  calibration="obs"),
        obs=ObsPolicy(enabled=True, quant_stats=True))
    r = run(g, cfg, base, n_epochs=2, seed=0)
    assert len(r["bits_per_layer"]) == cfg.n_layers
    assert all(b in (1, 2, 4, 8) for b in r["bits_per_layer"])


def test_obs_calibration_requires_telemetry_channel(g):
    plan = ExecutionPlan(
        precision=PrecisionPolicy(kind="autoprec", bit_budget=2.0,
                                  calibration="obs"))
    with pytest.raises(ValueError, match="quant_stats"):
        run(g, _cfg(g), plan, n_epochs=1, seed=0)


def test_policy_validation():
    with pytest.raises(ValueError, match="obs.quant_stats"):
        ObsPolicy(quant_stats=True)            # needs enabled=True
    with pytest.raises(ValueError, match="quant_stats_every"):
        ObsPolicy(enabled=True, quant_stats_every=0)
    with pytest.raises(ValueError, match="precision.calibration"):
        PrecisionPolicy(kind="autoprec", bit_budget=2.0,
                        calibration="bogus")
    with pytest.raises(ValueError, match="calibration"):
        PrecisionPolicy(kind="fixed", calibration="obs")
    p = dataclasses.replace(ExecutionPlan(), obs=ObsPolicy(enabled=True))
    assert "obs=trace+metrics" in p.describe()
    assert "obs" not in ExecutionPlan().describe()


# ------------------------------------------------------------------ pager
def test_pager_windowed_overlap(g):
    from repro.offload.pager import FeaturePager
    from repro.parallel.halo import graph_mesh

    mesh = graph_mesh(1)
    feats = np.random.default_rng(0).normal(
        size=(2, 1, 8, 4)).astype(np.float32)
    reg = MetricsRegistry()
    pg = FeaturePager(feats, mesh, metrics=reg, window=3)
    for r in (0, 1, 0, 1, 0, 1):
        pg.fetch(r)
        pg.prefetch((r + 1) % 2)
    st = pg.stats()
    assert st["fetches"] == 6
    assert st["overlap_window_size"] == 3      # bounded, not lifetime
    assert 0.0 <= st["overlap_frac_window"] <= 1.0
    assert st["overlap_frac_window_min"] <= st["overlap_frac_window"]
    assert reg.counter("pager/fetches").value == 6
    assert reg.gauge("pager/round_bytes").value == feats.nbytes // 2
    # without a registry the pager makes a private one: stats still live
    pg2 = FeaturePager(feats, mesh)
    pg2.fetch(0)
    assert pg2.stats()["overlap_window_size"] == 1
