"""AdamW (+8-bit block-wise states) behaviour."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import AdamWConfig, adamw_init, adamw_update


def _train_quadratic(opt_cfg, steps=120):
    target = jnp.asarray(np.random.default_rng(0).normal(0, 1, (64, 64)),
                         jnp.float32)
    params = {"w": jnp.zeros((64, 64))}
    state = adamw_init(params, opt_cfg)

    @jax.jit
    def step(params, state):
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean((p["w"] - target) ** 2))(params)
        params, state = adamw_update(grads, state, params, opt_cfg)
        return params, state, loss

    for _ in range(steps):
        params, state, loss = step(params, state)
    return float(loss)


def test_adamw_converges_fp32():
    assert _train_quadratic(AdamWConfig(lr=5e-2)) < 1e-2


def test_adamw_converges_8bit_states():
    """Dettmers-style block-wise int8 moments (same quant core as the
    paper's activations) must not break convergence."""
    loss8 = _train_quadratic(AdamWConfig(lr=5e-2, state_bits=8,
                                         state_group=64))
    assert loss8 < 5e-2, loss8


def test_adamw_bf16_states():
    assert _train_quadratic(AdamWConfig(lr=5e-2, state_dtype="bfloat16")) < 2e-2


def test_grad_clip():
    cfg = AdamWConfig(lr=1.0, grad_clip=1e-3)
    params = {"w": jnp.zeros((4,))}
    state = adamw_init(params, cfg)
    grads = {"w": jnp.full((4,), 1e6)}
    new_p, _ = adamw_update(grads, state, params, cfg)
    # clipped: update magnitude bounded by lr regardless of huge grad
    assert float(jnp.abs(new_p["w"]).max()) <= 1.0 + 1e-6


def test_schedule_warmup():
    from repro.optim.adamw import schedule
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10)
    assert float(schedule(cfg, jnp.asarray(0))) < 1e-3 / 5
    assert abs(float(schedule(cfg, jnp.asarray(100))) - 1e-3) < 1e-9
