"""End-to-end behaviour: the full train launcher (data pipeline → model →
ACT compression → optimizer → checkpoint/resume) and the serve launcher
(incl. the stash-arena read side) on CPU."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.serve import main as serve_main
from repro.launch.train import main as train_main


def test_train_launcher_loss_decreases(tmp_path):
    hist = train_main([
        "--arch", "qwen1.5-4b", "--smoke", "--steps", "25",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
        "--act-mode", "act", "--ckpt-dir", str(tmp_path / "ck")])
    losses = [h["loss"] for h in hist]
    assert losses[-1] < losses[0] - 0.1, losses[:3] + losses[-3:]


def test_train_launcher_resume(tmp_path):
    ck = str(tmp_path / "ck2")
    train_main(["--arch", "mamba2-780m", "--smoke", "--steps", "6",
                "--batch", "2", "--seq", "64", "--ckpt-dir", ck,
                "--ckpt-every", "3"])
    hist = train_main(["--arch", "mamba2-780m", "--smoke", "--steps", "9",
                       "--batch", "2", "--seq", "64", "--ckpt-dir", ck,
                       "--ckpt-every", "3"])
    # resumed from step 6, ran only 3 more
    assert hist[0]["step"] == 6 and len(hist) == 3


def test_serve_launcher_paged_kv_smoke():
    """``launch.serve`` on an attention family routes through the
    continuous-batching engine with a quantized paged KV cache under the
    host placement policy; every request must come back with its full
    generation budget."""
    outs = serve_main(["--arch", "qwen1.5-4b", "--smoke",
                       "--requests", "2", "--max-batch", "2",
                       "--prompt-len", "8", "--gen-len", "4",
                       "--kv-bits", "8", "--kv-policy", "host"])
    assert len(outs) == 2 and all(o.shape == (4,) for o in outs)


def test_serve_launcher_legacy_family_smoke():
    """Non-attention families (SSM state caches are not paged-KV shaped)
    still serve through the fixed-batch fallback loop, which accumulates
    tokens device-side and transfers once per batch."""
    outs = serve_main(["--arch", "mamba2-780m", "--smoke",
                       "--requests", "2", "--max-batch", "2",
                       "--prompt-len", "16", "--gen-len", "4"])
    assert len(outs) == 2 and all(o.shape == (4,) for o in outs)


def test_serve_loop_greedy_decode():
    """Prefill a prompt then greedily decode 8 tokens; deterministic."""
    import dataclasses

    from repro.configs import ARCHS, reduce_for_smoke
    from repro.launch.steps import make_serve_step
    from repro.models import Model

    r = dataclasses.replace(reduce_for_smoke(ARCHS["qwen3-32b"]),
                            act_mode="none")
    model = Model(r)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, r.vocab)
    _, cache = model.prefill(params, prompt, max_seq=32)
    serve = jax.jit(make_serve_step(model))
    tok = prompt[:, -1:]
    outs = []
    for _ in range(8):
        tok, logits, cache = serve(params, cache, tok)
        outs.append(np.asarray(tok))
    a = np.concatenate(outs, 1)
    # rerun: determinism
    _, cache = model.prefill(params, prompt, max_seq=32)
    tok = prompt[:, -1:]
    outs2 = []
    for _ in range(8):
        tok, logits, cache = serve(params, cache, tok)
        outs2.append(np.asarray(tok))
    np.testing.assert_array_equal(a, np.concatenate(outs2, 1))
