"""Core quantization properties: SR unbiasedness, error bounds, packing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # pragma: no cover - exercised on clean environments
    # Tiny deterministic fallback so the property tests still run (over a
    # fixed sample grid) when hypothesis isn't installed.
    import random as _random

    class _IntStrategy:
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def sample(self, rng):
            return rng.randint(self.lo, self.hi)

    class st:  # noqa: N801 - mimics hypothesis.strategies
        @staticmethod
        def integers(min_value, max_value):
            return _IntStrategy(min_value, max_value)

    def given(*strategies):
        def deco(fn):
            # NB: zero-arg wrapper (no functools.wraps) so pytest doesn't
            # mistake the property arguments for fixtures.
            def wrapper():
                rng = _random.Random(0)
                for _ in range(10):
                    fn(*(s.sample(rng) for s in strategies))
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def settings(**_kwargs):
        return lambda fn: fn

from repro.core import pack as packmod
from repro.core import quant as quantmod
from repro.core.compressor import CompressionConfig, compress, decompress


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("n", [1, 5, 32, 100])
def test_pack_roundtrip_exact(bits, n):
    codes = jnp.arange(n, dtype=jnp.int32) % (2**bits)
    words = packmod.pack(codes, bits)
    assert words.dtype == jnp.uint32
    back = packmod.unpack(words, bits, n)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


@given(st.integers(0, 2**32 - 1), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_pack_roundtrip_property(seed, n):
    rng = np.random.default_rng(seed)
    for bits in (2, 4):
        codes = jnp.asarray(rng.integers(0, 2**bits, n), jnp.int32)
        back = packmod.unpack(packmod.pack(codes, bits), bits, n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))


def test_sr_unbiased_uniform_levels():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 128)) * 2 + 0.3
    cfg = CompressionConfig(bits=2, group_size=128)
    mean = jnp.zeros_like(x)
    n = 400
    for s in range(n):
        mean = mean + decompress(compress(x, cfg, s))
    mean = mean / n
    rel = float(jnp.abs(mean - x).max() / (x.max() - x.min()))
    assert rel < 0.03, f"SR biased? rel={rel}"


def test_sr_unbiased_vm_levels():
    """Non-uniform (VM) levels must stay unbiased (paper App. A)."""
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 128))
    cfg = CompressionConfig(bits=2, group_size=128, vm=True)
    mean = jnp.zeros_like(x)
    n = 400
    for s in range(n):
        mean = mean + decompress(compress(x, cfg, s))
    mean = mean / n
    rel = float(jnp.abs(mean - x).max() / (x.max() - x.min()))
    assert rel < 0.03, f"VM SR biased? rel={rel}"


@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_error_bounded_by_bin(bits):
    """|x - dequant| <= max bin width (SR never rounds past a neighbor)."""
    x = jax.random.normal(jax.random.PRNGKey(2), (16, 64)) * 5
    codes, zero, rng, _ = quantmod.quantize(x, bits, 64, seed=7)
    xh = quantmod.dequantize(codes, zero, rng, bits, x.shape)
    bin_w = rng / (2**bits - 1)
    err = jnp.abs(xh - x)
    assert float((err - bin_w[:, None] * 1.001).max()) <= 0


def test_constant_block_exact():
    x = jnp.full((2, 64), 3.14159)
    cfg = CompressionConfig(bits=2, group_size=64)
    xh = decompress(compress(x, cfg, 0))
    np.testing.assert_allclose(np.asarray(xh), np.asarray(x), rtol=1e-6)


def test_compressed_nbytes_shrinks_with_group_size():
    """The paper's Table 1 memory trend: larger G -> smaller footprint."""
    x = jax.random.normal(jax.random.PRNGKey(3), (256, 256))
    sizes = []
    for g in (16, 32, 64, 128, 256):
        ct = compress(x, CompressionConfig(bits=2, group_size=g), 0)
        sizes.append(ct.nbytes)
    assert all(a >= b for a, b in zip(sizes, sizes[1:])), sizes
    # INT2 alone ~ 2/32 bits + block overhead
    assert sizes[-1] < 0.08 * x.size * 4
    # with the paper's D/R=8 random projection: >95% total reduction
    ct = compress(x, CompressionConfig(bits=2, group_size=64, rp_ratio=8), 0)
    assert ct.nbytes < 0.05 * x.size * 4


def test_counter_base_wraparound_safe():
    """Satellite bugfix: ``counter_base`` >= 2**32 used to wrap the uint32
    counter back onto base 0, silently reusing the SR noise stream.  The
    high word is now folded into the seed via the counter PRNG, so disjoint
    counter ranges (including ones 2**32 apart) draw decorrelated streams.
    """
    lv = quantmod.uniform_levels(2)
    h = jnp.full((4, 64), 1.5)  # mid-bin: codes are pure Bernoulli draws
    c0 = quantmod.stochastic_round_to_levels(h, lv, 7, counter_base=0)
    # same range re-drawn -> identical (determinism unchanged)
    np.testing.assert_array_equal(
        np.asarray(c0),
        np.asarray(quantmod.stochastic_round_to_levels(h, lv, 7,
                                                       counter_base=0)))
    # disjoint low-word ranges were always decorrelated
    c_lo = quantmod.stochastic_round_to_levels(h, lv, 7, counter_base=h.size)
    assert not np.array_equal(np.asarray(c0), np.asarray(c_lo))
    # bases 2**32 apart used to alias base 0 exactly; must differ now
    c_hi = quantmod.stochastic_round_to_levels(h, lv, 7, counter_base=1 << 32)
    assert not np.array_equal(np.asarray(c0), np.asarray(c_hi))
    # and distinct high words must not alias each other either
    c_hi2 = quantmod.stochastic_round_to_levels(h, lv, 7, counter_base=2 << 32)
    assert not np.array_equal(np.asarray(c_hi), np.asarray(c_hi2))
    # a chunk straddling a 2**32 boundary: the wrapped tail lands on low
    # counters 0.. but with a carried high word, so it must not replay the
    # base-0 stream (the old uint32 add aliased it exactly)
    n = h.size
    c_straddle = quantmod.stochastic_round_to_levels(
        h, lv, 7, counter_base=(1 << 32) - n // 2)
    tail = np.asarray(c_straddle).reshape(-1)[n // 2:]
    head_of_zero = np.asarray(c0).reshape(-1)[:n - n // 2]
    assert not np.array_equal(tail, head_of_zero)
    # all streams stay unbiased Bernoulli(0.5)-ish draws
    for c in (c0, c_lo, c_hi, c_hi2, c_straddle):
        assert 0.2 < float(jnp.mean(c % 2)) < 0.8


@given(st.integers(0, 1000))
@settings(max_examples=10, deadline=None)
def test_quant_dequant_idempotent_on_levels(seed):
    """Values already at quantization levels survive exactly."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 4, (4, 64))
    zero, span = -1.0, 2.0
    x = jnp.asarray(zero + codes / 3.0 * span, jnp.float32)
    c2, z2, r2, _ = quantmod.quantize(x, 2, 64, seed=seed)
    xh = quantmod.dequantize(c2, z2, r2, 2, x.shape)
    np.testing.assert_allclose(np.asarray(xh), np.asarray(x), atol=1e-5)
