"""Variance-guided adaptive bit allocation (core.autoprec) and the
heterogeneous-precision plumbing it drives through the GNN stack, plus the
memory-model fixes the allocator's byte accounting depends on."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, autoprec, compress
from repro.core.autoprec import (LayerStats, allocate_bits, budget_bytes_for,
                                 expected_layer_variance, layer_stash_bytes,
                                 total_expected_variance, total_stash_bytes)
from repro.graph import (GNNConfig, activation_memory_report,
                         collect_layer_stats, synthetic_graph, train_gnn,
                         train_gnn_batched)
from repro.graph.analysis import relu_mask_nbytes, saved_bytes_per_layer
from repro.graph.models import (graph_tuple, gnn_forward, init_gnn_params,
                                _relu_fwd)


@pytest.fixture(scope="module")
def small_graph():
    return synthetic_graph("autoprec", 768, 4000, 64, 6, homophily=0.6,
                           feature_noise=1.0, seed=2)


def _stats3():
    """Three layers with strongly heterogeneous sensitivity."""
    return [LayerStats(shape=(256, 32), n_blocks=128, rng_sq_mean=900.0),
            LayerStats(shape=(256, 16), n_blocks=64, rng_sq_mean=25.0),
            LayerStats(shape=(256, 16), n_blocks=64, rng_sq_mean=1e-4)]


def _templates3():
    t = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    return [t, t, t]


# ------------------------------------------------------------ solver core
def test_allocation_respects_budget():
    stats, tmpl = _stats3(), _templates3()
    for avg in (1.0, 1.5, 2.0, 3.0, 4.0, 8.0):
        budget = budget_bytes_for(stats, tmpl, avg)
        bits = allocate_bits(stats, tmpl, budget)
        per = [dataclasses.replace(t, bits=b) for t, b in zip(tmpl, bits)]
        assert total_stash_bytes(stats, per) <= budget, (avg, bits)
        assert all(b in autoprec.BIT_CHOICES for b in bits)


def test_allocation_never_worse_than_any_uniform_fit():
    """The backstop contract: at its budget, the allocation's modeled
    variance is <= every uniform width that fits the same budget."""
    stats, tmpl = _stats3(), _templates3()
    for avg in (1.0, 2.0, 4.0, 8.0):
        budget = budget_bytes_for(stats, tmpl, avg)
        bits = allocate_bits(stats, tmpl, budget)
        per = [dataclasses.replace(t, bits=b) for t, b in zip(tmpl, bits)]
        v = total_expected_variance(stats, per)
        for b in autoprec.BIT_CHOICES:
            uni = [dataclasses.replace(t, bits=b) for t in tmpl]
            if total_stash_bytes(stats, uni) <= budget:
                assert v <= total_expected_variance(stats, uni) * (1 + 1e-12)


def test_fractional_budget_goes_mixed():
    """Between uniform widths only a heterogeneous allocation can use the
    budget: with strongly skewed sensitivities the solver must split."""
    stats, tmpl = _stats3(), _templates3()
    budget = budget_bytes_for(stats, tmpl, 2.5)
    bits = allocate_bits(stats, tmpl, budget)
    assert len(set(bits)) > 1, bits
    # the near-dead layer must never out-bid the hot one
    assert bits[0] >= bits[2], bits


def test_variance_monotone_in_budget():
    stats, tmpl = _stats3(), _templates3()
    prev = None
    for avg in (1.0, 1.5, 2.0, 3.0, 4.0, 8.0):
        budget = budget_bytes_for(stats, tmpl, avg)
        bits = allocate_bits(stats, tmpl, budget)
        per = [dataclasses.replace(t, bits=b) for t, b in zip(tmpl, bits)]
        v = total_expected_variance(stats, per)
        if prev is not None:
            assert v <= prev * (1 + 1e-12)
        prev = v


def test_too_tight_budget_degrades_to_minimum():
    stats, tmpl = _stats3(), _templates3()
    bits = allocate_bits(stats, tmpl, budget_bytes=1)
    assert bits == (1, 1, 1)


def test_uncompressed_layers_skipped():
    stats, tmpl = _stats3(), _templates3()
    stats[1] = None
    tmpl[1] = None
    budget = budget_bytes_for(stats, tmpl, 2.0)
    bits = allocate_bits(stats, tmpl, budget)
    assert bits[1] == 0 and bits[0] in autoprec.BIT_CHOICES


def test_grad_sens_overrides_range_moments():
    """A calibrated gradient sensitivity replaces the moment product: a
    layer with huge ranges but measured-zero gradient noise loses its bits
    to the layer the probe says actually hurts."""
    t = CompressionConfig(bits=2, group_size=64)
    stats = [LayerStats((256, 16), 64, 900.0, grad_sens=1e-6),
             LayerStats((256, 16), 64, 1.0, grad_sens=1e3)]
    # 2.5 avg bits: the slack funds (1, 4) / (4, 1) but not (2, 4) — the
    # probe-weighted solver must give the extra width to layer 1 even
    # though layer 0's raw range moments are 900x larger
    budget = budget_bytes_for(stats, [t, t], 2.5)
    bits = allocate_bits(stats, [t, t], budget)
    assert bits[1] > bits[0], bits
    flipped = [dataclasses.replace(s, grad_sens=g)
               for s, g in zip(stats, (1e3, 1e-6))]
    bits = allocate_bits(flipped, [t, t], budget)
    assert bits[0] > bits[1], bits


def test_expected_layer_variance_scales_down_with_bits():
    t = CompressionConfig(bits=2, group_size=64)
    s = LayerStats((128, 16), 32, 10.0)
    vs = [expected_layer_variance(s, dataclasses.replace(t, bits=b))
          for b in (1, 2, 4, 8)]
    assert all(a > b for a, b in zip(vs, vs[1:]))


def test_integer_budget_matches_fixed_width_bytes():
    """avg_bits in BIT_CHOICES reproduces the packed fixed-width footprint
    exactly (the benchmark's equal-compressed-bytes contract)."""
    stats, tmpl = _stats3(), _templates3()
    for b in autoprec.BIT_CHOICES:
        uni = [dataclasses.replace(t, bits=b) for t in tmpl]
        assert budget_bytes_for(stats, tmpl, b) == \
            total_stash_bytes(stats, uni)


# ----------------------------------------------- per-layer config plumbing
def test_gnn_config_layer_compression_broadcast_and_tuple():
    comp = CompressionConfig(bits=2, group_size=64)
    cfg = GNNConfig(hidden=(32, 32), compression=comp)
    assert cfg.layer_compression() == (comp, comp, comp)
    cfg2 = cfg.with_layer_bits((1, 4, 8))
    assert [c.bits for c in cfg2.layer_compression()] == [1, 4, 8]
    # group/rp/vm settings survive the width change
    assert all(c.group_size == 64 for c in cfg2.layer_compression())
    with pytest.raises(ValueError, match="bit-widths"):
        cfg.with_layer_bits((2, 2))
    with pytest.raises(ValueError, match="entries"):
        GNNConfig(hidden=(32,), compression=(comp,)).layer_compression()
    assert GNNConfig(hidden=(32,)).layer_compression() == (None, None)


def test_with_impl_maps_over_layer_tuple():
    comp = CompressionConfig(bits=2, group_size=64)
    cfg = GNNConfig(hidden=(32,), compression=(comp, None)).with_impl("interp")
    assert cfg.layer_compression()[0].impl == "interp"
    assert cfg.layer_compression()[1] is None


def test_forward_runs_heterogeneous_widths(small_graph):
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=comp).with_layer_bits((8, 4, 1))
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    out = gnn_forward(params, graph_tuple(g), cfg, seed=3)
    assert out.shape == (g.n_nodes, g.num_classes)
    assert jnp.isfinite(out).all()
    grads = jax.grad(lambda p: gnn_forward(p, graph_tuple(g), cfg,
                                           seed=3).sum())(params)
    assert all(jnp.isfinite(l).all() for l in jax.tree.leaves(grads))


# -------------------------------------------------------- training engines
def test_train_gnn_bit_budget_end_to_end(small_graph):
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=comp)
    r = train_gnn(g, cfg, n_epochs=6, seed=0, bit_budget=2.0,
                  autoprec_refresh=3)
    assert np.isfinite(r["test_acc"])
    assert len(r["bits_per_layer"]) == cfg.n_layers
    assert all(b in autoprec.BIT_CHOICES for b in r["bits_per_layer"])
    per = r["cfg"].layer_compression()
    stats = collect_layer_stats(r["params"], graph_tuple(g), cfg)
    assert total_stash_bytes(stats, per) <= r["bit_budget_bytes"]


def test_train_gnn_batched_bit_budget(small_graph):
    # seed >= 2 is a regression gate: the probe-seed derivation used to
    # overflow uint32 conversion (numpy >= 2 raises instead of wrapping)
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=comp)
    r = train_gnn_batched(g, cfg, n_parts=2, n_epochs=4, seed=3,
                          bit_budget=1.5, autoprec_refresh=2)
    assert np.isfinite(r["test_acc"])
    assert len(r["bits_per_layer"]) == cfg.n_layers


def test_bit_budget_requires_compression(small_graph):
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes)
    with pytest.raises(ValueError, match="compression"):
        train_gnn(g, cfg, n_epochs=1, bit_budget=2.0)


# ------------------------------------------------------------ memory model
def test_relu_mask_bytes_match_actual_packed_mask():
    """Satellite bugfix: the ReLU mask is stored in whole uint32 words —
    the old ``n // 8`` floor undercounted every non-32-aligned count."""
    for shape in [(7, 5), (33, 3), (64, 32), (1, 1)]:
        z = jax.random.normal(jax.random.PRNGKey(shape[0]), shape)
        _, (mask, _) = _relu_fwd(z)
        n = int(np.prod(shape))
        assert relu_mask_nbytes(n) == mask.size * 4, shape
    assert relu_mask_nbytes(33) == 8           # old model said 33 // 8 == 4


def test_saved_bytes_match_real_compressed_tensor(small_graph):
    """Acceptance gate: the per-layer byte model equals the real packed
    ``CompressedTensor.nbytes`` + actual mask words — no floor drift."""
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=96, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(30, 30), n_classes=g.num_classes,
                    compression=comp).with_layer_bits((4, 2, 1))
    rows = saved_bytes_per_layer(cfg, g.n_feats, g.n_nodes)
    dims = [g.n_feats, 30, 30, g.num_classes]
    for li, row in enumerate(rows):
        lin_in = 2 * dims[li]
        d_eff = lin_in // comp.rp_ratio
        layer_comp = cfg.layer_compression()[li]
        x = jax.random.normal(jax.random.PRNGKey(li), (g.n_nodes, d_eff))
        ct = compress(x, dataclasses.replace(layer_comp, rp_ratio=0), li)
        expect = ct.nbytes
        if li < len(rows) - 1:
            _, (mask, _) = _relu_fwd(
                jax.random.normal(jax.random.PRNGKey(li + 7),
                                  (g.n_nodes, dims[li + 1])))
            expect += mask.size * 4
        assert row["compressed_bytes"] == expect, (li, row)
        assert row["bits"] == layer_comp.bits


def test_memory_report_mixed_precision(small_graph):
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32, 32), n_classes=g.num_classes,
                    compression=comp)
    mixed = cfg.with_layer_bits((1, 2, 4))
    rep_f = activation_memory_report(g, cfg)
    rep_m = activation_memory_report(g, mixed)
    assert rep_m["bits_per_layer"] == [1, 2, 4]
    assert rep_f["bits_per_layer"] == [2, 2, 2]
    # row-level widths drive the totals
    assert rep_m["per_layer"][0]["compressed_bytes"] < \
        rep_f["per_layer"][0]["compressed_bytes"]
    assert rep_m["per_layer"][2]["compressed_bytes"] > \
        rep_f["per_layer"][2]["compressed_bytes"]
    # a layer without compression contributes fp32 bytes to the total
    hetero = dataclasses.replace(cfg, compression=(None, comp, comp))
    rep_h = activation_memory_report(g, hetero)
    assert rep_h["compressed_bytes"] > rep_f["compressed_bytes"]
    assert "compressed_bytes" not in rep_h["per_layer"][0]


def test_collect_layer_stats_shapes(small_graph):
    g = small_graph
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=(comp, None))
    params = init_gnn_params(jax.random.PRNGKey(1), cfg, g.n_feats)
    stats = collect_layer_stats(params, graph_tuple(g), cfg)
    assert stats[1] is None
    s0 = stats[0]
    assert s0.shape == (g.n_nodes, (2 * g.n_feats) // 8)
    assert s0.n_blocks == -(-s0.n_elements // comp.group_size)
    assert s0.rng_sq_mean > 0
