"""repro.staticcheck: crafted violations each yield exactly their finding,
and the real repo comes up clean."""
import dataclasses
import json

import jax.numpy as jnp
import pytest

from repro.engine.plan import (ExecutionPlan, KernelPolicy, PrecisionPolicy,
                               SamplingPolicy, StashPolicy)
from repro.offload.gnn import plan_gnn_stashes
from repro.staticcheck import jaxpr_audit, kernel_contracts, plan_verify
from repro.staticcheck import seed_lint
from repro.staticcheck.findings import Finding, new_findings
from repro.staticcheck.matrix import audit_matrix, gnn_cfg, _FIXED


def _by_key():
    return {c.key: c for c in audit_matrix()}


# ---------------------------------------------------------------- policies


@pytest.mark.parametrize("field,make", [
    ("sampling.kind", lambda: SamplingPolicy(kind="bogus")),
    ("sampling.n_parts", lambda: SamplingPolicy(kind="partition",
                                                n_parts=0)),
    ("sampling.grad_accum", lambda: SamplingPolicy(kind="mesh", n_parts=4,
                                                   grad_accum=2)),
    ("precision.kind", lambda: PrecisionPolicy(kind="bogus")),
    ("precision.bit_budget", lambda: PrecisionPolicy(kind="autoprec")),
    ("stash.kind", lambda: StashPolicy(kind="bogus")),
    ("stash.placement", lambda: StashPolicy(kind="arena",
                                            placement="bogus")),
    ("kernel.impl", lambda: KernelPolicy(impl="bogus")),
    ("kernel.fused", lambda: KernelPolicy(fused="bogus")),
])
def test_plan_validation_names_offending_field(field, make):
    """Every policy validation error names the offending field and value
    (satellite 1); plan_verify surfaces the same message verbatim."""
    with pytest.raises(ValueError, match=field.replace(".", r"\.")) as ei:
        make()
    assert "bogus" in str(ei.value) or "=" in str(ei.value)


def test_verify_legacy_kwargs_reuses_field_messages():
    got = plan_verify.verify_legacy_kwargs(offload="bogus")
    assert len(got) == 1 and got[0].rule == "policy-field"
    assert "stash.placement" in got[0].message


# ------------------------------------------------------------ plan-verify


def _tensor_splan():
    return plan_gnn_stashes(gnn_cfg(_FIXED), 32, 256)


def test_arena_overlap_is_exactly_detected():
    splan = _tensor_splan()
    lp = splan.layers[0]
    # slide rp_seed inside the packed span: bounds/geometry stay valid
    bad = dataclasses.replace(lp, rp_seed=dataclasses.replace(
        lp.rp_seed, offset=lp.packed.offset))
    mutated = dataclasses.replace(splan,
                                  layers=(bad,) + splan.layers[1:])
    got = plan_verify.verify_stash_plan(mutated)
    assert [f.rule for f in got] == ["arena-overlap"]
    assert "u32 arena" in got[0].message


def test_ragged_mask_floor_is_exactly_detected():
    splan = _tensor_splan()
    lp = next(l for l in splan.layers if l.mask is not None)
    # the historical bug class: floor-divide drops the partial word of a
    # ragged tail (mask_elems not a multiple of 32)
    ragged = lp.mask_elems + 5
    floor_words = ragged // 32
    bad = dataclasses.replace(
        lp, mask_elems=ragged,
        mask=dataclasses.replace(lp.mask, size=floor_words))
    mutated = dataclasses.replace(
        splan, layers=tuple(bad if l is lp else l for l in splan.layers))
    got = plan_verify.verify_stash_plan(mutated)
    assert [f.rule for f in got] == ["mask-alignment"]
    assert "ragged tail" in got[0].message


def test_real_matrix_verifies_clean():
    for case in audit_matrix():
        assert plan_verify.verify_plan(case.plan, case.cfg, case.in_dim,
                                       case.n_nodes, where=case.key) == []


def test_kv_matrix_verifies_clean():
    assert plan_verify.verify_kv_matrix() == []


def _kv_layout(**kw):
    from repro.serving.kvcache import KVCacheConfig, plan_kv_layout

    return plan_kv_layout(KVCacheConfig(**kw), n_layers=2, n_kv_heads=4,
                          d_head=16)


def test_kv_page_overlap_and_bounds_exactly_detected():
    lay = _kv_layout(bits=4, n_pages=8)
    w = lay.words_per_page
    # page 1 starts one word inside page 0's span
    got = plan_verify.verify_kv_layout(
        lay, segments=[(0, 0, 0, w), (0, 1, w - 1, w)])
    assert [f.rule for f in got] == ["kv-page-overlap"]
    # last page pushed one word past the pool end
    got = plan_verify.verify_kv_layout(
        lay, segments=[(1, 7, lay.total_words - w + 1, w)])
    assert [f.rule for f in got] == ["kv-page-bounds"]
    # a segment sized off-geometry
    got = plan_verify.verify_kv_layout(lay, segments=[(0, 0, 0, w - 2)])
    assert [f.rule for f in got] == ["kv-page-geometry"]


def test_kv_word_alignment_exactly_detected():
    import dataclasses as dc

    # bypass plan_kv_layout validation: group of 6 at bits=8 leaves a
    # ragged 2-value tail in the last packed word of every block
    lay = dc.replace(_kv_layout(bits=8), group_size=6)
    rules = {f.rule for f in plan_verify.verify_kv_layout(lay)}
    assert "kv-page-alignment" in rules


def test_mesh_cross_policy_rules():
    plan = ExecutionPlan(
        sampling=SamplingPolicy(kind="mesh", n_parts=4),
        stash=StashPolicy(kind="arena", placement="device"),
        kernel=KernelPolicy(fused="on"))
    rules = {f.rule for f in plan_verify.verify_combination(plan)}
    assert rules == {"mesh-stash", "mesh-fused"}


# -------------------------------------------------------- kernel-contracts


def test_oversized_autotune_tile_is_exactly_detected(tmp_path):
    cache = tmp_path / "fused_tiles.json"
    cache.write_text(json.dumps(
        {"fwd/4096x1024x4096/b2/g64/cpu": [2048, 2048]}))
    got = kernel_contracts.check_autotune_cache(cache)
    assert [f.rule for f in got] == ["vmem-budget"]
    assert "VMEM" in got[0].message


def test_malformed_cache_key_is_detected(tmp_path):
    cache = tmp_path / "fused_tiles.json"
    cache.write_text(json.dumps({"fwd/banana": [128, 128]}))
    got = kernel_contracts.check_autotune_cache(cache)
    assert [f.rule for f in got] == ["cache-key"]


def test_real_autotune_cache_is_contract_clean():
    assert kernel_contracts.run() == []


# --------------------------------------------------------------- seed-lint


def test_seed_constant_reuse_is_exactly_detected():
    got = seed_lint.lint_source(
        "def stash_seed(li):\n    return (li + 1) * 7919\n",
        "repro/somewhere/mod.py")
    assert [f.rule for f in got] == ["seed-constant"]
    assert "7919" in got[0].message


def test_seed_constants_allowed_in_scheme_home():
    src = "SR_SEED_PRIME = 7919\n"
    assert seed_lint.lint_source(src, "repro/engine/seeds.py") == []
    assert len(seed_lint.lint_source(src, "repro/other.py")) == 1


def test_jit_host_nondeterminism_detected():
    src = ("import time\nimport jax\n\n"
           "@jax.jit\ndef step(x):\n    t = time.time()\n    return x + t\n")
    got = seed_lint.lint_source(src, "repro/mod.py")
    assert [f.rule for f in got] == ["jit-host-nondeterminism"]


def test_sr_seed_reuse_detected():
    src = ("def f(x, y):\n"
           "    a = sr_seed(3)\n"
           "    b = sr_seed(3)\n"
           "    return a, b\n")
    got = seed_lint.lint_source(src, "repro/mod.py")
    assert [f.rule for f in got] == ["sr-seed-reuse"]


def test_host_callback_outside_obs_tap_detected():
    """A raw jax.debug.callback inside jitted code is a finding unless it
    lives in the sanctioned homes (the obs tap or the offload store)."""
    src = ("import jax\n\n"
           "@jax.jit\ndef step(x):\n"
           "    jax.debug.callback(print, x)\n    return x\n")
    got = seed_lint.lint_source(src, "repro/graph/train.py")
    assert [f.rule for f in got] == ["host-callback-tap"]
    # same source is sanctioned in the obs telemetry module and the
    # offload callback host store
    assert seed_lint.lint_source(src, "repro/obs/quantstats.py") == []
    assert seed_lint.lint_source(src, "repro/offload/engine.py") == []


def test_host_callback_variants_detected():
    src = ("import jax\n\n"
           "def inner(x):\n"
           "    return jax.pure_callback(abs, x, x)\n\n"
           "out = jax.jit(inner)\n")
    got = seed_lint.lint_source(src, "repro/core/quant.py")
    assert [f.rule for f in got] == ["host-callback-tap"]


def test_obs_tap_on_dataflow_path_detected():
    """tap() must never appear on the residual/stash dataflow path — a
    tap there puts the telemetry callback inside the training jaxpr and
    forfeits obs-on/obs-off bit-identity."""
    src = ("from repro.obs.quantstats import tap\n\n"
           "def f_fwd(x):\n    tap(print, x)\n    return x\n")
    got = seed_lint.lint_source(src, "repro/engine/forward.py")
    assert [f.rule for f in got] == ["obs-tap-dataflow"]
    for fname in ("repro/offload/engine.py", "repro/offload/arena.py"):
        assert ["obs-tap-dataflow"] == [
            f.rule for f in seed_lint.lint_source(src, fname)]
    # outside the dataflow path (and outside jit) a tap is fine
    assert seed_lint.lint_source(src, "repro/engine/runner.py") == []


def test_obs_calibration_needs_telemetry_channel():
    from repro.obs import ObsPolicy

    plan = ExecutionPlan(
        precision=PrecisionPolicy(kind="autoprec", bit_budget=2.0,
                                  calibration="obs"))
    got = plan_verify.verify_combination(plan)
    assert [f.rule for f in got] == ["obs-calibration"]
    ok = dataclasses.replace(
        plan, obs=ObsPolicy(enabled=True, quant_stats=True))
    assert plan_verify.verify_combination(ok) == []


def test_repo_seed_discipline_is_clean():
    assert seed_lint.run() == []


# -------------------------------------------------------------- jaxpr-audit


def _audit(key):
    return jaxpr_audit.audit_case(_by_key()[key])


@pytest.mark.parametrize("key", [
    "full/fixed/tensor/fused-off",
    "batched/fixed/device/fused-off",
    "mesh/fixed/tensor/fused-off",
])
def test_ledger_matches_memory_report(key):
    """Acceptance: the jaxpr byte ledger equals activation_memory_report
    within 1% on the full/batched/mesh matrix (it is exact here)."""
    r = _audit(key)
    assert r.findings == []
    assert r.ledger_bytes == r.report_bytes


def test_callback_plan_ships_exactly_planned_bytes():
    r = _audit("full/fixed/host/fused-off")
    assert r.findings == []
    assert r.ledger_bytes == r.report_bytes


def test_residual_leak_is_exactly_detected():
    from repro.engine.forward import _build

    case = _by_key()["full/fixed/tensor/fused-off"]
    splan = plan_gnn_stashes(case.cfg, case.in_dim, case.live_nodes)
    fwd = _build(case.cfg, splan, case.plan.stash,
                 case.plan.kernel.fused).fwd

    def leaky(*a):
        h, res = fwd(*a)
        # a raw f32 activation escaping the quantizer
        return h, (res, jnp.zeros((257,), jnp.float32))

    got, _ = jaxpr_audit.audit_forward(
        leaky, jaxpr_audit._example_args(case.cfg, case.in_dim,
                                         case.live_nodes),
        splan, "tensor", where="crafted")
    assert [f.rule for f in got] == ["residual-leak"]
    assert "escaped the quantizer" in got[0].message


def test_missing_stash_is_detected():
    from repro.engine.forward import _build

    case = _by_key()["full/fixed/tensor/fused-off"]
    splan = plan_gnn_stashes(case.cfg, case.in_dim, case.live_nodes)
    fwd = _build(case.cfg, splan, case.plan.stash,
                 case.plan.kernel.fused).fwd

    def dropping(*a):
        h, _ = fwd(*a)
        return h, ()

    got, ledger = jaxpr_audit.audit_forward(
        dropping, jaxpr_audit._example_args(case.cfg, case.in_dim,
                                            case.live_nodes),
        splan, "tensor", where="crafted")
    assert got and all(f.rule == "missing-stash" for f in got)
    assert ledger == 0


# ---------------------------------------------------------------- dead-code


def test_dead_code_crafted(tmp_path):
    from repro.staticcheck import deadcode

    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    (pkg / "__init__.py").write_text("")
    (pkg / "mod.py").write_text(
        "def used():\n    return 1\n\n\ndef unused():\n    return 2\n")
    (pkg / "other.py").write_text(
        "from repro.mod import used\n\n\ndef caller():\n"
        "    return used()\n")
    (tmp_path / "tests").mkdir()
    (tmp_path / "tests" / "test_x.py").write_text(
        "from repro.other import caller\ncaller()\n")
    got = deadcode.sweep(tmp_path)
    assert [(f.rule, "unused" in f.message) for f in got] == \
        [("unused-symbol", True)]
    assert "repro.mod.unused" in got[0].message


def test_reexport_is_transparent(tmp_path):
    from repro.staticcheck import deadcode

    pkg = tmp_path / "src" / "repro"
    pkg.mkdir(parents=True)
    # shim kept importable only by its package __init__: still dead
    (pkg / "__init__.py").write_text("from repro.mod import shim\n")
    (pkg / "mod.py").write_text("def shim():\n    return 0\n")
    got = deadcode.sweep(tmp_path)
    assert [f.rule for f in got] == ["unused-symbol"]


# ------------------------------------------------------------ CLI/baseline


def test_fingerprint_ignores_message_rewording():
    a = Finding("p", "r", "w", "old text")
    b = Finding("p", "r", "w", "new text")
    assert a.fingerprint() == b.fingerprint()
    assert new_findings([b], {a.fingerprint()}) == []
    assert new_findings([b], set()) == [b]


def test_cli_gates_on_new_findings(tmp_path):
    from repro.staticcheck.cli import main

    baseline = tmp_path / "baseline.json"
    assert main(["--passes", "kernel-contracts",
                 "--baseline", str(baseline)]) == 0
    assert main(["--passes", "bogus-pass",
                 "--baseline", str(baseline)]) == 2
