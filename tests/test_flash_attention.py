"""Flash-attention Pallas kernel vs softmax oracle (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_call
from repro.kernels import ref


@pytest.mark.parametrize("bh,sq,skv,dh,causal,bq,bk", [
    (4, 256, 256, 64, True, 128, 128),
    (2, 256, 512, 64, False, 128, 128),
    (2, 128, 128, 128, True, 64, 64),
    (1, 512, 256, 64, False, 128, 64),
])
def test_flash_matches_oracle(bh, sq, skv, dh, causal, bq, bk):
    ks = jax.random.split(jax.random.PRNGKey(bh + sq), 3)
    q = jax.random.normal(ks[0], (bh, sq, dh), jnp.float32)
    k = jax.random.normal(ks[1], (bh, skv, dh), jnp.float32)
    v = jax.random.normal(ks[2], (bh, skv, dh), jnp.float32)
    out = flash_attention_call(q, k, v, causal=causal, blk_q=bq, blk_k=bk,
                               interpret=True)
    expected = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expected),
                               atol=3e-5, rtol=3e-5)


def test_flash_bf16_inputs():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (2, 128, 64), jnp.bfloat16)
    k = jax.random.normal(ks[1], (2, 128, 64), jnp.bfloat16)
    v = jax.random.normal(ks[2], (2, 128, 64), jnp.bfloat16)
    out = flash_attention_call(q, k, v, causal=True, blk_q=64, blk_k=64,
                               interpret=True)
    expected = ref.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expected, np.float32),
        atol=3e-2, rtol=3e-2)
    assert out.dtype == jnp.bfloat16
