"""Engine parity gates (ISSUE 5 acceptance).

The contract this file enforces:

* ``train_gnn`` / ``train_gnn_batched`` are now plan-building wrappers
  over ``engine.run`` — their loss/param trajectories must be
  **bit-identical** to the pre-refactor behavior, reconstructed here as
  hand-rolled legacy loops over the per-op autodiff ``custom_vjp`` stack
  (``_loss_fn`` with ``plan=None`` composes ``compressed_matmul`` /
  ``relu_1bit`` exactly as the old ``make_step`` closures did), across
  ``impl ∈ {jnp, interp}``, offload on/off, and mixed bits {1, 2, 4, 8};
* the kwarg → plan mapping: each legacy entry point equals an explicit
  ``ExecutionPlan`` handed to ``engine.run``;
* exactly one stash-aware ``custom_vjp`` forward remains: the per-tensor
  and arena stash policies of ``engine.forward`` reproduce the per-op
  autodiff gradients bit for bit (they *are* the same computation);
* the hoisted seed scheme (``engine.seeds``) is pinned numerically.
"""
import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.engine import seeds
from repro.engine.plan import (ExecutionPlan, KernelPolicy, PrecisionPolicy,
                               SamplingPolicy, StashPolicy)
from repro.graph import GNNConfig, cora_like, train_gnn, train_gnn_batched
from repro.graph.models import gnn_forward, graph_tuple, init_gnn_params
from repro.graph.train import _loss_fn
from repro.optim import AdamWConfig, adamw_init, adamw_update


@pytest.fixture(scope="module")
def g():
    return cora_like(scale=0.2, seed=0)


COMP = CompressionConfig(bits=2, group_size=64, rp_ratio=8)


def _cfg(g, comp=COMP, hidden=(32,), arch="sage"):
    return GNNConfig(arch=arch, hidden=hidden, n_classes=g.num_classes,
                     compression=comp)


def _tree_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ------------------------------------------------------------- seed scheme
def test_seed_scheme_pinned():
    """The hoisted helpers reproduce the literal pre-engine derivations:
    sr_seed(o) == (o+1)*7919 (uint32, wrapping), layer stride 1013."""
    assert seeds.SR_SEED_PRIME == 7919
    assert seeds.LAYER_SEED_STRIDE == 1013
    assert int(seeds.sr_seed(0)) == 7919
    assert int(seeds.sr_seed(12)) == 13 * 7919
    # arrays (a dp group at once) and traced scalars behave alike
    np.testing.assert_array_equal(
        np.asarray(seeds.sr_seed(jnp.arange(4))),
        (np.arange(4, dtype=np.uint32) + 1) * np.uint32(7919))
    np.testing.assert_array_equal(
        np.asarray(jax.jit(seeds.sr_seed)(jnp.asarray(7))),
        np.uint32(8 * 7919))
    # uint32 wraparound, not overflow
    big = int(seeds.sr_seed(2**31))
    assert 0 <= big < 2**32
    assert int(seeds.layer_seed(jnp.uint32(5), 3)) == 5 + 3 * 1013
    # batch ordinals: epoch e, update u, micro a, dp lanes
    ords = seeds.batch_ordinals(epoch=2, n_batches=8, update=1, group=4,
                                micro=1, dp=2)
    np.testing.assert_array_equal(np.asarray(ords), [22, 23])


def test_seed_scheme_deterministic_across_processes():
    """Pure functions of their inputs — same ordinal, same seed, always
    (the replay-determinism contract train resumption relies on)."""
    a = np.asarray(seeds.sr_seed(jnp.arange(100)))
    b = np.asarray(seeds.sr_seed(jnp.arange(100)))
    np.testing.assert_array_equal(a, b)
    s1, s2 = seeds.probe_seeds(17)
    t1, t2 = seeds.probe_seeds(17)
    assert (int(s1), int(s2)) == (int(t1), int(t2)) and int(s1) != int(s2)
    # order rng: same stream from the same seed
    np.testing.assert_array_equal(seeds.order_rng(3).permutation(16),
                                  seeds.order_rng(3).permutation(16))


# ----------------------------------------------------------- plan mapping
def test_plan_from_legacy_mapping():
    p = ExecutionPlan.from_legacy()
    assert p.sampling.kind == "full" and p.stash.kind == "tensor"
    assert p.precision.kind == "fixed" and p.kernel.impl is None
    assert p.offload is None
    p = ExecutionPlan.from_legacy(n_parts=4, offload="host", impl="interp",
                                  bit_budget=1.5, autoprec_refresh=3,
                                  halo=1, grad_accum=2, shuffle=False)
    assert p.sampling == SamplingPolicy(kind="partition", n_parts=4, halo=1,
                                        grad_accum=2, shuffle=False)
    assert p.stash == StashPolicy(kind="arena", placement="host")
    assert p.offload == "host"
    assert p.precision == PrecisionPolicy(kind="autoprec", bit_budget=1.5,
                                          refresh=3)
    assert p.kernel == KernelPolicy(impl="interp")
    assert hash(p)  # plans ride as static jit arguments


def test_plan_validation():
    with pytest.raises(ValueError, match="offload"):
        StashPolicy(kind="arena", placement="hsot")
    with pytest.raises(ValueError, match="tensor"):
        StashPolicy(kind="tensor", placement="host")
    with pytest.raises(ValueError, match="bit_budget"):
        PrecisionPolicy(kind="autoprec")
    with pytest.raises(ValueError, match="impl"):
        KernelPolicy(impl="cuda")
    with pytest.raises(ValueError, match="n_parts"):
        SamplingPolicy(kind="full", n_parts=2)


# ------------------------------------------ legacy-loop trajectory parity
def _legacy_train_gnn(g, cfg, n_epochs, seed=0):
    """Verbatim reconstruction of the pre-engine ``train_gnn`` loop: the
    per-op autodiff stack (``_loss_fn`` with ``plan=None``), the inline
    ``(epoch+1)*7919`` seed, one ``value_and_grad`` update per epoch."""
    opt = AdamWConfig(lr=5e-3, weight_decay=0.0)
    params = init_gnn_params(jax.random.PRNGKey(seed), cfg, g.n_feats)
    state = adamw_init(params, opt)
    gt = graph_tuple(g)
    tr_mask = g.train_mask.astype(jnp.float32)

    @partial(jax.jit, donate_argnums=(0, 1))
    def step(params, state, epoch, gt, labels, tr_mask):
        sr_seed = (epoch + 1).astype(jnp.uint32) * jnp.uint32(7919)
        loss, grads = jax.value_and_grad(_loss_fn)(
            params, gt, labels, tr_mask, cfg, sr_seed)
        params, state = adamw_update(grads, state, params, opt)
        return params, state, loss

    losses = []
    for epoch in range(n_epochs):
        params, state, loss = step(params, state, jnp.asarray(epoch), gt,
                                   g.labels, tr_mask)
        losses.append(float(loss))
    return params, losses


@pytest.mark.parametrize("impl", ["jnp", "interp"])
def test_train_gnn_bit_identical_to_legacy_loop(g, impl):
    """The acceptance gate: the plan-routed wrapper's loss AND param
    trajectory equals the pre-refactor computation bit for bit."""
    cfg = _cfg(g).with_impl(impl)
    n = 3 if impl == "interp" else 5
    legacy_params, legacy_losses = _legacy_train_gnn(g, cfg, n)
    r = train_gnn(g, cfg, n_epochs=n, seed=0, verbose=True, eval_every=1)
    _tree_equal(legacy_params, r["params"])
    assert legacy_losses == [l for _, l, _ in r["history"]]


@pytest.mark.parametrize("offload", [None, "device", "host"])
def test_train_gnn_offload_bit_identical_to_legacy_loop(g, offload):
    """Offload on/off rides the same single forward: every policy's
    trajectory equals the per-op legacy loop exactly."""
    cfg = _cfg(g)
    legacy_params, legacy_losses = _legacy_train_gnn(g, cfg, 3)
    r = train_gnn(g, cfg, n_epochs=3, seed=0, offload=offload,
                  verbose=True, eval_every=1)
    _tree_equal(legacy_params, r["params"])
    assert legacy_losses == [l for _, l, _ in r["history"]]


def test_mixed_bits_bit_identical_to_legacy_loop(g):
    """Heterogeneous widths {1, 2, 4, 8} + an uncompressed layer through
    the engine == the legacy per-op loop, and arena == tensor."""
    cfg = GNNConfig(
        arch="sage", hidden=(32, 32, 32), n_classes=g.num_classes,
        compression=(dataclasses.replace(COMP, bits=1),
                     dataclasses.replace(COMP, bits=4),
                     None,
                     dataclasses.replace(COMP, bits=8)))
    legacy_params, _ = _legacy_train_gnn(g, cfg, 3)
    r_tensor = train_gnn(g, cfg, n_epochs=3, seed=0)
    r_arena = train_gnn(g, cfg, n_epochs=3, seed=0, offload="device")
    _tree_equal(legacy_params, r_tensor["params"])
    _tree_equal(legacy_params, r_arena["params"])


# -------------------------------------------------- wrapper == plan-routed
def test_train_gnn_equals_explicit_plan(g):
    cfg = _cfg(g)
    r_legacy = train_gnn(g, cfg, n_epochs=3, seed=0, offload="device",
                         impl="interp")
    from repro.engine import run
    plan = ExecutionPlan(stash=StashPolicy(kind="arena",
                                           placement="device"),
                         kernel=KernelPolicy(impl="interp"))
    r_plan = run(g, cfg, plan, n_epochs=3, seed=0)
    _tree_equal(r_legacy["params"], r_plan["params"])
    assert r_legacy["test_acc"] == r_plan["test_acc"]
    assert r_legacy["plan"] == plan


def test_train_gnn_batched_equals_explicit_plan(g):
    cfg = _cfg(g)
    r_legacy = train_gnn_batched(g, cfg, 4, n_epochs=2, seed=0,
                                 grad_accum=2, method="random")
    from repro.engine import run
    plan = ExecutionPlan(sampling=SamplingPolicy(
        kind="partition", n_parts=4, grad_accum=2, method="random"))
    r_plan = run(g, cfg, plan, n_epochs=2, seed=0)
    _tree_equal(r_legacy["params"], r_plan["params"])
    assert r_legacy["n_parts"] == r_plan["n_parts"] == 4
    assert r_legacy["updates_per_epoch"] == r_plan["updates_per_epoch"] == 2


# --------------------------------------- one forward, bit-equal gradients
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("impl", ["jnp", "interp"])
def test_unified_forward_grads_equal_per_op_autodiff(g, bits, impl):
    """The "exactly one stash-aware custom_vjp forward" criterion, stated
    semantically: for every width and kernel backend, the engine forward's
    manual backward (tensor AND arena policies) emits the gradients the
    per-op autodiff composition emitted pre-refactor — bit for bit."""
    from repro.engine.compile import engine_loss
    from repro.engine.forward import TENSOR_STASH, plan_gnn_stashes

    cfg = _cfg(g, comp=dataclasses.replace(COMP, bits=bits, impl=impl))
    params = init_gnn_params(jax.random.PRNGKey(1), cfg, g.n_feats)
    gt = graph_tuple(g)
    mask = g.train_mask.astype(jnp.float32)
    splan = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    seed = seeds.sr_seed(4)

    g_per_op = jax.jit(jax.grad(_loss_fn), static_argnums=(4,))(
        params, gt, g.labels, mask, cfg, seed)
    gfn = jax.jit(jax.grad(engine_loss), static_argnums=(4, 7, 8))
    g_tensor = gfn(params, gt, g.labels, mask, cfg, seed, None, splan,
                   TENSOR_STASH)
    g_arena = gfn(params, gt, g.labels, mask, cfg, seed, None, splan,
                  StashPolicy(kind="arena", placement="device"))
    _tree_equal(g_per_op, g_tensor)
    _tree_equal(g_per_op, g_arena)


# ------------------------------------------------------ report plan routing
def test_memory_report_takes_plan(g):
    from repro.graph import activation_memory_report

    cfg = _cfg(g, hidden=(32, 32))
    plan = ExecutionPlan.from_legacy(n_parts=4, offload="host")
    rep_plan = activation_memory_report(g, cfg, plan=plan)
    rep_legacy = activation_memory_report(g, cfg, n_parts=4, offload="host")
    # the two spellings build the same plan -> identical accounting
    assert rep_plan["batched"]["peak_saved_bytes"] == \
        rep_legacy["batched"]["peak_saved_bytes"]
    assert rep_plan["arena"] == rep_legacy["arena"]
    assert rep_plan["arena"]["policy"] == "host"
    # a tensor-stash full-graph plan reports neither section
    rep_plain = activation_memory_report(g, cfg, plan=ExecutionPlan())
    assert "batched" not in rep_plain and "arena" not in rep_plain


def test_autoprec_refresh_recompiles_plan(g):
    """The refresh is a plan-recompile hook: a budgeted run re-solves on
    cadence and reports its allocation; the result carries the plan."""
    cfg = _cfg(g, hidden=(32, 32))
    r = train_gnn(g, cfg, n_epochs=4, seed=0, bit_budget=2.0,
                  autoprec_refresh=2)
    assert r["plan"].precision == PrecisionPolicy(kind="autoprec",
                                                  bit_budget=2.0, refresh=2)
    assert len(r["bits_per_layer"]) == cfg.n_layers
    assert r["bit_budget_bytes"] > 0


# ----------------------------------------------------- fused kernel policy
def test_kernel_policy_fused_knob():
    assert KernelPolicy().fused == "auto"
    assert ExecutionPlan.from_legacy(fused="on").kernel == \
        KernelPolicy(impl=None, fused="on")
    assert "fused=on" in ExecutionPlan.from_legacy(fused="on").describe()
    with pytest.raises(ValueError, match="fused"):
        KernelPolicy(fused="always")


@pytest.mark.parametrize("impl", ["jnp", "interp"])
def test_engine_fused_on_bit_identical_trajectory(g, impl):
    """Tentpole gate: fused=on plans must produce bit-identical training
    trajectories (losses AND final params) to fused=off, on every impl.
    Needs a fused-eligible config: no RP, blocks aligned to the layer
    input widths (sage doubles the feature dims, all % 64 == 0 here)."""
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=0, impl=impl)
    cfg = _cfg(g, comp=comp)
    n = 2 if impl == "interp" else 3
    r_off = train_gnn(g, cfg, n_epochs=n, seed=0, fused="off")
    r_on = train_gnn(g, cfg, n_epochs=n, seed=0, fused="on")
    assert r_off["history"] == r_on["history"]
    _tree_equal(r_off["params"], r_on["params"])


def test_engine_fused_auto_default_unchanged(g):
    """fused='auto' (the default) must not change the CPU trajectory:
    routing only fuses on the real Pallas backend."""
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=0)
    cfg = _cfg(g, comp=comp)
    r_auto = train_gnn(g, cfg, n_epochs=2, seed=0)           # fused="auto"
    r_off = train_gnn(g, cfg, n_epochs=2, seed=0, fused="off")
    assert r_auto["history"] == r_off["history"]
    _tree_equal(r_auto["params"], r_off["params"])


def test_engine_fused_on_ineligible_raises(g):
    """fused='on' refuses configs the fused pair cannot run bit-exactly
    (RP projects before quantization) instead of silently narrowing."""
    cfg = _cfg(g)   # COMP has rp_ratio=8
    with pytest.raises(ValueError, match="rp_ratio"):
        train_gnn(g, cfg, n_epochs=1, seed=0, fused="on")
