"""Mini-batch subgraph engine tests: partition coverage, padding inertness
(zero gradient), n_parts=1 parity with the full-graph loop, batched memory
model, and kernel-backend parity of the batched path."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.graph import (GNNConfig, activation_memory_report,
                         bfs_partition, make_subgraph_batches,
                         random_partition, synthetic_graph, train_gnn,
                         train_gnn_batched)
from repro.graph.models import init_gnn_params
from repro.graph.train import _loss_fn
from repro.launch.mesh import make_local_mesh


@pytest.fixture(scope="module")
def g():
    return synthetic_graph("t", 700, 3500, 32, 5, homophily=0.5,
                           feature_noise=1.5, seed=1)


COMP = CompressionConfig(bits=2, group_size=64, rp_ratio=8)


def _cfg(g, comp=COMP, hidden=(32,)):
    return GNNConfig(arch="sage", hidden=hidden, n_classes=g.num_classes,
                     compression=comp)


# ------------------------------------------------------------- partitioner
def test_partitions_cover_and_balance(g):
    cap = math.ceil(g.n_nodes / 4)
    for part in (random_partition(g.n_nodes, 4, seed=0),
                 bfs_partition(g.edge_src, g.edge_dst, g.n_nodes, 4, seed=0)):
        assert part.shape == (g.n_nodes,)
        sizes = np.bincount(part, minlength=4)
        assert sizes.sum() == g.n_nodes
        assert sizes.max() <= cap and sizes.min() >= 1, sizes
    # uneven n/P must never yield an empty part (9 = 3+3+3+0 regression)
    for n, p in [(9, 4), (7, 3), (700, 6)]:
        sizes = np.bincount(random_partition(n, p, seed=0), minlength=p)
        assert sizes.min() >= n // p and sizes.max() <= -(-n // p), (n, p)


def test_batches_static_shapes_and_masks(g):
    batches = make_subgraph_batches(g, 3, method="bfs", seed=0)
    shapes = {(b.features.shape, b.edge_src.shape) for b in batches}
    assert len(shapes) == 1  # one static bucket -> scan traces once
    assert batches[0].n_nodes % 64 == 0 and batches[0].n_edges % 256 == 0
    # every real node appears exactly once (halo=0); masks partition cleanly
    assert sum(int(b.node_mask.sum()) for b in batches) == g.n_nodes
    assert (sum(int(b.train_mask.sum()) for b in batches)
            == int(g.train_mask.sum()))
    for b in batches:
        nl, el = int(b.n_real_nodes), int(b.n_real_edges)
        assert not np.any(np.asarray(b.features)[nl:])      # zero pad rows
        assert not np.any(np.asarray(b.gcn_weight)[el:])    # inert pad edges
        assert not np.any(np.asarray(b.mean_weight)[el:])
        # masks never mark padding
        assert not np.any(np.asarray(b.train_mask)[nl:])
        assert not np.any(np.asarray(b.node_mask)[nl:])


def test_halo_adds_context_nodes_without_loss_rows(g):
    plain = make_subgraph_batches(g, 4, method="bfs", seed=0)
    halo = make_subgraph_batches(g, 4, method="bfs", seed=0, halo=1)
    assert (sum(int(b.node_mask.sum()) for b in halo)
            > sum(int(b.node_mask.sum()) for b in plain))
    # halo rows aggregate but never contribute loss/metrics
    assert (sum(int(b.train_mask.sum()) for b in halo)
            == int(g.train_mask.sum()))


# ----------------------------------------------------- n_parts=1 parity
def test_nparts1_bit_parity_with_full_graph(g):
    """Tight padding (multiples of 1) makes the batched engine the identity
    refactor: same seeds, same update order -> bit-identical params."""
    cfg = _cfg(g)
    r_full = train_gnn(g, cfg, n_epochs=12, seed=0)
    r_b1 = train_gnn_batched(g, cfg, 1, n_epochs=12, seed=0,
                             node_multiple=1, edge_multiple=1)
    for a, b in zip(jax.tree.leaves(r_full["params"]),
                    jax.tree.leaves(r_b1["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert r_full["test_acc"] == r_b1["test_acc"]


def test_nparts1_padded_parity_within_tolerance(g):
    """With real padding the quantization block boundaries shift, so parity
    is statistical, not bit-level — accuracy must stay within tolerance."""
    cfg = _cfg(g)
    r_full = train_gnn(g, cfg, n_epochs=25, seed=0)
    r_b1 = train_gnn_batched(g, cfg, 1, n_epochs=25, seed=0,
                             node_multiple=64, edge_multiple=256)
    assert abs(r_full["val_acc"] - r_b1["val_acc"]) < 0.05
    assert abs(r_full["test_acc"] - r_b1["test_acc"]) < 0.05


# ------------------------------------------------- padding: zero gradient
def _batch_loss(params, b, cfg, seed):
    return _loss_fn(params, b.graph_tuple(), b.labels, b.train_mask, cfg,
                    jnp.uint32(seed), node_mask=b.node_mask)


def test_padding_contributes_zero_gradient(g):
    batches = make_subgraph_batches(g, 2, method="bfs", seed=0)
    b = batches[0]
    nl = int(b.n_real_nodes)
    assert nl < b.n_nodes  # the bucket actually padded

    # (a) uncompressed: loss AND param grads exactly invariant to garbage
    # planted in the padding rows (node_mask pins them to zero).
    cfg = _cfg(g, comp=None)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    garbage = b.features.at[nl:].set(1e3)
    b_dirty = dataclasses.replace(b, features=garbage)
    l0, g0 = jax.value_and_grad(_batch_loss)(params, b, cfg, 3)
    l1, g1 = jax.value_and_grad(_batch_loss)(params, b_dirty, cfg, 3)
    assert float(l0) == float(l1)
    for a, c in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))

    # (b) compressed path: d(loss)/d(features) is exactly zero on pad rows.
    cfg_c = _cfg(g)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg_c, g.n_feats)
    df = jax.grad(lambda f: _batch_loss(
        params, dataclasses.replace(b, features=f), cfg_c, 3))(b.features)
    assert not np.any(np.asarray(df)[nl:])


# --------------------------------------------------------- batched engine
def test_batched_training_learns(g):
    cfg = _cfg(g)
    r = train_gnn_batched(g, cfg, 4, n_epochs=25, seed=0)
    assert r["test_acc"] > 2.0 / g.num_classes, r["test_acc"]
    assert r["updates_per_epoch"] == 4


def test_batched_grad_accum_and_mesh(g):
    cfg = _cfg(g, comp=None)
    r = train_gnn_batched(g, cfg, 4, n_epochs=8, seed=0, grad_accum=2,
                          mesh=make_local_mesh())
    assert r["updates_per_epoch"] == 2
    assert np.isfinite(r["test_acc"])
    with pytest.raises(ValueError):
        train_gnn_batched(g, cfg, 3, n_epochs=1, grad_accum=2)


def test_batched_impl_parity(g):
    """Same codes on every kernel backend (PR 1 gate) => the batched engine
    trains identically under jnp and pallas-interp."""
    small = synthetic_graph("p", 256, 1200, 16, 4, seed=2)
    cfg = GNNConfig(arch="sage", hidden=(16,), n_classes=small.num_classes,
                    compression=COMP)
    rs = {impl: train_gnn_batched(small, cfg, 2, n_epochs=3, seed=0,
                                  impl=impl)
          for impl in ("jnp", "interp")}
    assert rs["jnp"]["test_acc"] == rs["interp"]["test_acc"]
    for a, b in zip(jax.tree.leaves(rs["jnp"]["params"]),
                    jax.tree.leaves(rs["interp"]["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


# ----------------------------------------------------------- memory model
def test_batched_memory_report(g):
    cfg = _cfg(g, hidden=(64, 64))
    rep = activation_memory_report(g, cfg, n_parts=4)
    # full-graph keys unchanged + per-layer rows sum to the totals
    assert rep["reduction"] > 0.9
    assert sum(r["fp32_bytes"] for r in rep["per_layer"]) == rep["fp32_bytes"]
    assert (sum(r["compressed_bytes"] for r in rep["per_layer"])
            == rep["compressed_bytes"])
    b = rep["batched"]
    # acceptance: peak saved bytes at n_parts>=4 is >=2x below full-graph
    assert b["peak_saved_bytes"] * 2 <= rep["compressed_bytes"]
    assert b["peak_reduction_vs_full"] >= 2.0
    # actual padded batches agree with the analytic default
    batches = make_subgraph_batches(g, 4, method="random", seed=0)
    rep2 = activation_memory_report(g, cfg, n_parts=4,
                                    batch_nodes=batches[0].n_nodes)
    assert rep2["batched"]["batch_nodes"] == batches[0].n_nodes
