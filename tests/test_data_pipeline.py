"""Deterministic data pipeline: restart-safe, elastic, host-partitioned."""
import numpy as np

from repro.data import batch_for_step


def test_deterministic():
    a = batch_for_step(1000, 8, 64, step=7)
    b = batch_for_step(1000, 8, 64, step=7)
    np.testing.assert_array_equal(a, b)


def test_steps_differ():
    a = batch_for_step(1000, 8, 64, step=7)
    b = batch_for_step(1000, 8, 64, step=8)
    assert not np.array_equal(a, b)


def test_host_partitioning():
    h0 = batch_for_step(1000, 8, 64, step=3, host_id=0, n_hosts=2)
    h1 = batch_for_step(1000, 8, 64, step=3, host_id=1, n_hosts=2)
    assert h0.shape == (4, 64) and h1.shape == (4, 64)
    assert not np.array_equal(h0, h1)


def test_tokens_in_vocab():
    t = batch_for_step(517, 4, 128, step=0)
    assert t.min() >= 0 and t.max() < 517
    assert t.dtype == np.int32
