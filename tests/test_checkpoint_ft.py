"""Checkpoint/restart + fault tolerance: atomicity, bitwise resume,
elastic reload, straggler detection."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.runtime import StragglerMonitor, TrainRunner


def _tree(key):
    k1, k2 = jax.random.split(key)
    return {"a": jax.random.normal(k1, (16, 8)),
            "nested": {"b": jax.random.normal(k2, (4,), jnp.bfloat16),
                       "step": jnp.asarray(3, jnp.int32)}}


def test_save_load_bitwise(tmp_path):
    tree = _tree(jax.random.PRNGKey(0))
    save_checkpoint(tmp_path, 5, tree)
    assert latest_step(tmp_path) == 5
    back = load_checkpoint(tmp_path, 5, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step_ignores_tmp(tmp_path):
    tree = _tree(jax.random.PRNGKey(1))
    save_checkpoint(tmp_path, 1, tree)
    (tmp_path / "step_9.tmp").mkdir()          # simulated crashed write
    assert latest_step(tmp_path) == 1


def test_async_save(tmp_path):
    tree = _tree(jax.random.PRNGKey(2))
    t = save_checkpoint(tmp_path, 2, tree, async_write=True)
    t.join()
    assert latest_step(tmp_path) == 2


def _runner(tmp_path, fail_at=None):
    def step_fn(state, batch):
        new = jax.tree.map(lambda x: x + batch, state)
        return new, {"loss": jnp.sum(new["a"])}

    def make_batch(step):
        return jnp.asarray(float(step + 1))

    return TrainRunner(step_fn, make_batch, tmp_path, ckpt_every=3,
                       async_ckpt=False, fail_at_step=fail_at)


def test_failure_and_bitwise_resume(tmp_path):
    """Kill at step 7, restart, final state identical to an unfailed run."""
    init = {"a": jnp.zeros((2, 2))}
    ref_state, _ = _runner(tmp_path / "ref").run(init, 10)

    r = _runner(tmp_path / "x", fail_at=7)
    with pytest.raises(RuntimeError, match="injected failure"):
        r.run(init, 10)
    assert latest_step(tmp_path / "x") == 6
    # restart: no injected failure this time
    state, hist = _runner(tmp_path / "x").run(init, 10)
    np.testing.assert_array_equal(np.asarray(state["a"]),
                                  np.asarray(ref_state["a"]))
    assert hist[0]["step"] == 6      # resumed, not restarted


def test_elastic_reload_with_shardings(tmp_path):
    """Checkpoints restore under a different device layout (1-dev mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    save_checkpoint(tmp_path, 1, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    shardings = {"w": NamedSharding(mesh, P("data", "model"))}
    back = load_checkpoint(tmp_path, 1, tree, shardings=shardings)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    assert back["w"].sharding == shardings["w"]


def test_straggler_monitor():
    mon = StragglerMonitor(alpha=0.5, threshold=2.0, warmup=1)
    flagged = []
    mon.callback = lambda s, dt, ew: flagged.append(s)
    for s in range(8):
        mon.record(s, 0.1)
    assert mon.record(8, 1.0) is True        # 10x the EWMA
    assert flagged == [8]
    # straggler must not poison the EWMA
    assert mon.ewma < 0.2
