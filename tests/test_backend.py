"""Kernel-backend dispatch parity gate.

The dispatch layer (``repro.core.backend``) may never silently diverge:
``impl="jnp"`` and ``impl="interp"`` must produce **bit-identical** packed
words — all bit-widths, uniform + VM level tables, ragged block counts that
exercise the row-padding path — and the whole training stack must run under
either backend from a single config flag.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig, backend, compress, decompress
from repro.graph import GNNConfig, synthetic_graph, train_gnn
from repro.kernels import ops

# static VM tables (handcrafted so the test doesn't pay level optimization)
VM_TABLES = {2: (0.0, 1.05, 1.95, 3.0),
             4: tuple(float(v) for v in
                      [0.0, 0.8, 1.9, 3.1, 4.2, 5.1, 6.0, 7.0, 8.0, 9.0,
                       10.1, 11.0, 12.2, 13.1, 14.05, 15.0])}


# ------------------------------------------------------------ kernel level
@pytest.mark.parametrize("n_blocks", [1, 7, 9])
@pytest.mark.parametrize("bits", [1, 2, 4, 8])
def test_ragged_blocks_bit_identical(n_blocks, bits):
    """Satellite: ragged n_blocks through the zero-row-padded kernel path
    must match the reference bit-for-bit (packed words, zero, rng)."""
    g = 64
    x = jax.random.normal(jax.random.PRNGKey(n_blocks * 31 + bits),
                          (n_blocks, g), jnp.float32) * 2.1 - 0.4
    pj, zj, rj = ops.quantize_packed(x, bits, 11, None, impl="jnp",
                                     rows_per_tile=8)
    pi, zi, ri = ops.quantize_packed(x, bits, 11, None, impl="interp",
                                     rows_per_tile=8)
    np.testing.assert_array_equal(np.asarray(pj), np.asarray(pi))
    np.testing.assert_allclose(np.asarray(zj), np.asarray(zi), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rj), np.asarray(ri), rtol=1e-6)
    dj = ops.dequantize_packed(pj, zj, rj, bits, g, None, impl="jnp")
    di = ops.dequantize_packed(pi, zi, ri, bits, g, None, impl="interp")
    np.testing.assert_allclose(np.asarray(dj), np.asarray(di), atol=1e-5)


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("n_blocks", [1, 7, 9])
def test_ragged_blocks_vm_levels_bit_identical(bits, n_blocks):
    lv = VM_TABLES[bits]
    x = jax.random.normal(jax.random.PRNGKey(bits + n_blocks), (n_blocks, 64))
    pj, zj, rj = ops.quantize_packed(x, bits, 5, lv, impl="jnp")
    pi, zi, ri = ops.quantize_packed(x, bits, 5, lv, impl="interp")
    np.testing.assert_array_equal(np.asarray(pj), np.asarray(pi))
    dj = ops.dequantize_packed(pj, zj, rj, bits, 64, lv, impl="jnp")
    di = ops.dequantize_packed(pi, zi, ri, bits, 64, lv, impl="interp")
    np.testing.assert_allclose(np.asarray(dj), np.asarray(di), atol=1e-5)


def test_traced_level_table_rejected():
    """VM tables must reach pallas_call as static tuples, never tracers."""
    x = jnp.ones((4, 64))

    def f(lv):
        return ops.quantize_packed(x, 2, 0, lv, impl="jnp")

    with pytest.raises(TypeError, match="static"):
        jax.jit(f)(jnp.asarray([0.0, 1.0, 2.0, 3.0]))


# ------------------------------------------------------- compressor level
@pytest.mark.parametrize("cfg", [
    CompressionConfig(bits=2, group_size=64),
    CompressionConfig(bits=2, group_size=64, vm=True),
    CompressionConfig(bits=4, group_size=96),
    CompressionConfig(bits=8, group_size=128),
    CompressionConfig(bits=2, group_size=64, rp_ratio=4),
], ids=["int2", "int2_vm", "int4", "int8", "int2_rp"])
@pytest.mark.parametrize("shape", [(13, 100), (9, 64), (3, 5, 40)],
                         ids=["ragged_tail", "aligned", "rank3"])
def test_compress_parity_public_api(cfg, shape):
    """The acceptance gate: a single impl flag flips the whole public
    compressor between reference and fused kernels with bit-identical
    ``CompressedTensor.packed`` words."""
    if cfg.rp_ratio > 1 and shape[-1] % cfg.rp_ratio:
        shape = (*shape[:-1], shape[-1] - shape[-1] % cfg.rp_ratio + cfg.rp_ratio)
    x = jax.random.normal(jax.random.PRNGKey(hash(shape) % 2**31), shape) * 1.7
    ca = compress(x, cfg, 3, impl="jnp")
    cb = compress(x, cfg, 3, impl="interp")
    assert ca.impl == "jnp" and cb.impl == "interp"
    np.testing.assert_array_equal(np.asarray(ca.packed), np.asarray(cb.packed))
    np.testing.assert_allclose(np.asarray(ca.zero), np.asarray(cb.zero),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(ca.rng), np.asarray(cb.rng),
                               rtol=1e-6)
    da, db = decompress(ca), decompress(cb)
    assert da.shape == x.shape == db.shape
    np.testing.assert_allclose(np.asarray(da), np.asarray(db), atol=1e-5)


def test_tail_block_stats_not_contaminated():
    """The flat tail is replicate-padded: the last real block's (zero, range)
    must come from its actual elements — zero-padding would widen them."""
    x = jnp.asarray(np.full(100, 5.0, np.float32))  # 100 = 64 + 36 tail
    for impl in ("jnp", "interp"):
        ct = compress(x, CompressionConfig(bits=2, group_size=64), 0,
                      impl=impl)
        # constant input: every stored range must be exactly 0, and the
        # reconstruction exact — impossible if zeros entered the tail block
        np.testing.assert_array_equal(np.asarray(ct.rng), 0.0)
        np.testing.assert_allclose(np.asarray(decompress(ct)), 5.0,
                                   rtol=1e-6)


def test_compressed_tensor_carries_impl_through_pytree():
    """Round-trip under flatten/unflatten (scan carries, checkpoints)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    ct = compress(x, CompressionConfig(bits=2, group_size=64), 0,
                  impl="interp")
    leaves, treedef = jax.tree_util.tree_flatten(ct)
    ct2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert ct2.impl == "interp"
    np.testing.assert_allclose(np.asarray(decompress(ct2)),
                               np.asarray(decompress(ct)), atol=1e-6)


def test_pallas_written_tensor_decompresses_on_cpu():
    """A checkpoint written with impl="pallas" on TPU must restore on a
    host without TPU: the recorded impl is downgraded through 'auto'."""
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    ct = compress(x, CompressionConfig(bits=2, group_size=64), 0, impl="jnp")
    ct_tpu = dataclasses.replace(ct, impl="pallas")
    out = decompress(ct_tpu)  # would fail to lower if taken literally on CPU
    np.testing.assert_allclose(np.asarray(out), np.asarray(decompress(ct)),
                               atol=1e-6)


def test_use_impl_override_wins():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    cfg = CompressionConfig(bits=2, group_size=64, impl="jnp")
    with backend.use_impl("interp"):
        ct = compress(x, cfg, 0)
    assert ct.impl == "interp"
    assert backend.current_override() is None


def test_explicit_kernel_impl_raises_on_unsupported():
    """Explicit kernel impls are strict; only 'auto' falls back to jnp."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 50))
    with pytest.raises(ValueError, match="cannot run"):
        compress(x, CompressionConfig(bits=2, group_size=50), 0,
                 impl="interp")
    # auto quietly routes the same config to the reference path
    ct = compress(x, CompressionConfig(bits=2, group_size=50), 0)
    assert ct.impl == "jnp"
    assert jnp.isfinite(decompress(ct)).all()


def test_compressor_does_not_bypass_dispatch():
    """compress/decompress must route everything through core.backend —
    no direct quant/pack imports left in the orchestrator."""
    import ast
    import inspect

    from repro.core import compressor

    tree = ast.parse(inspect.getsource(compressor))
    imported = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom):
            imported.add(node.module or "")
            imported.update(f"{node.module}.{a.name}" for a in node.names)
        elif isinstance(node, ast.Import):
            imported.update(a.name for a in node.names)
    banned = {"repro.core.quant", "repro.core.pack",
              "repro.core.random_projection", "repro.core.prng"}
    hits = {i for i in imported if any(i.startswith(b) for b in banned)}
    assert not hits, f"compressor bypasses the dispatch layer: {hits}"


def test_mixed_bit_stash_roundtrip_parity():
    """Satellite: a stash written with per-layer bits {1, 2, 4, 8} (the
    autoprec output) must round-trip across ``impl in {jnp, interp}`` with
    bit-identical packed words and bit-identical reconstructions."""
    base = CompressionConfig(bits=2, group_size=64)
    cfg = GNNConfig(hidden=(32, 32, 32), compression=base)
    per = cfg.with_layer_bits((1, 2, 4, 8)).layer_compression()
    assert [c.bits for c in per] == [1, 2, 4, 8]
    for li, comp in enumerate(per):
        x = jax.random.normal(jax.random.PRNGKey(li), (9, 64)) * (li + 1.3)
        ca = compress(x, comp, li * 1013, impl="jnp")
        cb = compress(x, comp, li * 1013, impl="interp")
        np.testing.assert_array_equal(np.asarray(ca.packed),
                                      np.asarray(cb.packed))
        # same stash through either dequant impl: equal codes, float math
        # agrees to fusion order (XLA may fma one path)
        for writer in (ca, cb):
            dj = decompress(writer, impl="jnp")
            di = decompress(writer, impl="interp")
            np.testing.assert_allclose(np.asarray(dj), np.asarray(di),
                                       atol=1e-5)
        # cross-writer on one dequant impl is bit-exact: identical packed
        # words in, identical reconstruction out
        for impl in ("jnp", "interp"):
            np.testing.assert_array_equal(
                np.asarray(decompress(ca, impl=impl)),
                np.asarray(decompress(cb, impl=impl)))


# ---------------------------------------------------------- training level
@pytest.mark.parametrize("impl", ["jnp", "interp"])
def test_train_gnn_end_to_end_under_both_backends(impl):
    g = synthetic_graph("backend-test", 256, 1200, 32, 4, homophily=0.6,
                        feature_noise=1.0, seed=3)
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=CompressionConfig(bits=2, group_size=64,
                                                  rp_ratio=8))
    r = train_gnn(g, cfg, n_epochs=3, seed=0, verbose=True, impl=impl)
    assert np.isfinite(r["test_acc"])
    assert all(np.isfinite(loss) for _, loss, _ in r["history"])


def test_gnn_config_with_impl():
    comp = CompressionConfig(bits=2, group_size=64)
    cfg = GNNConfig(compression=comp)
    assert cfg.with_impl("interp").compression.impl == "interp"
    assert dataclasses.replace(cfg, compression=None).with_impl(
        "interp").compression is None


# ------------------------------------------------------------ fused matmul
def _fused_case(m, d, bits, seed=0):
    n = 24
    x = jax.random.normal(jax.random.PRNGKey(seed), (m, d),
                          jnp.float32) * 2.1 - 0.4
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (d, n), jnp.float32)
    gy = jax.random.normal(jax.random.PRNGKey(seed + 2), (m, n), jnp.float32)
    return x, w, gy


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("m,d,g", [(96, 64, 64),   # aligned, D % G == 0
                                   (9, 64, 64),    # ragged M (padded rows)
                                   (10, 32, 64),   # G % D == 0 (2 rows/blk)
                                   (100, 64, 32)])
def test_fused_fwd_bit_identical_to_unfused(bits, m, d, g):
    """Tentpole gate: the fused forward's stash triplet AND the matmul
    output are bit-identical to the unfused reference, on both kernel
    spellings, including the zero-row-padded ragged-M path."""
    x, w, _ = _fused_case(m, d, bits, seed=m + bits)
    assert backend.supports_fused((m, d), bits, g)
    y_ref = x @ w
    pr, zr, rr = ops.quantize_packed(x.reshape(-1, g), bits, 7, None,
                                     impl="jnp")
    for impl in ("jnp", "interp"):
        y, p, z, r = ops.matmul_quantize_packed(x, w, bits, 7, None,
                                                impl=impl, group_size=g)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
        np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))
        np.testing.assert_array_equal(np.asarray(z), np.asarray(zr))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rr))


@pytest.mark.parametrize("bits", [2, 4])
@pytest.mark.parametrize("m", [64, 9])
def test_fused_fwd_vm_levels_bit_identical(bits, m):
    lv = VM_TABLES[bits]
    x, w, _ = _fused_case(m, 64, bits, seed=m)
    pr, zr, rr = ops.quantize_packed(x.reshape(-1, 64), bits, 5, lv,
                                     impl="jnp")
    for impl in ("jnp", "interp"):
        y, p, z, r = ops.matmul_quantize_packed(x, w, bits, 5, lv,
                                                impl=impl, group_size=64)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x @ w))
        np.testing.assert_array_equal(np.asarray(p), np.asarray(pr))


@pytest.mark.parametrize("bits", [1, 2, 4, 8])
@pytest.mark.parametrize("m,d,g", [(96, 64, 64), (9, 64, 64), (100, 64, 32)])
def test_fused_bwd_bit_identical_to_unfused(bits, m, d, g):
    """The fused backward (dequantize in the matmul prologue) must equal
    the unfused dequantize -> x̂ᵀ@g spelling bit-for-bit *per impl* (the
    repo-wide contract: packed words are cross-impl bit-exact, float
    reconstruction is per-impl)."""
    x, w, gy = _fused_case(m, d, bits, seed=m * 3 + bits)
    p, z, r = ops.quantize_packed(x.reshape(-1, g), bits, 7, None,
                                  impl="jnp")
    for impl in ("jnp", "interp"):
        x_hat = ops.dequantize_packed(p, z, r, bits, g, None, impl=impl)
        dw_ref = x_hat.reshape(m, d).T @ gy
        dw = ops.dequant_matmul_packed(p, z, r, gy, bits, g, d, None,
                                       impl=impl)
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))


@pytest.mark.parametrize("bits", [2, 4])
def test_fused_bwd_vm_levels_bit_identical(bits):
    lv = VM_TABLES[bits]
    m, d, g = 64, 48, 16
    x, w, gy = _fused_case(m, d, bits, seed=bits)
    p, z, r = ops.quantize_packed(x.reshape(-1, g), bits, 5, lv, impl="jnp")
    for impl in ("jnp", "interp"):
        x_hat = ops.dequantize_packed(p, z, r, bits, g, lv, impl=impl)
        dw_ref = x_hat.reshape(m, d).T @ gy
        dw = ops.dequant_matmul_packed(p, z, r, gy, bits, g, d, lv,
                                       impl=impl)
        np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))


def test_supports_fused_predicate():
    """Satellite: the single eligibility predicate used by dispatch,
    engine, benchmarks, and tests."""
    assert backend.supports_fused((96, 64), 2, 64)        # D % G == 0
    assert backend.supports_fused((10, 32), 2, 64)        # G % D == 0
    assert backend.supports_fused((100, 64), 8, 32)
    # not 2-D
    assert not backend.supports_fused((4, 8, 16), 2, 64)
    # blocks straddle rows without dividing evenly
    assert not backend.supports_fused((96, 96), 2, 64)
    # ragged tail: element count not whole blocks
    assert not backend.supports_fused((9, 100), 2, 64)
    # base quant-kernel constraints still apply
    assert not backend.supports_fused((96, 64), 3, 64)    # bits !| 32
    assert not backend.supports_fused(
        (96, 64), 8, 64, tuple(float(i) for i in range(17)))  # VM > 16
    # the reason string names the failure
    assert "straddle" in backend.fused_unsupported((96, 96), 2, 64)


def test_route_fused_modes():
    shape, bits, g = (96, 64), 2, 64
    # off: never
    assert backend.route_fused("off", "jnp", shape, bits, g) is None
    # auto: only on the real kernel backend — on this CPU host "auto"
    # resolves to jnp, so no fusion (default paths unchanged)
    assert backend.route_fused("auto", "auto", shape, bits, g) is None
    assert backend.route_fused("auto", "interp", shape, bits, g) is None
    # on: forces the fused pair on whatever impl resolves to
    assert backend.route_fused("on", "jnp", shape, bits, g) == "jnp"
    assert backend.route_fused("on", "interp", shape, bits, g) == "interp"
    # on + ineligible raises instead of silently narrowing
    with pytest.raises(ValueError, match="straddle"):
        backend.route_fused("on", "jnp", (96, 96), bits, g)
    with pytest.raises(ValueError, match="rp_ratio"):
        backend.route_fused("on", "jnp", shape, bits, g, rp_ratio=8)
    # auto + rp quietly declines
    assert backend.route_fused("auto", "jnp", shape, bits, g,
                               rp_ratio=8) is None
    with pytest.raises(ValueError, match="fused"):
        backend.route_fused("maybe", "jnp", shape, bits, g)


@pytest.mark.parametrize("impl", ["jnp", "interp"])
def test_compress_matmul_orchestrators_parity(impl):
    """Public orchestrator gate: compress_matmul/decompress_matmul with
    fused='on' are bit-identical to the unfused compress + matmul /
    decompress + matmul spellings per impl, and ride the CompressedTensor
    pytree unchanged."""
    from repro.core import compress_matmul, decompress_matmul

    cfg = CompressionConfig(bits=2, group_size=64, impl=impl)
    x, w, gy = _fused_case(96, 64, 2, seed=17)
    ct_ref = compress(x, cfg, 7)
    y_ref = x @ w
    y, ct = compress_matmul(x, w, cfg, 7, fused="on")
    np.testing.assert_array_equal(np.asarray(y), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(ct.packed),
                                  np.asarray(ct_ref.packed))
    assert ct.shape == ct_ref.shape and ct.cfg == ct_ref.cfg
    dw_ref = decompress(ct_ref, impl=impl).reshape(96, 64).T @ gy
    dw = decompress_matmul(ct, gy, impl=impl, fused="on")
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw_ref))
    # fused="auto" on a CPU host falls back to the unfused spelling but
    # still returns the identical (y, ct) pair
    y2, ct2 = compress_matmul(x, w, cfg, 7, fused="auto")
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(y_ref))
    np.testing.assert_array_equal(np.asarray(ct2.packed),
                                  np.asarray(ct_ref.packed))
