"""Mesh-sharded partition-parallel training gates (ISSUE 7).

The multi-device tests need real (forced-host) devices:
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` **before jax
initializes** — CI runs this file in a dedicated step with that env; a
plain local ``pytest`` run skips them (device_count == 1).  The
single-device gates — 1-partition mesh ≡ ``train_gnn`` and m=1 mesh ≡
the batched engine, both bit-identical — always run.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.act_compress import CompressionConfig
from repro.engine.plan import (ExecutionPlan, KernelPolicy, SamplingPolicy,
                               StashPolicy)
from repro.engine.runner import run
from repro.graph.data import (cora_like, papers100m_like,
                              stream_edge_chunks)
from repro.graph.models import GNNConfig
from repro.graph.train import train_gnn, train_gnn_batched
from repro.parallel.halo import (build_halo_program, exchange_widths,
                                 halo_bytes_per_epoch, halo_exchange)

INT2 = CompressionConfig(bits=2, group_size=32)


def _mesh_plan(n_parts, **kw):
    return ExecutionPlan(sampling=SamplingPolicy(kind="mesh",
                                                 n_parts=n_parts, **kw))


def _assert_params_equal(a, b):
    for (pa, pb) in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# ------------------------------------------------------ halo program
def test_halo_program_static_shapes_and_edge_conservation():
    g = cora_like(0.5)
    prog = build_halo_program(g, 4, 2, method="bfs", seed=0)
    assert prog.rounds == 2 and prog.group == 2
    m, H = prog.group, prog.halo
    assert prog.features.shape == (2, m, prog.n_pad, g.n_feats)
    assert prog.edge_src.shape == (2, m, prog.e_pad)
    assert prog.send_idx.shape == (2, m, m, H)
    # every edge is accounted for exactly once: kept per partition + dropped
    assert int(prog.n_real_edges.sum()) + prog.dropped_edges == g.n_edges
    # local sources index the partition block, remote ones the halo strip
    for r in range(prog.rounds):
        for j in range(m):
            el = int(prog.n_real_edges[r, j])
            es = prog.edge_src[r, j, :el]
            assert es.min() >= 0 and es.max() < prog.n_pad + m * H
            # send maps address owned (padded) rows only
            assert prog.send_idx[r].min() >= 0
            assert prog.send_idx[r].max() < prog.n_pad
    # m == n_parts drops nothing (exact full-graph distribution)
    prog_full = build_halo_program(g, 4, 4, method="bfs", seed=0)
    assert prog_full.dropped_edges == 0
    assert int(prog_full.n_real_edges.sum()) == g.n_edges


def test_halo_program_rejects_indivisible_group():
    g = cora_like(0.25)
    with pytest.raises(ValueError, match="multiple"):
        build_halo_program(g, 3, 2)


def test_exchange_widths_and_bytes():
    dims = [128, 64, 32, 7]
    assert exchange_widths("gcn", dims) == (64, 32, 7)
    assert exchange_widths("sage", dims) == (128, 64, 32)
    g = cora_like(0.5)
    prog = build_halo_program(g, 4, 2)
    b = halo_bytes_per_epoch(prog, (64, 7))
    assert b == prog.rounds * 2 * 2 * prog.halo * 4 * 71
    prog1 = build_halo_program(g, 2, 1)
    assert prog1.halo == 0  # m == 1: no in-round peers
    assert halo_bytes_per_epoch(prog1, (64, 7)) == 0


# ------------------------------------------------- halo exchange (mesh)
@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_halo_exchange_round_trip_exact():
    """all_to_all semantics vs a numpy gather reference: on device j the
    halo strip slot (i, s) holds h_i[send_idx_i[j, s]] exactly."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import NamedSharding, PartitionSpec as P

    m, n_loc, H, F = 4, 16, 3, 8
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:m]), ("graph",))
    rng = np.random.default_rng(0)
    h = rng.normal(0, 1, (m, n_loc, F)).astype(np.float32)
    send = rng.integers(0, n_loc, (m, m, H)).astype(np.int32)

    fn = shard_map(
        lambda hh, ss: halo_exchange(hh[0], ss[0], "graph")[None],
        mesh=mesh, in_specs=(P("graph"), P("graph")),
        out_specs=P("graph"), check_rep=False)
    out = np.asarray(fn(jnp.asarray(h), jnp.asarray(send)))
    assert out.shape == (m, n_loc + m * H, F)
    for j in range(m):
        np.testing.assert_array_equal(out[j, :n_loc], h[j])
        for i in range(m):
            ref = h[i][send[i, j]]          # what i ships to j
            np.testing.assert_array_equal(
                out[j, n_loc + i * H:n_loc + (i + 1) * H], ref)


def test_halo_exchange_identities():
    h = jnp.arange(24, dtype=jnp.float32).reshape(6, 4)
    assert halo_exchange(h, jnp.zeros((4, 0), jnp.int32), "graph") is h
    assert halo_exchange(h, jnp.zeros((1, 3), jnp.int32), "graph") is h
    assert halo_exchange(h, jnp.zeros((4, 3), jnp.int32), None) is h


# ------------------------------------------------------- parity gates
def test_mesh_1_partition_bit_identical_to_full_graph():
    """Gate (a): SamplingPolicy(kind='mesh', n_parts=1) with exact padding
    reproduces train_gnn bit-for-bit — compression on."""
    g = cora_like(0.5)
    cfg = GNNConfig(hidden=(64,), n_classes=g.num_classes,
                    compression=INT2)
    ref = train_gnn(g, cfg, n_epochs=5, seed=0)
    res = run(g, cfg, _mesh_plan(1, node_multiple=1, edge_multiple=1),
              n_epochs=5, seed=0)
    _assert_params_equal(res["params"], ref["params"])
    assert res["mesh_devices"] == 1
    assert res["halo_bytes_per_epoch"] == 0


@pytest.mark.parametrize("arch", ["gcn", "sage"])
def test_mesh_m1_bit_identical_to_batched(arch):
    """Gate (b): a k-partition mesh on ONE device (m=1, k rounds) is the
    batched engine with n_parts=k, shuffle=False — bit-identical."""
    g = cora_like(0.5)
    cfg = GNNConfig(hidden=(32,), n_classes=g.num_classes, arch=arch,
                    compression=INT2)
    ref = train_gnn_batched(g, cfg, n_parts=3, n_epochs=4, seed=0,
                            shuffle=False)
    if jax.device_count() > 1:
        # pin the mesh to one device so the gate tests the m=1 lowering
        # even under the forced-8-device CI env
        mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("graph",))
    else:
        mesh = None
    res = run(g, cfg, _mesh_plan(3), n_epochs=4, seed=0, mesh=mesh)
    _assert_params_equal(res["params"], ref["params"])
    assert res["updates_per_epoch"] == 3


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_mesh_full_width_matches_full_graph_fp32():
    """m == n_parts keeps every edge: exact distributed full-graph
    training, numerically close to the single-device run (collective /
    scatter orders differ, so float tolerance, not bits)."""
    g = cora_like(0.5)
    cfg = GNNConfig(hidden=(64,), n_classes=g.num_classes,
                    compression=None)
    ref = train_gnn(g, cfg, n_epochs=4, seed=0)
    res = run(g, cfg, _mesh_plan(4), n_epochs=4, seed=0)
    assert res["mesh_devices"] == 4
    assert res["dropped_edges"] == 0
    assert res["halo_width"] > 0
    for (pa, pb) in zip(jax.tree.leaves(res["params"]),
                        jax.tree.leaves(ref["params"])):
        np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                                   rtol=2e-4, atol=2e-5)


@pytest.mark.skipif(jax.device_count() < 4,
                    reason="needs XLA_FLAGS=--xla_force_host_platform_"
                           "device_count=8")
def test_mesh_compressed_trains_and_pages():
    """Compressed multi-round mesh run: 8 partitions on 4 devices, INT2,
    feature pager active — trains to a sane accuracy, pager overlaps."""
    g = cora_like(0.5)
    cfg = GNNConfig(hidden=(32,), n_classes=g.num_classes,
                    compression=INT2)
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:4]), ("graph",))
    res = run(g, cfg, _mesh_plan(8), n_epochs=15, seed=0, mesh=mesh)
    assert res["updates_per_epoch"] == 2
    assert res["test_acc"] > 0.8
    st = res["pager"]
    assert st["prefetch_hits"] == st["fetches"]
    assert st["host_bytes"] >= st["round_bytes"] * 2


# --------------------------------------------------- per-device memory
def test_mesh_per_device_stash_ledger_at_least_2x_smaller():
    """The ISSUE 7 acceptance gate, on the deterministic ledger: a
    4-partition mesh's per-device stash plan is >= 2x below the
    single-device full-graph plan at the same compression config."""
    from repro.engine.forward import mesh_stash_plan, plan_gnn_stashes

    g = papers100m_like(2e-5)
    cfg = GNNConfig(hidden=(128,), n_classes=g.num_classes,
                    compression=INT2)
    full = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    prog = build_halo_program(g, 4, 4)
    mesh = mesh_stash_plan(cfg, g.n_feats, prog.n_pad)
    ratio = full.total_bytes / mesh.total_bytes
    assert ratio >= 2.0, ratio


# ----------------------------------------------------- plan validation
def test_mesh_plan_validation():
    with pytest.raises(ValueError, match="grad_accum"):
        SamplingPolicy(kind="mesh", n_parts=4, grad_accum=2)
    with pytest.raises(ValueError, match="structural"):
        SamplingPolicy(kind="mesh", n_parts=4, halo=1)
    with pytest.raises(ValueError, match="renormalize"):
        SamplingPolicy(kind="mesh", n_parts=4, renormalize=True)

    g = cora_like(0.25)
    cfg = GNNConfig(hidden=(16,), n_classes=g.num_classes,
                    compression=INT2)
    with pytest.raises(ValueError, match="host-resident"):
        run(g, cfg, ExecutionPlan(
            sampling=SamplingPolicy(kind="mesh", n_parts=2),
            stash=StashPolicy(kind="arena", placement="device")),
            n_epochs=1)
    with pytest.raises(ValueError, match="fused"):
        run(g, cfg, ExecutionPlan(
            sampling=SamplingPolicy(kind="mesh", n_parts=2),
            kernel=KernelPolicy(fused="on")), n_epochs=1)


# ------------------------------------------------------ feature pager
def test_feature_pager_round_trip_and_stats():
    from repro.offload.pager import FeaturePager

    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("graph",))
    rng = np.random.default_rng(0)
    feats = rng.normal(0, 1, (3, 1, 100, 16)).astype(np.float32)
    pager = FeaturePager(feats, mesh, page_rows=32)
    assert pager.n_pages == 4  # ceil(100 / 32)
    pager.prefetch(0)
    for r in range(3):
        got = np.asarray(pager.fetch(r))
        np.testing.assert_array_equal(got, feats[r])
        pager.prefetch((r + 1) % 3)
    st = pager.stats()
    assert st["fetches"] == 3
    assert st["prefetch_hits"] >= 1
    assert st["host_bytes"] == feats.nbytes
    assert 0.0 <= st["overlap_frac"] <= 1.0


# -------------------------------------------- streaming graph generator
def test_stream_edge_chunks_shapes_and_budget():
    n, e = 5000, 1 << 16
    labs = np.random.default_rng(0).integers(0, 7, n)
    tot = 0
    for src, dst in stream_edge_chunks(n, e, labels=labs, homophily=0.5,
                                       seed=3, chunk_edges=1 << 13):
        assert src.shape == dst.shape and src.ndim == 1
        assert len(src) <= 1 << 13          # O(chunk) host memory
        assert src.min() >= 0 and src.max() < n
        assert dst.min() >= 0 and dst.max() < n
        assert np.all(src != dst)           # self loops filtered
        tot += len(src)
    assert 0.95 * e < tot <= e
    # deterministic across invocations
    a = list(stream_edge_chunks(n, 1 << 14, seed=9))
    b = list(stream_edge_chunks(n, 1 << 14, seed=9))
    for (s1, d1), (s2, d2) in zip(a, b):
        np.testing.assert_array_equal(s1, s2)
        np.testing.assert_array_equal(d1, d2)


def test_stream_edge_chunks_degree_skew():
    """dst ~ floor(N·u²) puts P(dst < N/100) = sqrt(1/100) = 10% of the
    mass on the first percentile of nodes — uniform would be 1%."""
    n = 10_000
    dsts = np.concatenate([d for _, d in stream_edge_chunks(n, 1 << 17,
                                                            seed=1)])
    frac = float(np.mean(dsts < n // 100))
    assert frac > 0.05, frac


def test_papers100m_like_invariants():
    g = papers100m_like(2e-5)
    assert g.n_nodes == 4096 and g.n_feats == 128 and g.num_classes == 172
    assert g.n_edges >= 8 * g.n_nodes
    mw = np.asarray(g.mean_weight)
    dd = np.asarray(g.edge_dst)
    sums = np.bincount(dd, weights=mw, minlength=g.n_nodes)
    np.testing.assert_allclose(sums, 1.0, rtol=1e-5)
