"""Unit tests for the loop-aware HLO analyzer (the roofline's foundation)."""
import textwrap

from repro.launch.hlo_analysis import analyze, parse_computations

SIMPLE = textwrap.dedent("""\
    HloModule test

    ENTRY %main (p0: f32[8,16], p1: f32[16,32]) -> f32[8,32] {
      %p0 = f32[8,16]{1,0} parameter(0)
      %p1 = f32[16,32]{1,0} parameter(1)
      ROOT %dot.1 = f32[8,32]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
    }
    """)


def test_simple_dot_flops():
    r = analyze(SIMPLE)
    assert r["flops"] == 2 * 8 * 32 * 16


LOOPED = textwrap.dedent("""\
    HloModule looped

    %cond (param: (s32[], f32[8,16])) -> pred[] {
      %param = (s32[], f32[8,16]) parameter(0)
      %gte = s32[] get-tuple-element(%param), index=0
      %constant.5 = s32[] constant(12)
      ROOT %lt = pred[] compare(%gte, %constant.5), direction=LT
    }

    %body (param.1: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %param.1 = (s32[], f32[8,16]) parameter(0)
      %gte.1 = s32[] get-tuple-element(%param.1), index=0
      %gte.2 = f32[8,16]{1,0} get-tuple-element(%param.1), index=1
      %w = f32[16,16]{1,0} constant({...})
      %dot.2 = f32[8,16]{1,0} dot(%gte.2, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,16]{1,0} all-reduce(%dot.2), replica_groups=[16,16]<=[256], to_apply=%add
      %one = s32[] constant(1)
      %next = s32[] add(%gte.1, %one)
      ROOT %tup = (s32[], f32[8,16]) tuple(%next, %ar)
    }

    %add (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (init: (s32[], f32[8,16])) -> (s32[], f32[8,16]) {
      %init = (s32[], f32[8,16]) parameter(0)
      ROOT %while.1 = (s32[], f32[8,16]) while(%init), condition=%cond, body=%body
    }
    """)


def test_while_trip_multiplies_flops_and_collectives():
    r = analyze(LOOPED, n_devices=256)
    assert r["flops"] == 12 * (2 * 8 * 16 * 16)
    # all-reduce wire bytes: 2*(g-1)/g * result, g=16, x12 trips
    expected = 12 * 2 * (15 / 16) * (8 * 16 * 4)
    assert abs(r["coll"]["all-reduce"] - expected) < 1e-6
    assert r["coll_total"] == r["coll"]["all-reduce"]


def test_parse_computations_structure():
    comps, entry = parse_computations(LOOPED)
    assert entry == "main"
    assert {"cond", "body", "add", "main"} <= set(comps)
    body = comps["body"]
    assert any(i.op == "dot" for i in body.instrs)


def test_scan_stacked_buffer_charged_per_slice():
    hlo = textwrap.dedent("""\
        HloModule stacked

        %cond (p: (s32[], f32[40,8,16])) -> pred[] {
          %p = (s32[], f32[40,8,16]) parameter(0)
          %g = s32[] get-tuple-element(%p), index=0
          %c = s32[] constant(40)
          ROOT %lt = pred[] compare(%g, %c), direction=LT
        }

        %body (p.1: (s32[], f32[40,8,16])) -> (s32[], f32[40,8,16]) {
          %p.1 = (s32[], f32[40,8,16]) parameter(0)
          %g.1 = s32[] get-tuple-element(%p.1), index=0
          %xs = f32[40,8,16]{2,1,0} get-tuple-element(%p.1), index=1
          %neg = f32[40,8,16]{2,1,0} negate(%xs)
          %one = s32[] constant(1)
          %nx = s32[] add(%g.1, %one)
          ROOT %t = (s32[], f32[40,8,16]) tuple(%nx, %neg)
        }

        ENTRY %main (i: (s32[], f32[40,8,16])) -> (s32[], f32[40,8,16]) {
          %i = (s32[], f32[40,8,16]) parameter(0)
          ROOT %w = (s32[], f32[40,8,16]) while(%i), condition=%cond, body=%body
        }
        """)
    r = analyze(hlo)
    # negate touches (operand+result) one slice (8,16) per iteration, x40:
    # equals touching the full stacked array (operand+result) once, plus
    # 12 B/iter of scalar induction-variable traffic
    full = 2 * 40 * 8 * 16 * 4
    assert full <= r["hbm"] <= full + 40 * 16, r["hbm"]
