"""custom_vjp compressed-training primitives."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig
from repro.core.act_compress import (compressed_block, compressed_elementwise,
                                     compressed_linear, compressed_matmul)

CFG = CompressionConfig(bits=2, group_size=64)


def test_forward_is_exact():
    """Compression only affects what's SAVED — forward must be exact."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    np.testing.assert_allclose(
        np.asarray(compressed_matmul(x, w, jnp.uint32(0), CFG)),
        np.asarray(x @ w), rtol=1e-6)


def test_dx_is_exact():
    """dL/dx = g @ wT needs only w — must match the uncompressed grad."""
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    gc = jax.grad(lambda x: compressed_matmul(x, w, jnp.uint32(3), CFG).sum())(x)
    ge = jax.grad(lambda x: (x @ w).sum())(x)
    np.testing.assert_allclose(np.asarray(gc), np.asarray(ge), rtol=1e-5)


def test_dw_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(2), (32, 64))
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 16))

    def loss(w, s):
        return (compressed_matmul(x, w, s, CFG) ** 2).sum()

    ge = jax.grad(lambda w: ((x @ w) ** 2).sum())(w)
    acc = jnp.zeros_like(w)
    n = 300
    for s in range(n):
        acc = acc + jax.grad(loss)(w, jnp.uint32(s))
    rel = float(jnp.linalg.norm(acc / n - ge) / jnp.linalg.norm(ge))
    assert rel < 0.08, f"dw biased? rel={rel}"


def test_compressed_linear_bias_grad():
    x = jax.random.normal(jax.random.PRNGKey(4), (8, 64))
    w = jax.random.normal(jax.random.PRNGKey(5), (64, 16))
    b = jnp.zeros((16,))
    g = jax.grad(lambda b: compressed_linear(x, w, b, jnp.uint32(0), CFG).sum())(b)
    np.testing.assert_allclose(np.asarray(g), 8.0, rtol=1e-6)


def test_compressed_elementwise():
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 64)) * 2
    y, vjp = jax.vjp(
        lambda x: compressed_elementwise(jnp.tanh, x, jnp.uint32(1), CFG), x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.tanh(x)),
                               rtol=1e-6)
    (dx,) = vjp(jnp.ones_like(y))
    # grad evaluated at the INT2 reconstruction: plumbing check (mean error
    # bounded by tanh'' x bin width); unbiasedness is tested separately
    ref = 1 - jnp.tanh(x) ** 2
    assert float(jnp.abs(dx - ref).mean()) < 0.4


def test_compressed_block_params_grad_flows():
    def f(x, p):
        return jnp.tanh(x @ p["w"]) @ p["v"]

    x = jax.random.normal(jax.random.PRNGKey(7), (8, 64))
    p = {"w": jax.random.normal(jax.random.PRNGKey(8), (64, 32)),
         "v": jax.random.normal(jax.random.PRNGKey(9), (32, 4))}
    g = compressed_block(f, CFG)
    grads = jax.grad(lambda p: g(x, p, jnp.uint32(0)).sum())(p)
    assert all(jnp.isfinite(v).all() for v in jax.tree.leaves(grads))
    assert float(jnp.abs(grads["w"]).sum()) > 0


def test_compressed_block_under_scan():
    """The transformer integration path: custom_vjp inside lax.scan."""
    def f(x, p):
        return jnp.tanh(x @ p)

    g = compressed_block(f, CFG)
    x = jax.random.normal(jax.random.PRNGKey(10), (4, 64))
    stack = jax.random.normal(jax.random.PRNGKey(11), (3, 64, 64)) * 0.1

    def run(stack):
        def body(h, p):
            return g(h, p, jnp.uint32(0)), None
        h, _ = jax.lax.scan(body, x, stack)
        return (h ** 2).sum()

    val, grads = jax.value_and_grad(run)(stack)
    assert jnp.isfinite(val)
    assert jnp.isfinite(grads).all()
