"""Mamba-2 SSD correctness: chunked scan == naive recurrence, chunk-size
invariance, and train/decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.ssm import ssd_chunked


def naive_ssd(xh, dt, a_neg, bmat, cmat):
    """Token-by-token reference recurrence."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    state = np.zeros((b, h, p, n))
    ys = []
    x = np.asarray(xh, np.float64)
    dt = np.asarray(dt, np.float64)
    a = np.asarray(a_neg, np.float64)
    B = np.asarray(bmat, np.float64)
    C = np.asarray(cmat, np.float64)
    for t in range(s):
        decay = np.exp(dt[:, t] * a)                       # (B,H)
        upd = np.einsum("bh,bhp,bn->bhpn", dt[:, t], x[:, t], B[:, t])
        state = state * decay[:, :, None, None] + upd
        ys.append(np.einsum("bhpn,bn->bhp", state, C[:, t]))
    return np.stack(ys, 1), state


def _random_inputs(key, b=2, s=32, h=4, p=8, n=16):
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a_neg = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bmat = jax.random.normal(ks[3], (b, s, n))
    cmat = jax.random.normal(ks[4], (b, s, n))
    return xh, dt, a_neg, bmat, cmat


@pytest.mark.parametrize("chunk", [4, 8, 16, 32])
def test_chunked_matches_naive(chunk):
    xh, dt, a_neg, bmat, cmat = _random_inputs(jax.random.PRNGKey(0))
    y, state = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=chunk,
                           return_state=True)
    y_ref, state_ref = naive_ssd(xh, dt, a_neg, bmat, cmat)
    np.testing.assert_allclose(np.asarray(y, np.float64), y_ref,
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state, np.float64), state_ref,
                               rtol=2e-4, atol=2e-4)


def test_chunk_size_invariance():
    xh, dt, a_neg, bmat, cmat = _random_inputs(jax.random.PRNGKey(1))
    y4, _ = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=4)
    y16, _ = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=16)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y16),
                               rtol=1e-4, atol=1e-4)


def test_initial_state_carries():
    """Splitting a sequence in two with state carry == one pass."""
    xh, dt, a_neg, bmat, cmat = _random_inputs(jax.random.PRNGKey(2), s=32)
    y_full, st_full = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=8,
                                  return_state=True)
    y1, st1 = ssd_chunked(xh[:, :16], dt[:, :16], a_neg, bmat[:, :16],
                          cmat[:, :16], chunk=8, return_state=True)
    y2, st2 = ssd_chunked(xh[:, 16:], dt[:, 16:], a_neg, bmat[:, 16:],
                          cmat[:, 16:], chunk=8, initial_state=st1,
                          return_state=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(st2), np.asarray(st_full),
                               rtol=1e-4, atol=1e-4)


def test_ssd_grads_finite():
    xh, dt, a_neg, bmat, cmat = _random_inputs(jax.random.PRNGKey(3))

    def loss(xh, dt, bmat, cmat):
        y, _ = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=8)
        return (y ** 2).sum()

    grads = jax.grad(loss, argnums=(0, 1, 2, 3))(xh, dt, bmat, cmat)
    for g in grads:
        assert jnp.isfinite(g).all()
