"""Pallas kernels vs pure-jnp oracle: bit-exact codes, allclose dequant,
shape/dtype/bits sweep (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

VM2 = (0.0, 1.05, 1.95, 3.0)


@pytest.mark.parametrize("n,g", [(8, 32), (16, 64), (24, 128), (8, 256),
                                 (3, 64), (1, 32)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_kernel_matches_ref(n, g, bits):
    x = jax.random.normal(jax.random.PRNGKey(n * g + bits), (n, g),
                          jnp.float32) * 2.3 + 0.7
    pk, zk, rk = ops.quantize_packed(x, bits, 42, None, impl="interp")
    pr, zr, rr = ref.quantize_packed(x, bits, 42, None)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), rtol=1e-6)
    dk = ops.dequantize_packed(pk, zk, rk, bits, g, None, impl="interp")
    dr = ref.dequantize_packed(pr, zr, rr, bits, g, None)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_vm_levels(dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3).astype(dtype)
    x32 = x.astype(jnp.float32)
    pk, zk, rk = ops.quantize_packed(x32, 2, 7, VM2, impl="interp")
    pr, zr, rr = ref.quantize_packed(x32, 2, 7, VM2)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    dk = ops.dequantize_packed(pk, zk, rk, 2, 64, VM2, impl="interp")
    dr = ref.dequantize_packed(pr, zr, rr, 2, 64, VM2)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), atol=1e-5)


def test_quant_kernel_seed_sensitivity():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    p1, _, _ = ops.quantize_packed(x, 2, 1, None, impl="interp")
    p2, _, _ = ops.quantize_packed(x, 2, 2, None, impl="interp")
    assert not np.array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("m,d,r", [(64, 256, 128), (100, 512, 128),
                                   (128, 128, 256)])
def test_rp_kernel_matches_ref(m, d, r):
    x = jax.random.normal(jax.random.PRNGKey(m + d), (m, d), jnp.float32)
    yk = ops.rp_project(x, 7, r, impl="interp")
    yr = ref.rp_project(x, 7, r)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    xik = ops.irp_project(yk, 7, d, impl="interp")
    xir = ref.irp_project(yr, 7, d)
    np.testing.assert_allclose(np.asarray(xik), np.asarray(xir),
                               rtol=2e-4, atol=2e-4)


def test_rp_kernel_projection_is_unbiased_reconstruction():
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 256), jnp.float32)
    acc = jnp.zeros_like(x)
    n = 64
    for s in range(n):
        y = ops.rp_project(x, s, 128, impl="interp")
        acc = acc + ops.irp_project(y, s, 256, impl="interp")
    # single-seed rel err ≈ √(D/R − 1) ≈ 1.4; mean of n shrinks as 1/√n
    rel = float(jnp.linalg.norm(acc / n - x) / jnp.linalg.norm(x))
    assert rel < 2.8 / np.sqrt(n), rel


def test_jnp_impl_equals_interp_impl_end_to_end():
    """The 'auto' CPU path (jnp) and the kernel path produce identical bits."""
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 128))
    for bits in (2, 4):
        pa, za, ra = ops.quantize_packed(x, bits, 3, None, impl="jnp")
        pb, zb, rb = ops.quantize_packed(x, bits, 3, None, impl="interp")
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))
