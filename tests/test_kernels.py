"""Pallas kernels vs pure-jnp oracle: bit-exact codes, allclose dequant,
shape/dtype/bits sweep (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

VM2 = (0.0, 1.05, 1.95, 3.0)


@pytest.mark.parametrize("n,g", [(8, 32), (16, 64), (24, 128), (8, 256),
                                 (3, 64), (1, 32)])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quant_kernel_matches_ref(n, g, bits):
    x = jax.random.normal(jax.random.PRNGKey(n * g + bits), (n, g),
                          jnp.float32) * 2.3 + 0.7
    pk, zk, rk = ops.quantize_packed(x, bits, 42, None, impl="interp")
    pr, zr, rr = ref.quantize_packed(x, bits, 42, None)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_allclose(np.asarray(zk), np.asarray(zr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(rk), np.asarray(rr), rtol=1e-6)
    dk = ops.dequantize_packed(pk, zk, rk, bits, g, None, impl="interp")
    dr = ref.dequantize_packed(pr, zr, rr, bits, g, None)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_kernel_vm_levels(dtype):
    x = (jax.random.normal(jax.random.PRNGKey(0), (16, 64)) * 3).astype(dtype)
    x32 = x.astype(jnp.float32)
    pk, zk, rk = ops.quantize_packed(x32, 2, 7, VM2, impl="interp")
    pr, zr, rr = ref.quantize_packed(x32, 2, 7, VM2)
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    dk = ops.dequantize_packed(pk, zk, rk, 2, 64, VM2, impl="interp")
    dr = ref.dequantize_packed(pr, zr, rr, 2, 64, VM2)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), atol=1e-5)


def test_quant_kernel_seed_sensitivity():
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64))
    p1, _, _ = ops.quantize_packed(x, 2, 1, None, impl="interp")
    p2, _, _ = ops.quantize_packed(x, 2, 2, None, impl="interp")
    assert not np.array_equal(np.asarray(p1), np.asarray(p2))


@pytest.mark.parametrize("m,d,r", [(64, 256, 128), (100, 512, 128),
                                   (128, 128, 256)])
def test_rp_kernel_matches_ref(m, d, r):
    x = jax.random.normal(jax.random.PRNGKey(m + d), (m, d), jnp.float32)
    yk = ops.rp_project(x, 7, r, impl="interp")
    yr = ref.rp_project(x, 7, r)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               rtol=2e-4, atol=2e-4)
    xik = ops.irp_project(yk, 7, d, impl="interp")
    xir = ref.irp_project(yr, 7, d)
    np.testing.assert_allclose(np.asarray(xik), np.asarray(xir),
                               rtol=2e-4, atol=2e-4)


def test_rp_kernel_projection_is_unbiased_reconstruction():
    x = jax.random.normal(jax.random.PRNGKey(5), (32, 256), jnp.float32)
    acc = jnp.zeros_like(x)
    n = 64
    for s in range(n):
        y = ops.rp_project(x, s, 128, impl="interp")
        acc = acc + ops.irp_project(y, s, 256, impl="interp")
    # single-seed rel err ≈ √(D/R − 1) ≈ 1.4; mean of n shrinks as 1/√n
    rel = float(jnp.linalg.norm(acc / n - x) / jnp.linalg.norm(x))
    assert rel < 2.8 / np.sqrt(n), rel


def test_jnp_impl_equals_interp_impl_end_to_end():
    """The 'auto' CPU path (jnp) and the kernel path produce identical bits."""
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 128))
    for bits in (2, 4):
        pa, za, ra = ops.quantize_packed(x, bits, 3, None, impl="jnp")
        pb, zb, rb = ops.quantize_packed(x, bits, 3, None, impl="interp")
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb))


# ------------------------------------------------- fused backward M-split
def _np_tree(parts):
    """The fixed-order pairwise reduction the kernel contract names."""
    while parts.shape[0] > 1:
        half = parts.shape[0] // 2
        paired = parts[: 2 * half]
        parts = np.concatenate([paired[0::2] + paired[1::2],
                                parts[2 * half:]], axis=0)
    return parts[0]


@pytest.mark.parametrize("tile_rows,m", [(128, 384), (128, 256), (64, 320)])
def test_fused_bwd_tiled_is_fixed_order_tree(tile_rows, m):
    """Row-tiled fused backward == the fixed-order pairwise tree over
    per-tile ``x̂ᵀ@g`` partials, exactly — including odd tile counts —
    and is bit-stable across repeated runs."""
    d, n, bits, g = 32, 128, 2, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
    gy = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)
    p, z, r = ops.quantize_packed(x.reshape(-1, g), bits, 7, None,
                                  impl="jnp")
    x_hat = np.asarray(ops.dequantize_packed(
        p, z, r, bits, g, None, impl="interp")).reshape(m, d)
    k_tiles = m // tile_rows
    parts = np.stack([
        np.asarray(jnp.dot(jnp.asarray(x_hat[k * tile_rows:
                                             (k + 1) * tile_rows]).T,
                           gy[k * tile_rows:(k + 1) * tile_rows],
                           preferred_element_type=jnp.float32))
        for k in range(k_tiles)])
    dw = ops.dequant_matmul_packed(p, z, r, gy, bits, g, d, None,
                                   impl="interp", tile_rows=tile_rows)
    np.testing.assert_array_equal(np.asarray(dw), _np_tree(parts))
    dw2 = ops.dequant_matmul_packed(p, z, r, gy, bits, g, d, None,
                                    impl="interp", tile_rows=tile_rows)
    np.testing.assert_array_equal(np.asarray(dw), np.asarray(dw2))
    # and the split accumulation stays float-close to the single-tile
    # (bit-parity) order
    dw_single = ops.dequant_matmul_packed(p, z, r, gy, bits, g, d, None,
                                          impl="interp")
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_single),
                               rtol=1e-4, atol=1e-4)


def test_tree_sum_orders():
    from repro.kernels.fused_matmul import _tree_sum

    for k in (1, 2, 3, 4, 5, 8):
        parts = jax.random.normal(jax.random.PRNGKey(k), (k, 8, 16),
                                  jnp.float32)
        np.testing.assert_array_equal(np.asarray(_tree_sum(parts)),
                                      _np_tree(np.asarray(parts)))
