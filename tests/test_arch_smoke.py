"""Per-arch smoke tests (assignment requirement): reduced same-family
config, one forward/train step on CPU, shapes + no-NaN assertions."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.models import Model

B, S = 2, 64


def _inputs(r, key):
    kwargs = {}
    if r.family == "encdec":
        kwargs["enc_embeds"] = jax.random.normal(key, (B, S, r.d_model),
                                                 jnp.bfloat16)
    if r.frontend == "vision":
        kwargs["prefix_embeds"] = jax.random.normal(
            key, (B, r.frontend_len, r.d_model), jnp.bfloat16)
    return kwargs


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_train_step(name):
    cfg = ARCHS[name]
    r = reduce_for_smoke(cfg)
    assert r.family == cfg.family
    model = Model(r)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, r.vocab)
    kwargs = _inputs(r, key)
    loss, grads = jax.value_and_grad(model.loss)(params, tokens, **kwargs)
    assert jnp.isfinite(loss), f"{name}: loss not finite"
    for path, g in jax.tree_util.tree_leaves_with_path(grads):
        assert jnp.isfinite(g).all(), f"{name}: NaN grad at {path}"
    h, _ = model.hidden_states(params, tokens, **kwargs)
    npfx = r.frontend_len if r.frontend == "vision" else 0
    assert h.shape == (B, S + npfx, r.d_model)


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_smoke_decode_step(name):
    cfg = ARCHS[name]
    r = reduce_for_smoke(cfg)
    model = Model(r)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    cache = model.init_cache(B, 32, enc_len=S if r.family == "encdec" else 0)
    if r.family == "encdec":
        cache["enc"] = jax.random.normal(key, (B, S, r.d_model), jnp.bfloat16)
    tok = jax.random.randint(key, (B, 1), 0, r.vocab)
    for _ in range(3):
        logits, cache = model.decode_step(params, cache, tok)
        assert logits.shape == (B, 1, r.vocab)
        assert jnp.isfinite(logits).all(), f"{name}: decode NaN"
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    assert int(cache["pos"][0]) == 3


@pytest.mark.parametrize("name", ["qwen3-32b", "mamba2-780m"])
def test_smoke_act_compression_mode(name):
    """The paper's feature end-to-end inside a transformer."""
    import dataclasses

    from repro.core import CompressionConfig

    r = reduce_for_smoke(ARCHS[name])
    r = dataclasses.replace(r, act_mode="act", act_compression=
                            CompressionConfig(bits=2, group_size=64))
    model = Model(r)
    key = jax.random.PRNGKey(2)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, r.vocab)
    loss, grads = jax.value_and_grad(model.loss)(params, tokens)
    assert jnp.isfinite(loss)
    assert all(jnp.isfinite(g).all() for g in jax.tree.leaves(grads))


def test_configs_match_assignment():
    """Exact architecture hyper-parameters from the assignment table."""
    c = ARCHS["qwen3-moe-235b-a22b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (94, 4096, 64, 4)
    assert (c.n_experts, c.top_k, c.vocab) == (128, 8, 151936)
    c = ARCHS["arctic-480b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.top_k) == (35, 7168, 4864, 2)
    assert c.dense_residual
    c = ARCHS["qwen1.5-32b"]
    assert (c.n_layers, c.d_model, c.d_ff, c.vocab) == (64, 5120, 27392, 152064)
    assert c.qkv_bias
    c = ARCHS["mistral-nemo-12b"]
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads) == (40, 5120, 32, 8)
    c = ARCHS["qwen3-32b"]
    assert c.qk_norm and (c.n_heads, c.n_kv_heads) == (64, 8)
    c = ARCHS["mamba2-780m"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (48, 1536, 128)
    c = ARCHS["zamba2-1.2b"]
    assert (c.n_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = ARCHS["seamless-m4t-large-v2"]
    assert (c.encoder_layers, c.n_layers, c.vocab) == (24, 24, 256206)
    c = ARCHS["internvl2-2b"]
    assert (c.n_layers, c.d_model, c.vocab) == (24, 2048, 92553)
    c = ARCHS["qwen1.5-4b"]
    assert (c.n_layers, c.d_model, c.d_ff) == (40, 2560, 6912)
