"""Continuous-batching serving engine + paged quantized KV cache.

Pins the ISSUE-10 contracts: page-allocator bounds/geometry, layout
byte accounting (bits=4 >= 3x smaller than f32), raw/quantized pool
roundtrips, the bits=16 engine bit-identical to the legacy fixed-batch
loop (continuous AND fixed modes, at capacity), bits=8 logits parity
within tolerance, slot reuse under a single-slot engine, and admission
rejection reasons."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.launch.steps import make_serve_step
from repro.models import Model
from repro.serving import (KVCacheConfig, PageAllocator, Request,
                           ServeEngine, plan_kv_layout)
from repro.serving import kvcache

S, GEN, T = 8, 6, 4                    # prompt len, gen budget, page tokens


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen1.5-4b"]),
                              act_mode="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.asarray(jax.random.randint(
        jax.random.PRNGKey(1), (3, S), 0, cfg.vocab), np.int32)
    return model, params, prompts


def _legacy_tokens(model, params, prompts, max_seq):
    serve = jax.jit(make_serve_step(model))
    logits, cache = model.prefill(params, jnp.asarray(prompts),
                                  max_seq=max_seq)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    gen = [np.asarray(tok)]
    for _ in range(GEN - 1):
        tok, _, cache = serve(params, cache, tok)
        gen.append(np.asarray(tok))
    return np.concatenate(gen, axis=1)


def _run(model, params, prompts, *, bits, n_pages, max_batch, mode,
         max_queue=64, **kw):
    kv = KVCacheConfig(bits=bits, group_size=64, page_tokens=T,
                       n_pages=n_pages)
    eng = ServeEngine(model, params, kv=kv, max_batch=max_batch,
                      max_prompt=S, gen_cap=GEN, mode=mode,
                      max_queue=max_queue, **kw)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=GEN)
            for i in range(len(prompts))]
    return eng.run(reqs)


# ------------------------------------------------------------- allocator
def test_page_allocator_bounds_and_reuse():
    a = PageAllocator(4)
    p1 = a.alloc(3)
    assert p1 == [0, 1, 2] and a.free_pages == 1 and a.used_pages == 3
    assert a.alloc(2) is None          # over capacity -> hold, not error
    a.free([1])
    assert a.alloc(2) == [1, 3]        # freed page is reused first (LIFO)
    with pytest.raises(ValueError, match="double free"):
        a.free([0, 0])
    with pytest.raises(ValueError, match="outside"):
        a.free([4])
    with pytest.raises(ValueError):
        PageAllocator(0)


# ---------------------------------------------------------------- layout
def test_plan_kv_layout_validates_and_counts_bytes():
    mk = lambda **kw: plan_kv_layout(KVCacheConfig(**kw), n_layers=2,
                                     n_kv_heads=4, d_head=16)
    with pytest.raises(ValueError, match="bits"):
        mk(bits=3)
    with pytest.raises(ValueError, match="divide"):
        mk(group_size=48)              # 64-elem token row, 48 straddles
    with pytest.raises(ValueError, match="offload"):
        plan_kv_layout(KVCacheConfig(policy="bogus"), n_layers=2,
                       n_kv_heads=4, d_head=16)
    lay4, lay16 = mk(bits=4), mk(bits=16)
    # bits=4 pool must undercut the uncompressed-f32 pool >= 3x (gated
    # end-to-end in BENCH_serve.json's bytes_gate)
    assert lay4.f32_pool_bytes / lay4.pool_bytes >= 3.0
    assert lay16.pool_bytes == lay16.f32_pool_bytes // 2   # raw bf16
    segs = list(lay4.page_segments())
    assert len(segs) == lay4.n_layers * lay4.n_pages
    assert segs[-1][2] + segs[-1][3] == lay4.total_words


# ------------------------------------------------------------ roundtrips
@pytest.mark.parametrize("bits", [16, 8])
def test_pool_roundtrip_prompt_write(bits):
    lay = plan_kv_layout(KVCacheConfig(bits=bits, group_size=64,
                                       page_tokens=T, n_pages=8),
                         n_layers=2, n_kv_heads=4, d_head=16)
    pool = kvcache.init_kv_pool(lay)
    B = 2
    k = jax.random.normal(jax.random.PRNGKey(2), (2, B, S, 4, 16),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(3), (2, B, S, 4, 16),
                          jnp.bfloat16)
    npg = S // T
    phys = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    pool = kvcache.write_prompt(pool, lay, k, v, phys,
                                jnp.asarray([0, 1], jnp.int32))
    table = jnp.pad(phys, ((0, 0), (0, 2)), constant_values=lay.null_page)
    pool_l0 = jax.tree.map(lambda a: a[0], pool)
    if bits == 16:
        kf, vf = kvcache.gather_kv_raw(pool_l0, lay, table)
        np.testing.assert_array_equal(
            np.asarray(kf[:, :S]), np.asarray(k[0].astype(jnp.float32)))
        # unallocated pages read as zeros (legacy padding semantics)
        assert not np.any(np.asarray(kf[:, S:]))
    else:
        fetch = kvcache.make_page_fetch(pool_l0, lay, table)
        kf0, vf0, kv_pos = fetch(jnp.int32(0))
        np.testing.assert_array_equal(np.asarray(kv_pos), np.arange(T))
        ref = np.asarray(k[0, :, :T].astype(jnp.float32))
        got = np.asarray(kf0)
        # int8 blockwise SR: reconstruction within a range-step of truth
        assert np.max(np.abs(got - ref)) <= np.ptp(ref) / (2**bits - 1) + 1e-6
        k2, _, pos2 = fetch(jnp.int32(3))       # null page -> zeros
        assert not np.any(np.asarray(k2))
        np.testing.assert_array_equal(np.asarray(pos2),
                                      3 * T + np.arange(T))


# ------------------------------------------------------ engine contracts
def test_engine_bits16_bit_identical_to_legacy(served):
    model, params, prompts = served
    maxp = -(-(S + GEN - 1) // T)
    legacy = _legacy_tokens(model, params, prompts, maxp * T)
    for mode in ("continuous", "fixed"):
        out = _run(model, params, prompts, bits=16, n_pages=3 * maxp,
                   max_batch=3, mode=mode)
        got = np.stack([r.tokens for r in out["results"]])
        np.testing.assert_array_equal(got, legacy)
        assert out["rejected"] == 0
        assert out["gen_tokens"] == 3 * GEN


def test_engine_bits8_logits_parity(served):
    model, params, prompts = served
    maxp = -(-(S + GEN - 1) // T)
    outs = {bits: _run(model, params, prompts[:1], bits=bits, n_pages=maxp,
                       max_batch=1, mode="continuous", collect_logits=True)
            for bits in (16, 8)}
    l16, l8 = outs[16]["logits"][0], outs[8]["logits"][0]
    # step 0 comes out of full-precision prefill: exactly equal
    np.testing.assert_array_equal(l8[0], l16[0])
    # step 1 reads the int8 prompt KV: parity within tolerance
    assert np.max(np.abs(l8[1] - l16[1])) < 0.5, \
        np.max(np.abs(l8[1] - l16[1]))
    assert np.argmax(l8[1]) == np.argmax(l16[1])


def test_engine_slot_reuse_single_slot(served):
    model, params, prompts = served
    maxp = -(-(S + GEN - 1) // T)
    legacy = _legacy_tokens(model, params, prompts, maxp * T)
    out = _run(model, params, prompts, bits=16, n_pages=maxp, max_batch=1,
               mode="continuous")
    # one slot serves all three requests in sequence; each row must match
    # the legacy batch row exactly (pages freed and reused in between)
    got = np.stack([r.tokens for r in out["results"]])
    np.testing.assert_array_equal(got, legacy)
    assert out["decode_steps"] == 3 * (GEN - 1)


def test_admission_rejection_reasons(served):
    model, params, prompts = served
    maxp = -(-(S + GEN - 1) // T)
    out = _run(model, params, prompts, bits=16, n_pages=maxp, max_batch=1,
               mode="continuous", max_queue=2)
    # all three arrive before the first admit; the 2-deep queue holds the
    # first two and bounces the third at the door
    statuses = [r.status for r in out["results"]]
    assert statuses == ["done", "done", "rejected"]
    assert "queue full" in out["results"][2].reason
    assert out["rejected"] == 1

    kv = KVCacheConfig(bits=16, page_tokens=T, n_pages=maxp)
    eng = ServeEngine(model, params, kv=kv, max_batch=1, max_prompt=S,
                      gen_cap=GEN)
    ok, reason = eng.sched.submit(
        Request(rid=9, prompt=np.zeros(4 * S, np.int32), max_new=GEN))
    assert not ok and "prompt length" in reason
    ok, reason = eng.sched.submit(
        Request(rid=10, prompt=prompts[0], max_new=10 * GEN))
    assert not ok and "max_new" in reason


def test_engine_rejects_non_attention_families(served):
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["mamba2-780m"]),
                              act_mode="none")
    model = Model(cfg)
    with pytest.raises(ValueError, match="families"):
        ServeEngine(model, {}, max_batch=1, max_prompt=S, gen_cap=GEN)
