"""Variance-minimization math (paper §3.2, App. A-C)."""
import numpy as np
import pytest

from repro.core.compressor import CompressionConfig
from repro.core.variance import (clipped_normal_params, expected_sr_variance,
                                 expected_sr_variance_uniform, js_divergence,
                                 model_histogram, optimize_levels,
                                 sr_variance, variance_reduction)


def test_clipped_normal_params():
    mu, sigma = clipped_normal_params(16, bits=2)
    assert mu == 1.5
    # mass below 0 is exactly 1/D by construction
    from scipy.stats import norm
    assert abs(norm.cdf(0, mu, sigma) - 1 / 16) < 1e-9


def test_sr_variance_zero_at_levels():
    levels = np.array([0.0, 1.1, 1.9, 3.0])
    v = sr_variance(levels.copy(), levels)
    np.testing.assert_allclose(v, 0.0, atol=1e-12)


def test_sr_variance_max_at_bin_center():
    levels = np.array([0.0, 1.0, 2.0, 3.0])
    h = np.linspace(0.01, 0.99, 99)
    v = sr_variance(h, levels)
    assert abs(h[np.argmax(v)] - 0.5) < 0.02


@pytest.mark.parametrize("D", [8, 16, 64, 256, 1024])
def test_optimized_levels_beat_uniform(D):
    lv = optimize_levels(D, 2)
    assert lv[0] == 0.0 and lv[-1] == 3.0
    assert all(a < b for a, b in zip(lv, lv[1:]))
    vo = expected_sr_variance(lv, D, 2)
    vu = expected_sr_variance_uniform(D, 2)
    assert vo <= vu + 1e-12


def test_variance_reduction_grows_with_D():
    """Heavier clipping (larger D) -> more non-uniform optimum -> larger
    reduction (matches paper Fig. 5 trend)."""
    reds = [variance_reduction(d, 2) for d in (16, 64, 256)]
    assert reds[0] < reds[-1]
    assert 0.0 <= reds[0] < 0.5


def test_optimal_levels_symmetric():
    """CN is symmetric about B/2, so α* + β* ≈ B."""
    lv = optimize_levels(128, 2)
    assert abs((lv[1] + lv[2]) - 3.0) < 0.02


def test_levels_default_uses_post_rp_dim():
    """Without RP the CN dimension is the block size; with RP it must be
    the *projected* block size (paper App. C uses the projected row dim)."""
    no_rp = CompressionConfig(bits=2, group_size=256, vm=True)
    assert no_rp.cn_dim() == 256
    assert no_rp.levels() == optimize_levels(256, 2)
    with_rp = CompressionConfig(bits=2, group_size=256, rp_ratio=8, vm=True)
    assert with_rp.cn_dim() == 32
    assert with_rp.levels() == optimize_levels(32, 2)
    # explicit vm_dim always wins over the default
    pinned = CompressionConfig(bits=2, group_size=256, rp_ratio=8, vm=True,
                               vm_dim=64)
    assert pinned.levels() == optimize_levels(64, 2)


def test_levels_vm_dim_zero_rejected_not_silently_defaulted():
    """``vm_dim or group_size`` treated 0 as unset; now only ``None`` is
    the sentinel and degenerate explicit values raise."""
    cfg = CompressionConfig(bits=2, group_size=64, vm=True, vm_dim=0)
    with pytest.raises(ValueError, match="vm_dim"):
        cfg.levels()
    with pytest.raises(ValueError, match="vm_dim"):
        CompressionConfig(bits=2, group_size=64, vm=True, vm_dim=1).cn_dim()
    # tiny groups with large rp_ratio clamp the default to a valid D
    assert CompressionConfig(bits=2, group_size=8, rp_ratio=8,
                             vm=True).cn_dim() == 2


def test_js_divergence_basic():
    p = np.array([0.5, 0.5, 0.0])
    assert js_divergence(p, p) < 1e-9
    q = np.array([0.0, 0.0, 1.0])
    assert js_divergence(p, q) > 0.5


def test_model_histograms_normalized():
    edges = np.linspace(0, 3, 61)
    for kind in ("uniform", "clipnorm"):
        h = model_histogram(64, 2, edges, kind)
        assert abs(h.sum() - 1.0) < 1e-6
