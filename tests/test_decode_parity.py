"""Prefill/decode vs full-forward parity — the strongest serving-path
correctness check: running the model token-by-token through the cache must
reproduce the training-path logits."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduce_for_smoke
from repro.models import Model

B, S = 2, 32


def _full_logits(model, params, tokens):
    h, _ = model.hidden_states(params, tokens)
    return (h @ params["lm_head"]).astype(jnp.float32)


@pytest.mark.parametrize("name", ["qwen3-32b", "qwen1.5-4b", "mamba2-780m",
                                  "zamba2-1.2b", "qwen3-moe-235b-a22b"])
def test_decode_matches_forward(name):
    r = reduce_for_smoke(ARCHS[name])
    # generous MoE capacity: capacity drops are legitimate train/serve
    # divergence, so parity is tested in the drop-free regime
    r = dataclasses.replace(r, act_mode="none", moe_capacity_factor=8.0)
    model = Model(r)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, r.vocab)

    ref = _full_logits(model, params, tokens)          # (B, S, V)

    cache = model.init_cache(B, S)
    outs = []
    for t in range(S):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)

    # bf16 params, f32 softmax path: compare top-1 agreement + numeric close
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=0.1, atol=0.15)
    top_ref = np.asarray(jnp.argmax(ref, -1))
    top_got = np.asarray(jnp.argmax(got, -1))
    agree = (top_ref == top_got).mean()
    assert agree > 0.95, f"{name}: top-1 agreement {agree}"


@pytest.mark.parametrize("name", ["qwen3-32b", "mamba2-780m", "zamba2-1.2b"])
def test_prefill_then_decode_matches_forward(name):
    r = reduce_for_smoke(ARCHS[name])
    r = dataclasses.replace(r, act_mode="none")
    model = Model(r)
    key = jax.random.PRNGKey(1)
    params = model.init(key)
    tokens = jax.random.randint(key, (B, S), 0, r.vocab)
    split = S // 2

    ref = _full_logits(model, params, tokens)

    last_logits, cache = model.prefill(params, tokens[:, :split], max_seq=S)
    np.testing.assert_allclose(np.asarray(last_logits),
                               np.asarray(ref[:, split - 1]),
                               rtol=0.1, atol=0.15)
    for t in range(split, S):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(ref[:, t]),
                                   rtol=0.1, atol=0.2)
