"""MoE dispatch invariants."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import swiglu
from repro.models.moe import capacity, moe_ffn


def _params(key, e, d, f, identical=False):
    ks = jax.random.split(key, 4)
    wg = jax.random.normal(ks[0], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[1], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[2], (e, f, d)) * 0.1
    if identical:
        wg = jnp.broadcast_to(wg[:1], wg.shape)
        wu = jnp.broadcast_to(wu[:1], wu.shape)
        wd = jnp.broadcast_to(wd[:1], wd.shape)
    return {"router": jax.random.normal(ks[3], (d, e)) * 0.1,
            "w_gate": wg, "w_up": wu, "w_down": wd}


def test_identical_experts_equal_dense_ffn():
    """With all experts identical and generous capacity, MoE == dense FFN."""
    e, k, d, f = 8, 2, 32, 64
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, d))
    p = _params(jax.random.PRNGKey(1), e, d, f, identical=True)
    y, aux = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=8.0)
    y_ref = swiglu(x, p["w_gate"][0], p["w_up"][0], p["w_down"][0])
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y_ref, np.float32),
                               rtol=2e-2, atol=2e-3)


def test_aux_loss_uniform_router_is_one():
    """Perfectly balanced routing gives aux = E/k · k/E · ... = 1."""
    e, k, d, f = 8, 2, 32, 64
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 64, d))
    p = _params(jax.random.PRNGKey(3), e, d, f)
    p = {**p, "router": jnp.zeros((d, e))}
    _, aux = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=8.0)
    # ties in top_k with zero router logits pick arbitrary experts; f_e stays
    # a permutation-invariant distribution summing to k... aux ~ 1
    assert 0.5 < float(aux) < 2.0


def test_capacity_rounding():
    assert capacity(4096, 128, 8, 1.25) == 328
    assert capacity(64, 8, 2, 1.0) % 8 == 0
    assert capacity(1, 128, 8, 1.25) >= 8


def test_moe_grads_finite_and_router_learns():
    e, k, d, f = 8, 2, 32, 64
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, d))
    p = _params(jax.random.PRNGKey(5), e, d, f)

    def loss(p):
        y, aux = moe_ffn(x, p, n_experts=e, top_k=k)
        return (y ** 2).sum() + 0.01 * aux

    g = jax.grad(loss)(p)
    for leaf in jax.tree.leaves(g):
        assert jnp.isfinite(leaf).all()
    assert float(jnp.abs(g["router"]).sum()) > 0, "router got no gradient"


def test_dropped_tokens_pass_through_zero():
    """Capacity 'drops' must zero the expert contribution, not corrupt it."""
    e, k, d, f = 4, 1, 16, 32
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 64, d))
    p = _params(jax.random.PRNGKey(7), e, d, f)
    # force everything to expert 0 with tiny capacity -> most tokens dropped
    p = {**p, "router": jnp.zeros((d, e)).at[:, 0].set(100.0)}
    y, _ = moe_ffn(x, p, n_experts=e, top_k=k, capacity_factor=0.1)
    assert jnp.isfinite(y).all()
    # some rows must be exactly zero (dropped)
    row_norms = jnp.linalg.norm(y[0], axis=-1)
    assert float(row_norms.min()) == 0.0
