"""Faithful-repro GNN tests: the paper's accuracy-parity and memory claims
on the synthetic matched-statistics datasets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.core.compressor import compress
from repro.graph import (GNNConfig, arxiv_like, synthetic_graph, train_gnn,
                         activation_memory_report)
from repro.graph.analysis import collect_projected_activations, table2_row
from repro.graph.models import (gnn_forward, graph_tuple, init_gnn_params,
                                relu_1bit)
from repro.graph.train import _loss_fn


@pytest.fixture(scope="module")
def small_graph():
    return synthetic_graph("test", 1024, 6000, 64, 8, homophily=0.5,
                           feature_noise=1.5, seed=0)


def test_forward_shapes(small_graph):
    g = small_graph
    for arch in ("gcn", "sage"):
        cfg = GNNConfig(arch=arch, hidden=(32,), n_classes=g.num_classes)
        params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
        out = gnn_forward(params, graph_tuple(g), cfg)
        assert out.shape == (g.n_nodes, g.num_classes)
        assert jnp.isfinite(out).all()


def test_training_beats_prior(small_graph):
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(64,), n_classes=g.num_classes)
    r = train_gnn(g, cfg, n_epochs=40, seed=0)
    assert r["test_acc"] > 2.0 / g.num_classes, r["test_acc"]


def test_int2_blockwise_accuracy_parity(small_graph):
    """Paper Table 1: INT2 + RP + block-wise ≈ FP32 accuracy."""
    g = small_graph
    accs = {}
    for name, comp in [
        ("fp32", None),
        ("int2_g64", CompressionConfig(bits=2, group_size=64, rp_ratio=8)),
        ("int2_g64_vm", CompressionConfig(bits=2, group_size=64, rp_ratio=8,
                                          vm=True)),
    ]:
        cfg = GNNConfig(arch="sage", hidden=(64, 64),
                        n_classes=g.num_classes, compression=comp)
        accs[name] = train_gnn(g, cfg, n_epochs=60, seed=0)["test_acc"]
    assert accs["int2_g64"] > accs["fp32"] - 0.08, accs
    assert accs["int2_g64_vm"] > accs["fp32"] - 0.08, accs


def test_memory_report_trends(small_graph):
    """Paper Table 1 M column: block-wise beats per-row; >95% vs FP32."""
    g = small_graph
    prev = None
    for gsize in (16, 64, 256):
        cfg = GNNConfig(arch="sage", hidden=(64, 64),
                        n_classes=g.num_classes,
                        compression=CompressionConfig(2, gsize, 8))
        rep = activation_memory_report(g, cfg)
        assert rep["reduction"] > 0.95
        if prev is not None:
            assert rep["compressed_bytes"] <= prev
        prev = rep["compressed_bytes"]


def test_relu_1bit_shape_robustness():
    """The packed sign mask must round-trip gradients for any rank — the
    old packing reshaped to (shape[0], -1) and silently assumed 2-D."""
    key = jax.random.PRNGKey(7)
    for shape in [(), (5,), (33,), (5, 6), (3, 4, 5), (2, 3, 4, 5)]:
        z = jax.random.normal(key, shape)
        y, vjp = jax.vjp(relu_1bit, z)
        (dz,) = vjp(jnp.ones_like(z))
        assert jnp.array_equal(y, jnp.maximum(z, 0.0)), shape
        assert jnp.array_equal(dz, (z > 0).astype(z.dtype)), shape


def test_sr_seed_determinism_and_layer_decorrelation(small_graph):
    """Identical sr_seed => bit-identical grads across runs; different
    seeds (and the per-layer ``seed + li*1013`` offsets) actually change
    the stochastic-rounding codes."""
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(32,), n_classes=g.num_classes,
                    compression=CompressionConfig(2, 64, 8))
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    gt = graph_tuple(g)
    mask = g.train_mask.astype(jnp.float32)
    grad_fn = jax.jit(jax.grad(_loss_fn), static_argnums=(4,))
    g1 = grad_fn(params, gt, g.labels, mask, cfg, jnp.uint32(5))
    g2 = grad_fn(params, gt, g.labels, mask, cfg, jnp.uint32(5))
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    g3 = grad_fn(params, gt, g.labels, mask, cfg, jnp.uint32(6))
    assert any(not np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g3)))
    # the per-layer offset scheme: adjacent layer seeds give distinct codes
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 64))
    seed = jnp.uint32(5 * 7919)
    c0 = compress(x, cfg.compression, seed)
    c1 = compress(x, cfg.compression, seed + jnp.uint32(1013))
    assert not np.array_equal(np.asarray(c0.packed), np.asarray(c1.packed))


def test_table2_instrumentation(small_graph):
    """JS(clipnorm) < JS(uniform) on observed activations (paper Table 2)."""
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(64,), n_classes=g.num_classes)
    r = train_gnn(g, cfg, n_epochs=30, seed=0)
    caps = collect_projected_activations(r["params"], graph_tuple(g), cfg,
                                         rp_ratio=8)
    rows = [table2_row(c) for c in caps]
    for row in rows:
        assert row["js_clipnorm"] < row["js_uniform"], row
        assert row["var_reduction_pct"] > -5.0, row
