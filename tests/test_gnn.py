"""Faithful-repro GNN tests: the paper's accuracy-parity and memory claims
on the synthetic matched-statistics datasets."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CompressionConfig
from repro.graph import (GNNConfig, arxiv_like, synthetic_graph, train_gnn,
                         activation_memory_report)
from repro.graph.analysis import collect_projected_activations, table2_row
from repro.graph.models import gnn_forward, graph_tuple, init_gnn_params


@pytest.fixture(scope="module")
def small_graph():
    return synthetic_graph("test", 1024, 6000, 64, 8, homophily=0.5,
                           feature_noise=1.5, seed=0)


def test_forward_shapes(small_graph):
    g = small_graph
    for arch in ("gcn", "sage"):
        cfg = GNNConfig(arch=arch, hidden=(32,), n_classes=g.num_classes)
        params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
        out = gnn_forward(params, graph_tuple(g), cfg)
        assert out.shape == (g.n_nodes, g.num_classes)
        assert jnp.isfinite(out).all()


def test_training_beats_prior(small_graph):
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(64,), n_classes=g.num_classes)
    r = train_gnn(g, cfg, n_epochs=40, seed=0)
    assert r["test_acc"] > 2.0 / g.num_classes, r["test_acc"]


def test_int2_blockwise_accuracy_parity(small_graph):
    """Paper Table 1: INT2 + RP + block-wise ≈ FP32 accuracy."""
    g = small_graph
    accs = {}
    for name, comp in [
        ("fp32", None),
        ("int2_g64", CompressionConfig(bits=2, group_size=64, rp_ratio=8)),
        ("int2_g64_vm", CompressionConfig(bits=2, group_size=64, rp_ratio=8,
                                          vm=True)),
    ]:
        cfg = GNNConfig(arch="sage", hidden=(64, 64),
                        n_classes=g.num_classes, compression=comp)
        accs[name] = train_gnn(g, cfg, n_epochs=60, seed=0)["test_acc"]
    assert accs["int2_g64"] > accs["fp32"] - 0.08, accs
    assert accs["int2_g64_vm"] > accs["fp32"] - 0.08, accs


def test_memory_report_trends(small_graph):
    """Paper Table 1 M column: block-wise beats per-row; >95% vs FP32."""
    g = small_graph
    prev = None
    for gsize in (16, 64, 256):
        cfg = GNNConfig(arch="sage", hidden=(64, 64),
                        n_classes=g.num_classes,
                        compression=CompressionConfig(2, gsize, 8))
        rep = activation_memory_report(g, cfg)
        assert rep["reduction"] > 0.95
        if prev is not None:
            assert rep["compressed_bytes"] <= prev
        prev = rep["compressed_bytes"]


def test_table2_instrumentation(small_graph):
    """JS(clipnorm) < JS(uniform) on observed activations (paper Table 2)."""
    g = small_graph
    cfg = GNNConfig(arch="sage", hidden=(64,), n_classes=g.num_classes)
    r = train_gnn(g, cfg, n_epochs=30, seed=0)
    caps = collect_projected_activations(r["params"], graph_tuple(g), cfg,
                                         rp_ratio=8)
    rows = [table2_row(c) for c in caps]
    for row in rows:
        assert row["js_clipnorm"] < row["js_uniform"], row
        assert row["var_reduction_pct"] > -5.0, row
