"""Deterministic, restart-safe synthetic token pipeline.

``batch_for_step(cfg, shape, step, host_id, n_hosts)`` is a pure function of
its arguments — no iterator state to checkpoint, no epoch bookkeeping to
lose on failure, and elastic: changing ``n_hosts`` re-partitions the same
global stream.  Tokens follow a Zipf-ish marginal (more realistic softmax
load than uniform) with a repeating-ngram structure so a real LM loss
actually decreases.
"""
from __future__ import annotations

import numpy as np


def batch_for_step(vocab: int, batch: int, seq: int, step: int,
                   host_id: int = 0, n_hosts: int = 1, seed: int = 0):
    assert batch % n_hosts == 0
    local = batch // n_hosts
    rng = np.random.default_rng(
        np.uint64(seed) * np.uint64(1_000_003)
        + np.uint64(step) * np.uint64(65_537) + np.uint64(host_id))
    # zipf-ish marginal over the vocab
    z = rng.zipf(1.3, size=(local, seq)).astype(np.int64)
    tokens = (z - 1) % vocab
    # inject short repeated n-grams (learnable structure)
    period = 64
    base = rng.integers(0, vocab, size=(local, period))
    mask = rng.random((local, seq)) < 0.5
    tiled = np.tile(base, (1, seq // period + 1))[:, :seq]
    tokens = np.where(mask, tiled, tokens)
    return tokens.astype(np.int32)
