from repro.data.synthetic import batch_for_step

__all__ = ["batch_for_step"]
