"""Synthetic graph datasets with OGB-Arxiv / Flickr matched statistics.

The benchmark datasets are not downloadable in this offline container
(DESIGN.md §2), so we generate stochastic-block-model-flavoured stand-ins:
power-law-ish degrees, homophilous edges, class-conditional Gaussian
features — enough learnable structure that a GCN/SAGE materially beats the
class prior, which is what the paper's accuracy-parity claims need.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass
class Graph:
    name: str
    features: jnp.ndarray        # (N, F) f32
    labels: jnp.ndarray          # (N,) i32
    edge_src: jnp.ndarray        # (E,) i32  — includes self loops, directed both ways
    edge_dst: jnp.ndarray        # (E,) i32
    gcn_weight: jnp.ndarray      # (E,) f32  — D̃^{-1/2}(A+I)D̃^{-1/2} entries
    mean_weight: jnp.ndarray     # (E,) f32  — row-mean aggregation weights
    train_mask: jnp.ndarray      # (N,) bool
    val_mask: jnp.ndarray
    test_mask: jnp.ndarray
    num_classes: int

    @property
    def n_nodes(self) -> int:
        return int(self.features.shape[0])

    @property
    def n_feats(self) -> int:
        return int(self.features.shape[1])

    @property
    def n_edges(self) -> int:
        return int(self.edge_src.shape[0])


def in_adjacency(edge_src, edge_dst, n_nodes: int):
    """CSR over *destination*: ``(nbr, starts)`` with the in-neighbors
    (message sources) of node ``u`` at ``nbr[starts[u]:starts[u+1]]``.

    numpy-side helper for partitioners/samplers — the edge list itself stays
    the device-side representation (``spmm`` consumes it directly)."""
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    order = np.argsort(dst, kind="stable")
    starts = np.searchsorted(dst[order], np.arange(n_nodes + 1))
    return src[order], starts


def synthetic_graph(name: str, n_nodes: int, n_edges: int, n_feats: int,
                    n_classes: int, homophily: float = 0.65,
                    feature_noise: float = 1.0, seed: int = 0) -> Graph:
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes)

    # power-law-ish degree skew: dst index drawn as floor(N * u^2)
    src = rng.integers(0, n_nodes, n_edges)
    dst = (n_nodes * rng.random(n_edges) ** 2).astype(np.int64)
    # homophily: rewire a fraction of edges to a same-class destination
    same = rng.random(n_edges) < homophily
    by_class = [np.flatnonzero(labels == c) for c in range(n_classes)]
    rewired = np.array(
        [by_class[labels[s]][rng.integers(len(by_class[labels[s]]))]
         if m else d for s, d, m in zip(src, dst, same)], dtype=np.int64)
    dst = rewired
    keep = src != dst
    src, dst = src[keep], dst[keep]

    # symmetrize + self loops
    s_all = np.concatenate([src, dst, np.arange(n_nodes)])
    d_all = np.concatenate([dst, src, np.arange(n_nodes)])

    deg = np.bincount(d_all, minlength=n_nodes).astype(np.float64)
    gcn_w = 1.0 / np.sqrt(deg[s_all] * deg[d_all])
    mean_w = 1.0 / deg[d_all]

    centers = rng.normal(0, 1, (n_classes, n_feats))
    feats = centers[labels] + feature_noise * rng.normal(0, 1, (n_nodes, n_feats))

    perm = rng.permutation(n_nodes)
    n_tr, n_va = int(0.6 * n_nodes), int(0.2 * n_nodes)
    train_mask = np.zeros(n_nodes, bool)
    val_mask = np.zeros(n_nodes, bool)
    test_mask = np.zeros(n_nodes, bool)
    train_mask[perm[:n_tr]] = True
    val_mask[perm[n_tr:n_tr + n_va]] = True
    test_mask[perm[n_tr + n_va:]] = True

    return Graph(
        name=name,
        features=jnp.asarray(feats, jnp.float32),
        labels=jnp.asarray(labels, jnp.int32),
        edge_src=jnp.asarray(s_all, jnp.int32),
        edge_dst=jnp.asarray(d_all, jnp.int32),
        gcn_weight=jnp.asarray(gcn_w, jnp.float32),
        mean_weight=jnp.asarray(mean_w, jnp.float32),
        train_mask=jnp.asarray(train_mask),
        val_mask=jnp.asarray(val_mask),
        test_mask=jnp.asarray(test_mask),
        num_classes=n_classes,
    )


def stream_edge_chunks(n_nodes: int, n_edges: int, *, labels=None,
                       homophily: float = 0.0, seed: int = 0,
                       chunk_edges: int = 1 << 18):
    """Yield the synthetic edge stream as ``(src, dst)`` chunks with
    O(chunk) host memory.

    The same generative family as :func:`synthetic_graph` — uniform
    sources, power-law-ish destinations (``floor(N·u²)``), an optional
    homophilous rewiring of a ``homophily`` fraction of destinations to a
    same-class node — but fully vectorized per chunk and never
    materializing the edge list: the papers100M-scale generator's
    building block.  Self loops are filtered per chunk (so chunk lengths
    vary slightly; the *drawn* count is exact).

    Homophilous rewiring picks, for each rewired edge, a uniform node of
    the source's class via one ``argsort(labels)`` table shared across
    chunks — vectorized, unlike ``synthetic_graph``'s per-edge Python
    loop (kept untouched upstream: its draw order defines the existing
    datasets' bits).
    """
    rng = np.random.default_rng(seed)
    order = starts = None
    if homophily > 0.0:
        if labels is None:
            raise ValueError("homophily > 0 needs labels")
        labels = np.asarray(labels)
        order = np.argsort(labels, kind="stable")
        n_classes = int(labels.max()) + 1
        starts = np.searchsorted(labels[order], np.arange(n_classes + 1))
    done = 0
    while done < n_edges:
        k = min(chunk_edges, n_edges - done)
        src = rng.integers(0, n_nodes, k)
        dst = (n_nodes * rng.random(k) ** 2).astype(np.int64)
        if homophily > 0.0:
            rew = rng.random(k) < homophily
            ls = labels[src[rew]]
            lo, hi = starts[ls], starts[ls + 1]
            dst[rew] = order[lo + rng.integers(0, hi - lo)]
        keep = src != dst
        yield src[keep], dst[keep]
        done += k


def synthetic_graph_streamed(name: str, n_nodes: int, n_edges: int,
                             n_feats: int, n_classes: int,
                             homophily: float = 0.0,
                             feature_noise: float = 1.0, seed: int = 0,
                             chunk_edges: int = 1 << 18) -> Graph:
    """:func:`synthetic_graph`'s Graph assembled from
    :func:`stream_edge_chunks` — same symmetrize/self-loop/normalization
    pipeline, but degrees accumulate per chunk (one ``bincount`` pass)
    and the host never holds more than one chunk of intermediate draw
    state.  Used for the papers100M-scale mesh benchmarks; the classic
    datasets keep :func:`synthetic_graph` (different draw order, so
    different — frozen — bits).
    """
    rng = np.random.default_rng(seed + 1)
    labels = rng.integers(0, n_classes, n_nodes)
    deg = np.zeros(n_nodes, np.int64)
    srcs, dsts = [np.arange(n_nodes)], [np.arange(n_nodes)]
    deg += 1  # self loops
    for src, dst in stream_edge_chunks(n_nodes, n_edges, labels=labels,
                                       homophily=homophily, seed=seed,
                                       chunk_edges=chunk_edges):
        # symmetrize chunk-locally: both directions land in the stream
        srcs.extend([src, dst])
        dsts.extend([dst, src])
        deg += np.bincount(dst, minlength=n_nodes)
        deg += np.bincount(src, minlength=n_nodes)
    s_all = np.concatenate(srcs)
    d_all = np.concatenate(dsts)
    degf = deg.astype(np.float64)
    gcn_w = 1.0 / np.sqrt(degf[s_all] * degf[d_all])
    mean_w = 1.0 / degf[d_all]

    centers = rng.normal(0, 1, (n_classes, n_feats))
    feats = (centers[labels]
             + feature_noise * rng.normal(0, 1, (n_nodes, n_feats)))

    perm = rng.permutation(n_nodes)
    n_tr, n_va = int(0.6 * n_nodes), int(0.2 * n_nodes)
    train_mask = np.zeros(n_nodes, bool)
    val_mask = np.zeros(n_nodes, bool)
    test_mask = np.zeros(n_nodes, bool)
    train_mask[perm[:n_tr]] = True
    val_mask[perm[n_tr:n_tr + n_va]] = True
    test_mask[perm[n_tr + n_va:]] = True

    return Graph(
        name=name,
        features=jnp.asarray(feats, jnp.float32),
        labels=jnp.asarray(labels, jnp.int32),
        edge_src=jnp.asarray(s_all, jnp.int32),
        edge_dst=jnp.asarray(d_all, jnp.int32),
        gcn_weight=jnp.asarray(gcn_w, jnp.float32),
        mean_weight=jnp.asarray(mean_w, jnp.float32),
        train_mask=jnp.asarray(train_mask),
        val_mask=jnp.asarray(val_mask),
        test_mask=jnp.asarray(test_mask),
        num_classes=n_classes,
    )


def papers100m_like(scale: float = 1e-4, seed: int = 0) -> Graph:
    """ogbn-papers100M stand-in: 111,059,956 nodes / 1.6B edges / 128
    feats / 172 classes, streamed down by ``scale``.

    The mesh engine's scale target (ISSUE 7): big enough at small scales
    to exercise partition-parallel sharding + the host-resident feature
    pager, generated via :func:`synthetic_graph_streamed` so host memory
    stays O(chunk) during edge synthesis.
    """
    n = max(4096, int(111_059_956 * scale))
    e = max(8 * n, int(1_615_685_872 * scale))
    return synthetic_graph_streamed("papers100m-like", n, e, 128, 172,
                                    homophily=0.4, feature_noise=2.5,
                                    seed=seed)


def arxiv_like(scale: float = 0.1, seed: int = 0) -> Graph:
    """OGB-Arxiv stand-in: 169,343 nodes / ~1.17M edges / 128 feats / 40 cls.

    Noise/homophily tuned so a 3-layer SAGE lands mid-range (~0.7), leaving
    headroom for compression-induced accuracy loss to show if it existed —
    mirrors the paper's Table 1 operating point (71.95% FP32).
    """
    n = max(512, int(169_343 * scale))
    e = max(4 * n, int(1_166_243 * scale))
    return synthetic_graph("arxiv-like", n, e, 128, 40, homophily=0.5,
                           feature_noise=2.0, seed=seed)


def cora_like(scale: float = 1.0, seed: int = 0) -> Graph:
    """Cora stand-in: 2,708 nodes / ~10.5K edges / 128 feats / 7 classes.

    The classic citation-network smoke config — small enough that offload
    parity gates (host-vs-device loss trajectories) run in seconds on
    CPU, with the real datasets' class count and edge density.  Feature
    dim is 128 (not Cora's 1433 bag-of-words) to keep CPU matmuls cheap.
    """
    n = max(256, int(2_708 * scale))
    e = max(4 * n, int(10_556 * scale))
    return synthetic_graph("cora-like", n, e, 128, 7, homophily=0.6,
                           feature_noise=1.5, seed=seed)


def flickr_like(scale: float = 0.1, seed: int = 0) -> Graph:
    """Flickr stand-in: 89,250 nodes / ~900K edges / 500 feats / 7 classes.

    Tuned toward the paper's ~51.8% FP32 operating point (hard task)."""
    n = max(512, int(89_250 * scale))
    e = max(4 * n, int(899_756 * scale))
    return synthetic_graph("flickr-like", n, e, 500, 7, homophily=0.4,
                           feature_noise=3.0, seed=seed)
