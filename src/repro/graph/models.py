"""GCN (Kipf-Welling, paper Eq. 1) and GraphSAGE with EXACT-style
activation compression.

Compression placement matches EXACT/i-EXACT exactly:

* the dense input of every linear is stored compressed
  (:func:`repro.core.compressed_matmul`) — RP + block-wise SR quant;
* ReLU saves a packed 1-bit sign mask (:func:`relu_1bit`), never the tensor;
* the sparse aggregation ``Â·`` is linear in H — its VJP needs only the edge
  list and weights, so it stores no float activations at all.

The compress/decompress execution strategy is picked by
``CompressionConfig.impl`` (see :mod:`repro.core.backend`);
:meth:`GNNConfig.with_impl` flips a whole training job between the
reference and fused kernel backends with bit-identical codes.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as packmod
from repro.core.act_compress import compressed_matmul, zero_ct  # noqa: F401
from repro.core.compressor import CompressionConfig
from repro.engine.seeds import layer_seed


# ------------------------------------------------------------- 1-bit ReLU
@jax.custom_vjp
def relu_1bit(z):
    return jnp.maximum(z, 0.0)


def _relu_fwd(z):
    # Pack the sign bits of the *flattened* tensor as one row: rank-agnostic
    # (scalars, vectors, (N, F) maps, stacked/batched rank>=3 inputs) and at
    # most 31 wasted bits total, vs one word per row of a 2-D reshape.
    mask = packmod.pack((z > 0).astype(jnp.int32).reshape(1, -1), 1)
    return jnp.maximum(z, 0.0), (mask, z.shape)


def _relu_bwd(res, g):
    mask, shape = res
    m = packmod.unpack(mask, 1, int(np.prod(shape, dtype=np.int64)))
    return (g * m.reshape(shape).astype(g.dtype),)


relu_1bit.defvjp(_relu_fwd, _relu_bwd)


# ------------------------------------------------------------------ SpMM
def spmm(h, src, dst, w, n_nodes: int):
    """out[d] += w_e * h[s] over edges — the Â· product as segment-sum."""
    msg = h[src] * w[:, None]
    return jax.ops.segment_sum(msg, dst, num_segments=n_nodes)


# ----------------------------------------------------------------- model
@dataclasses.dataclass(frozen=True)
class GNNConfig:
    """``compression`` is heterogeneous-precision aware: a single
    ``CompressionConfig`` is broadcast to every layer (the original
    homogeneous behavior), while a tuple carries one entry per GNN layer
    (``len(hidden) + 1``; ``None`` entries leave that layer uncompressed).
    :meth:`layer_compression` is the normalized per-layer view every
    consumer (forward pass, memory model, allocator) reads."""

    arch: str = "sage"                 # "gcn" | "sage"
    hidden: tuple[int, ...] = (256, 256)
    n_classes: int = 40
    compression: (CompressionConfig | None
                  | tuple[CompressionConfig | None, ...]) = None
    dropout: float = 0.0

    @property
    def n_layers(self) -> int:
        return len(self.hidden) + 1

    def layer_compression(self) -> tuple[CompressionConfig | None, ...]:
        """Per-layer compression configs, broadcasting a shared one."""
        if self.compression is None:
            return (None,) * self.n_layers
        if isinstance(self.compression, CompressionConfig):
            return (self.compression,) * self.n_layers
        per = tuple(self.compression)
        if len(per) != self.n_layers:
            raise ValueError(
                f"per-layer compression tuple has {len(per)} entries for a "
                f"{self.n_layers}-layer model")
        return per

    def with_layer_bits(self, bits) -> "GNNConfig":
        """Pin each layer's quantization width (autoprec's output).

        ``bits`` holds one entry per layer; entries that are falsy (0/None)
        or land on an uncompressed layer leave that layer untouched.
        """
        per = self.layer_compression()
        if len(bits) != self.n_layers:
            raise ValueError(
                f"got {len(bits)} bit-widths for {self.n_layers} layers")
        new = tuple(
            c if c is None or not b else dataclasses.replace(c, bits=int(b))
            for c, b in zip(per, bits))
        return dataclasses.replace(self, compression=new)

    def with_impl(self, impl: str) -> "GNNConfig":
        """Same model, compression routed through a different kernel backend.

        No-op on an uncompressed config — fp32 baselines stay valid inside
        backend sweeps (there is no compression stack to reroute).
        """
        if self.compression is None:
            return self
        if isinstance(self.compression, CompressionConfig):
            return dataclasses.replace(
                self, compression=self.compression.with_impl(impl))
        return dataclasses.replace(self, compression=tuple(
            None if c is None else c.with_impl(impl)
            for c in self.compression))


def _dims(cfg: GNNConfig, in_dim: int):
    return [in_dim, *cfg.hidden, cfg.n_classes]


def init_gnn_params(key, cfg: GNNConfig, in_dim: int):
    dims = _dims(cfg, in_dim)
    params = []
    for i, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        key, sub = jax.random.split(key)
        fan_in = d_in * (2 if cfg.arch == "sage" else 1)
        w = jax.random.normal(sub, (fan_in, d_out), jnp.float32) / np.sqrt(fan_in)
        params.append({"w": w, "b": jnp.zeros((d_out,), jnp.float32)})
    return params


def _maybe_compressed_matmul(x, w, comp: CompressionConfig | None, seed):
    if comp is None:
        return x @ w
    return compressed_matmul(x, w, seed, comp)


def gnn_forward(params, graph, cfg: GNNConfig, seed=0, dropout_key=None,
                node_mask=None, plan=None, offload=None):
    """graph = (features, src, dst, gcn_w, mean_w).

    ``node_mask`` ((N,) f32, optional) marks valid rows of a padded subgraph
    batch: activations of masked-out rows are pinned to zero after every
    layer, so the compressed stashes (``compressed_matmul`` inputs, ReLU
    sign masks) see clean zeros on padding instead of bias leakage, and
    quantization block statistics stay unpolluted.  ``None`` (full graph)
    is the existing behavior, bit for bit.

    ``plan`` (a :class:`repro.offload.arena.StashPlan`, optional) reroutes
    every layer's saved-for-backward stash through the pooled arena under
    the ``offload`` policy ("device" | "host" | "pinned-paged" — see
    :mod:`repro.offload.engine`); forward values and stash bits are
    identical to the per-tensor path.
    """
    if plan is not None:
        if dropout_key is not None and cfg.dropout:
            raise ValueError("arena-routed forward does not support dropout")
        from repro.engine.forward import arena_gnn_forward

        return arena_gnn_forward(params, graph, cfg, plan, seed=seed,
                                 node_mask=node_mask,
                                 policy=offload or "device")
    feats, src, dst, gcn_w, mean_w = graph
    n = feats.shape[0]  # static under jit
    h = feats if node_mask is None else feats * node_mask[:, None]
    seed = jnp.asarray(seed, jnp.uint32)
    per_layer = cfg.layer_compression()
    for li, p in enumerate(params):
        lseed = layer_seed(seed, li)
        comp = per_layer[li]
        if cfg.arch == "gcn":
            z = _maybe_compressed_matmul(h, p["w"], comp, lseed) + p["b"]
            z = spmm(z, src, dst, gcn_w, n)
        else:  # sage
            agg = spmm(h, src, dst, mean_w, n)
            x = jnp.concatenate([h, agg], axis=1)
            z = _maybe_compressed_matmul(x, p["w"], comp, lseed) + p["b"]
        if li < len(params) - 1:
            z = relu_1bit(z)
            if cfg.dropout and dropout_key is not None:
                dropout_key, sub = jax.random.split(dropout_key)
                keep = jax.random.bernoulli(sub, 1 - cfg.dropout, z.shape)
                z = jnp.where(keep, z / (1 - cfg.dropout), 0.0)
        h = z if node_mask is None else z * node_mask[:, None]
    return h


def graph_tuple(g):
    """Pull the jit-stable array tuple out of a Graph dataclass."""
    return (g.features, g.edge_src, g.edge_dst, g.gcn_weight, g.mean_weight)
