"""Cluster-GCN-style partition sampling: node partitions → padded subgraph
batches with static shapes.

Full-graph training materializes every layer's stash for all N nodes at
once; the paper's block-wise compression shrinks those bytes but cannot
change the O(N) live set.  Mini-batch subgraph training does: partition the
nodes (METIS-free — balanced random or greedy multi-source BFS for
locality), train on one intra-partition subgraph at a time, and only that
partition's activations are ever live.  Each batch runs the exact same
compressed ``custom_vjp`` stack as the full graph.

jit stability: every batch in one call is padded to the *same* static
node/edge counts (max over partitions, rounded up to a bucket multiple), so
``lax.scan`` over stacked batches traces once and ``spmm`` segment-sums /
``compressed_matmul`` stashes never see ragged shapes.  Padding is inert by
construction: pad feature rows are zero, pad edges carry weight 0 and point
at node 0, and pad nodes are excluded from every loss/metric mask — see
``tests/test_gnn_batched.py`` for the zero-gradient proof.

``halo=k`` additionally includes the k-hop in-neighborhood of each
partition (Cluster-GCN's boundary-edge recovery): halo nodes participate in
aggregation but carry no loss (their train/val/test masks are zeroed).
"""
from __future__ import annotations

import collections
import dataclasses
import math

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.data import Graph, in_adjacency


# ------------------------------------------------------------ partitioners
def random_partition(n_nodes: int, n_parts: int, seed: int = 0) -> np.ndarray:
    """Balanced uniform-random node partition: (N,) int part ids, sizes
    differing by at most 1."""
    if not 1 <= n_parts <= n_nodes:
        raise ValueError(f"n_parts={n_parts} must be in [1, {n_nodes}]")
    rng = np.random.default_rng(seed)
    base, extra = divmod(n_nodes, n_parts)
    counts = base + (np.arange(n_parts) < extra)
    part = np.repeat(np.arange(n_parts), counts)
    rng.shuffle(part)
    return part


def bfs_partition(edge_src, edge_dst, n_nodes: int, n_parts: int,
                  seed: int = 0) -> np.ndarray:
    """Greedy multi-source BFS partition (METIS-free locality).

    Grow all parts simultaneously from random seed nodes, always expanding
    the currently-smallest part, each capped at ceil(N/P) nodes; nodes
    unreached by any frontier (disconnected shards) fill the smallest parts.
    Keeps most edges intra-partition on homophilous graphs, which is what
    limits Cluster-GCN's gradient bias.
    """
    if not 1 <= n_parts <= n_nodes:
        raise ValueError(f"n_parts={n_parts} must be in [1, {n_nodes}]")
    src = np.asarray(edge_src)
    dst = np.asarray(edge_dst)
    nbr, starts = in_adjacency(src, dst, n_nodes)
    rng = np.random.default_rng(seed)
    cap = math.ceil(n_nodes / n_parts)
    part = np.full(n_nodes, -1, np.int64)
    sizes = np.zeros(n_parts, np.int64)
    seeds = rng.choice(n_nodes, n_parts, replace=False)
    queues = []
    for p, s in enumerate(seeds):
        part[s] = p
        sizes[p] = 1
        queues.append(collections.deque([int(s)]))
    active = set(range(n_parts))
    while active:
        p = min(active, key=lambda q: sizes[q])
        if not queues[p] or sizes[p] >= cap:
            active.discard(p)
            continue
        u = queues[p].popleft()
        for v in nbr[starts[u]:starts[u + 1]]:
            if part[v] < 0 and sizes[p] < cap:
                part[v] = p
                sizes[p] += 1
                queues[p].append(int(v))
    for v in np.flatnonzero(part < 0):
        p = int(np.argmin(sizes))
        part[v] = p
        sizes[p] += 1
    return part


# ------------------------------------------------------------ batch pytree
_FIELDS = ("features", "labels", "edge_src", "edge_dst", "gcn_weight",
           "mean_weight", "train_mask", "val_mask", "test_mask",
           "node_mask", "n_real_nodes", "n_real_edges")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SubgraphBatch:
    """One padded node-partition subgraph.

    Every field is an array leaf (the real counts included, as scalars) so
    batches stack along a leading axis for ``lax.scan`` epochs and
    data-parallel device sharding.  Local node order is: owned partition
    nodes, then halo nodes, then zero padding; ``node_mask`` marks real
    (owned + halo) rows, while train/val/test masks cover owned rows only.
    """
    features: jnp.ndarray      # (Np, F) f32 — zero on padding rows
    labels: jnp.ndarray        # (Np,) i32 — 0 on padding
    edge_src: jnp.ndarray      # (Ep,) i32 — 0 on padding
    edge_dst: jnp.ndarray      # (Ep,) i32 — 0 on padding
    gcn_weight: jnp.ndarray    # (Ep,) f32 — 0 on padding edges
    mean_weight: jnp.ndarray   # (Ep,) f32 — 0 on padding edges
    train_mask: jnp.ndarray    # (Np,) f32 — owned nodes only
    val_mask: jnp.ndarray      # (Np,) f32
    test_mask: jnp.ndarray     # (Np,) f32
    node_mask: jnp.ndarray     # (Np,) f32 — 1 real (incl. halo), 0 padding
    n_real_nodes: jnp.ndarray  # () i32
    n_real_edges: jnp.ndarray  # () i32

    def tree_flatten(self):
        return tuple(getattr(self, f) for f in _FIELDS), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n_nodes(self) -> int:
        """Padded (static) node count."""
        return int(self.features.shape[0])

    @property
    def n_edges(self) -> int:
        """Padded (static) edge count."""
        return int(self.edge_src.shape[0])

    def graph_tuple(self):
        """The 5-tuple :func:`repro.graph.models.gnn_forward` consumes."""
        return (self.features, self.edge_src, self.edge_dst,
                self.gcn_weight, self.mean_weight)


def stack_batches(batches: list[SubgraphBatch]) -> SubgraphBatch:
    """Stack same-shape batches into one pytree with a leading batch axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)


def group_batches(stacked: SubgraphBatch, order, n_updates: int,
                  grad_accum: int, dp: int) -> SubgraphBatch:
    """Reorder stacked batches and reshape every leaf to the epoch scan's
    update-group layout ``(n_updates, grad_accum, dp, ...)`` — the data
    contract of the engine's partition lowering
    (:class:`repro.engine.compile._CompiledPartition`)."""
    return jax.tree.map(
        lambda x: x[order].reshape(n_updates, grad_accum, dp, *x.shape[1:]),
        stacked)


# ---------------------------------------------------------------- sampler
def _bucket(n: int, multiple: int) -> int:
    return max(multiple, ((n + multiple - 1) // multiple) * multiple)


def make_subgraph_batches(g: Graph, n_parts: int, *, method: str = "bfs",
                          halo: int = 0, seed: int = 0,
                          node_multiple: int = 64, edge_multiple: int = 256,
                          renormalize: bool = False) -> list[SubgraphBatch]:
    """Split ``g`` into ``n_parts`` padded subgraph batches.

    method        "bfs" (greedy multi-source BFS, locality-preserving) or
                  "random" (balanced uniform — Cluster-GCN's stochastic
                  partition baseline).
    halo          hops of in-neighborhood context added around each
                  partition (0 = pure intra-partition edges).
    node/edge_multiple
                  pad buckets: all batches share one static (node, edge)
                  shape, the max real size rounded up to these multiples
                  (1 = tight padding; n_parts=1 with multiples of 1
                  reproduces the full graph exactly).
    renormalize   recompute GCN/mean aggregation weights from *subgraph*
                  degrees (Cluster-GCN's Â normalization) instead of
                  slicing the full-graph weights.  Off by default so
                  n_parts=1 matches full-graph training bit-for-bit.
    """
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    n = g.n_nodes
    if n_parts == 1:
        part = np.zeros(n, np.int64)
    elif method == "random":
        part = random_partition(n, n_parts, seed)
    elif method == "bfs":
        part = bfs_partition(src, dst, n, n_parts, seed)
    else:
        raise ValueError(f"unknown partition method {method!r}")

    feats = np.asarray(g.features)
    labels = np.asarray(g.labels)
    gcn_w = np.asarray(g.gcn_weight)
    mean_w = np.asarray(g.mean_weight)
    masks = {"train": np.asarray(g.train_mask), "val": np.asarray(g.val_mask),
             "test": np.asarray(g.test_mask)}

    raw = []
    for p in range(n_parts):
        owned = np.flatnonzero(part == p)
        in_set = np.zeros(n, bool)
        in_set[owned] = True
        for _ in range(halo):
            in_set[src[in_set[dst]]] = True
        halo_nodes = np.setdiff1d(np.flatnonzero(in_set), owned,
                                  assume_unique=True)
        nodes = np.concatenate([owned, halo_nodes])
        loc = np.full(n, -1, np.int64)
        loc[nodes] = np.arange(len(nodes))
        keep = in_set[src] & in_set[dst]
        s_loc, d_loc = loc[src[keep]], loc[dst[keep]]
        if renormalize:
            deg = np.bincount(d_loc, minlength=len(nodes)).astype(np.float64)
            deg = np.maximum(deg, 1.0)
            gw = 1.0 / np.sqrt(deg[s_loc] * deg[d_loc])
            mw = 1.0 / deg[d_loc]
        else:
            gw, mw = gcn_w[keep], mean_w[keep]
        raw.append((nodes, len(owned), s_loc, d_loc, gw, mw))

    n_pad = _bucket(max(len(r[0]) for r in raw), node_multiple)
    e_pad = _bucket(max(len(r[2]) for r in raw), edge_multiple)

    batches = []
    for nodes, n_owned, s_loc, d_loc, gw, mw in raw:
        nl, el = len(nodes), len(s_loc)
        f = np.zeros((n_pad, feats.shape[1]), np.float32)
        f[:nl] = feats[nodes]
        lab = np.zeros(n_pad, np.int32)
        lab[:nl] = labels[nodes]
        es = np.zeros(e_pad, np.int32)
        ed = np.zeros(e_pad, np.int32)
        ew_g = np.zeros(e_pad, np.float32)
        ew_m = np.zeros(e_pad, np.float32)
        es[:el], ed[:el] = s_loc, d_loc
        ew_g[:el], ew_m[:el] = gw, mw
        node_mask = np.zeros(n_pad, np.float32)
        node_mask[:nl] = 1.0
        owned_rows = np.arange(n_pad) < n_owned
        m = {}
        for k, full in masks.items():
            mk = np.zeros(n_pad, np.float32)
            mk[:nl] = full[nodes].astype(np.float32)
            m[k] = mk * owned_rows
        batches.append(SubgraphBatch(
            features=jnp.asarray(f), labels=jnp.asarray(lab),
            edge_src=jnp.asarray(es), edge_dst=jnp.asarray(ed),
            gcn_weight=jnp.asarray(ew_g), mean_weight=jnp.asarray(ew_m),
            train_mask=jnp.asarray(m["train"]), val_mask=jnp.asarray(m["val"]),
            test_mask=jnp.asarray(m["test"]),
            node_mask=jnp.asarray(node_mask),
            n_real_nodes=jnp.asarray(nl, jnp.int32),
            n_real_edges=jnp.asarray(el, jnp.int32)))
    return batches
