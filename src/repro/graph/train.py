"""GNN training entry points: thin wrappers over the plan-compile-execute
engine (:mod:`repro.engine`).

``train_gnn`` (the paper's full-graph loop, Table 1) and
``train_gnn_batched`` (the partition-sampled mini-batch engine,
Cluster-GCN flavor) keep their pre-engine signatures and bit-exact
trajectories, but no longer own any step construction: each builds an
:class:`~repro.engine.plan.ExecutionPlan` from its kwargs and hands it to
:func:`repro.engine.runner.run`, which compiles ONE jitted epoch step on the
single stash-aware ``custom_vjp`` forward.  ``tests/test_engine.py``
gates the kwarg → plan mapping bit-for-bit against hand-rolled legacy
loops.

``activation_memory_report`` reads the same plan object the engines
execute, so the byte/bit accounting cannot drift from what training
actually stashes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.engine.plan import ExecutionPlan
from repro.graph.data import Graph
from repro.graph.models import GNNConfig, gnn_forward
from repro.graph.sampling import _bucket
from repro.offload import (check_policy, device_resident_stash_bytes,
                           device_memory_stats, measure_live_bytes,
                           plan_gnn_stashes)
from repro.graph.analysis import saved_bytes_per_layer
from repro.optim import AdamWConfig


def _loss_fn(params, graph, labels, mask, cfg, seed, node_mask=None,
             plan=None, offload=None):
    """The training loss at the pre-engine call shape (kept for tests,
    benchmarks, and ad-hoc grads): per-op forward when ``plan`` is None,
    arena-routed engine forward otherwise — both spell the same
    computation the engine's compiled steps run."""
    from repro.engine.compile import masked_nll  # lazy: engine ← graph

    logits = gnn_forward(params, graph, cfg, seed=seed, node_mask=node_mask,
                         plan=plan, offload=offload)
    return masked_nll(logits, labels, mask)


def _accuracy(params, graph, labels, mask, cfg):
    logits = gnn_forward(params, graph, cfg, seed=0)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1)


def train_gnn(g: Graph, cfg: GNNConfig, opt: AdamWConfig | None = None,
              n_epochs: int = 100, seed: int = 0, eval_every: int = 10,
              verbose: bool = False, impl: str | None = None,
              fused: str = "auto",
              bit_budget: float | None = None, autoprec_refresh: int = 0,
              offload: str | None = None):
    """Full-graph training; returns dict(test_acc, val_acc, history,
    epochs_per_sec, params, cfg, plan).

    ``impl`` (optional) reroutes the compression stack onto a specific
    kernel backend for the whole job — "jnp" | "interp" | "pallas" | "auto"
    (see :mod:`repro.core.backend`); codes are bit-identical across impls.
    Ignored when ``cfg.compression`` is None (fp32 baseline).

    ``fused`` ("auto" | "on" | "off") governs the quantize-in-epilogue
    matmul pair (:class:`repro.engine.plan.KernelPolicy`): "auto" fuses
    eligible layers on the real Pallas backend only, "on" forces the
    fused pair everywhere (parity testing), "off" keeps the two-pass
    spelling.

    ``bit_budget`` (optional) turns on variance-guided adaptive precision
    (:mod:`repro.core.autoprec`): the value is the average stash bits per
    element (2.0 = the fixed-INT2 footprint), converted once to a byte
    ceiling and split across layers by minimizing total expected SR
    variance from first-epoch sensitivity stats.  ``autoprec_refresh=k``
    re-collects stats and re-solves every k epochs (0 = allocate once);
    a changed allocation recompiles the plan's step.  The result dict
    then carries ``bits_per_layer`` and ``bit_budget_bytes``.

    ``offload`` (optional) routes every layer's saved-for-backward stash
    through one pooled arena (:mod:`repro.offload`): "device" keeps the
    arena on device, "host"/"pinned-paged" move each layer's segments to
    host after the forward stash and prefetch them one layer ahead of
    the backward walk.  Stash bits and the loss trajectory are identical
    across policies.

    Equivalent plan: ``ExecutionPlan.from_legacy(impl=impl,
    offload=offload, bit_budget=bit_budget,
    autoprec_refresh=autoprec_refresh)`` (full-graph sampling).
    """
    from repro.engine.runner import run

    plan = ExecutionPlan.from_legacy(
        impl=impl, fused=fused, offload=offload, bit_budget=bit_budget,
        autoprec_refresh=autoprec_refresh)
    return run(g, cfg, plan, opt, n_epochs=n_epochs, seed=seed,
               eval_every=eval_every, verbose=verbose)


def train_gnn_batched(g: Graph, cfg: GNNConfig, n_parts: int,
                      opt: AdamWConfig | None = None, n_epochs: int = 100,
                      seed: int = 0, *, method: str = "bfs", halo: int = 0,
                      grad_accum: int = 1, mesh=None, impl: str | None = None,
                      fused: str = "auto",
                      node_multiple: int = 64, edge_multiple: int = 256,
                      renormalize: bool = False, shuffle: bool = True,
                      batches=None, eval_every: int = 10,
                      verbose: bool = False, bit_budget: float | None = None,
                      autoprec_refresh: int = 0, offload: str | None = None):
    """Partition-sampled mini-batch GNN training (Cluster-GCN flavor).

    Splits ``g`` into ``n_parts`` padded subgraph batches (see
    :func:`repro.graph.sampling.make_subgraph_batches` for ``method``,
    ``halo``, bucket multiples, ``renormalize``), then runs one jitted
    epoch step that ``lax.scan``s over per-batch optimizer updates with
    donated params/opt state.  Peak live activation stash is one batch, not
    the whole graph — the regime where the paper's block-wise compression
    matters.

    grad_accum   accumulate gradients over this many consecutive batches
                 per optimizer update.
    mesh         optional jax device mesh: each update consumes
                 ``dp_size(mesh)`` batches in parallel, sharded over the
                 data axes via :func:`repro.parallel.sharding.graph_batch_pspecs`
                 (grads are averaged across the group).  ``n_parts`` must be
                 a multiple of ``dp_size(mesh) * grad_accum``.
    impl         kernel backend override for the compression stack, as in
                 :func:`train_gnn`.
    fused        fused matmul-quant mode ("auto" | "on" | "off"), as in
                 :func:`train_gnn`.
    batches      prebuilt ``SubgraphBatch`` list (skips partitioning —
                 lets benchmarks/tests reuse one sampling pass).
    bit_budget / autoprec_refresh
                 variance-guided adaptive per-layer precision, as in
                 :func:`train_gnn` (budget = average stash bits/element).
                 Sensitivity stats and the byte ceiling are computed on a
                 single padded batch — the engine's live stash unit — so
                 calibration never re-materializes full-graph activations;
                 a refresh that changes the allocation recompiles the step.
    offload      pooled-arena stash routing per batch, as in
                 :func:`train_gnn` ("device" | "host" | "pinned-paged");
                 the plan is laid out for one padded batch — the engine's
                 live stash unit.  Host policies require an unsharded run
                 (``dp_size(mesh) == 1``): the host store is keyed per
                 forward, not per shard.

    Per-batch activation seeds extend the full-graph scheme
    (:mod:`repro.engine.seeds`): batch ordinal ``b = epoch * n_parts +
    position`` gets ``sr_seed = (b + 1) * 7919``, so ``n_parts=1``
    reproduces ``train_gnn`` seeds exactly.

    Evaluation runs full-graph on the final params (the padded batches are
    a *training*-time construct).  Returns the ``train_gnn`` result dict
    plus ``n_parts``, ``updates_per_epoch``, ``batch_nodes``,
    ``batch_edges``.

    Equivalent plan: ``ExecutionPlan.from_legacy(n_parts=n_parts, ...)``
    with every sampling kwarg forwarded.
    """
    from repro.engine.runner import run

    plan = ExecutionPlan.from_legacy(
        n_parts=n_parts, impl=impl, fused=fused, offload=offload,
        bit_budget=bit_budget,
        autoprec_refresh=autoprec_refresh, method=method, halo=halo,
        node_multiple=node_multiple, edge_multiple=edge_multiple,
        renormalize=renormalize, shuffle=shuffle, grad_accum=grad_accum)
    return run(g, cfg, plan, opt, n_epochs=n_epochs, seed=seed,
               eval_every=eval_every, verbose=verbose, batches=batches,
               mesh=mesh)


def train_gnn_mesh(g: Graph, cfg: GNNConfig, n_parts: int,
                   opt: AdamWConfig | None = None, n_epochs: int = 100,
                   seed: int = 0, *, method: str = "bfs", mesh=None,
                   impl: str | None = None, node_multiple: int = 64,
                   edge_multiple: int = 256, eval_every: int = 10,
                   verbose: bool = False):
    """Mesh-sharded partition-parallel GNN training (ISSUE 7 tentpole).

    Shards ``n_parts`` graph partitions over a ``graph`` device mesh axis
    of size ``m`` (``mesh=None`` picks the largest divisor of ``n_parts``
    this host's devices allow) and trains them in ``n_parts // m`` rounds
    per epoch: one ``shard_map``-lowered jitted step per round, a
    per-layer halo exchange (:mod:`repro.parallel.halo`) shipping
    cross-partition boundary activations, per-device block-wise
    compression of *local* activations only, and the full feature matrix
    host-resident behind the double-buffered
    :class:`repro.offload.pager.FeaturePager`.

    Parity gates (``tests/test_parallel.py``): ``n_parts=1`` with exact
    padding is bit-identical to :func:`train_gnn`; any ``n_parts`` on a
    1-device mesh is bit-identical to :func:`train_gnn_batched` with
    ``shuffle=False``; ``m == n_parts`` keeps every edge (exact
    distributed full-graph training, float-tolerance vs single device).

    Returns the engine result dict plus the mesh extras
    (``mesh_devices``, ``halo_width``, ``dropped_edges``,
    ``halo_bytes_per_epoch``, ``pager``).

    Equivalent plan: ``ExecutionPlan(sampling=SamplingPolicy(
    kind="mesh", n_parts=n_parts, method=method, shuffle=False, ...))``.
    """
    from repro.engine.plan import KernelPolicy, SamplingPolicy
    from repro.engine.runner import run

    plan = ExecutionPlan(
        sampling=SamplingPolicy(kind="mesh", n_parts=n_parts,
                                method=method, shuffle=False,
                                node_multiple=node_multiple,
                                edge_multiple=edge_multiple),
        kernel=KernelPolicy(impl=impl))
    return run(g, cfg, plan, opt, n_epochs=n_epochs, seed=seed,
               eval_every=eval_every, verbose=verbose, mesh=mesh)


def activation_memory_report(g: Graph, cfg: GNNConfig, n_parts: int = 1,
                             batch_nodes: int | None = None,
                             node_multiple: int = 64,
                             offload: str | None = None,
                             plan: ExecutionPlan | None = None,
                             quant_health: list | None = None) -> dict:
    """Bytes of *saved-for-backward* activations — the paper's Table-1 "M"
    column model, per layer and (optionally) per subgraph batch.

    Pass the :class:`~repro.engine.plan.ExecutionPlan` the training run
    executed (``result["plan"]``, or the one handed to ``engine.run``) and
    the report models exactly what that plan stashes — sampling decides
    the batched section, the stash policy decides the arena section.  The
    legacy kwargs (``n_parts=``, ``offload=``) remain as a shorthand that
    builds the equivalent plan internally, so the two spellings cannot
    diverge.

    Full-graph keys (always present):

    * ``fp32_bytes`` — f32 input of every linear + f32 ReLU context;
    * ``compressed_bytes`` / ``reduction`` / ``bits_per_layer`` (when any
      layer is compressed) — packed codes + one (zero, range) f32 pair per
      quantization block + word-aligned 1-bit ReLU masks; heterogeneous
      (autoprec) configs report each layer at its own width, and layers
      without compression contribute their fp32 bytes;
    * ``per_layer`` — the same accounting, one dict per GNN layer
      (``layer``, ``fp32_bytes``[, ``compressed_bytes``, ``bits``]).

    With partition sampling (``n_parts > 1`` or a partition plan) the
    mini-batch regime is modeled too: batches run sequentially, so the
    *peak* stash is a single padded batch.  ``batch_nodes`` defaults to
    ceil(N / n_parts) rounded up to the plan's ``node_multiple`` (matching
    ``make_subgraph_batches`` padding); pass the actual padded count
    (the result dict's ``batch_nodes``) when using halo or custom buckets.
    The ``batched`` sub-dict then reports ``peak_fp32_bytes``,
    ``peak_saved_bytes`` (compressed when configured), a per-batch-size
    ``per_layer`` breakdown, and ``peak_reduction_vs_full`` = full-graph
    saved bytes / per-batch peak.

    With an arena stash policy (legacy ``offload=``) an ``arena`` sub-dict
    is added: the pooled-arena ledger from the
    :class:`repro.offload.arena.StashPlan` (``planned_bytes`` split into
    u32/f32 arenas, per-layer rows) plus the *measured* device-peak
    column — ``device_resident_bytes`` is the ledger model of what stays
    on device under the policy (whole arena, or the double-buffered
    two-layer prefetch window for host policies), validated best-effort
    against ``jax.live_arrays`` (``measured_live_bytes``) and the
    backend's device memory stats where the platform exposes them.

    ``quant_health`` attaches the obs telemetry channel's per-layer
    measured-vs-Eq.10 rows (:func:`repro.obs.quantstats.health_rows`, or
    ``result["obs"].quant_rows()``) verbatim under ``"quant_health"`` —
    the byte ledger and the variance ledger of the same run, one report.
    """
    if plan is None:
        plan = ExecutionPlan.from_legacy(
            n_parts=n_parts if n_parts > 1 else None,
            offload=check_policy(offload), node_multiple=node_multiple)
    mesh_kind = plan.sampling.kind == "mesh"
    if plan.sampling.kind in ("partition", "mesh"):
        n_parts = plan.sampling.n_parts
        node_multiple = plan.sampling.node_multiple
    else:
        n_parts = 1
    offload = plan.stash.offload

    per_layer = saved_bytes_per_layer(cfg, g.n_feats, g.n_nodes)
    # mixed precision: a layer without compression contributes fp32 bytes
    has_comp = any("compressed_bytes" in r for r in per_layer)
    total_fp32 = sum(r["fp32_bytes"] for r in per_layer)
    out = {"fp32_bytes": total_fp32, "per_layer": per_layer}
    full_saved = total_fp32
    if has_comp:
        total_c = sum(r.get("compressed_bytes", r["fp32_bytes"])
                      for r in per_layer)
        out["compressed_bytes"] = total_c
        out["reduction"] = 1.0 - total_c / total_fp32
        out["bits_per_layer"] = [r.get("bits") for r in per_layer]
        full_saved = total_c
    if n_parts > 1:
        if batch_nodes is None:
            batch_nodes = _bucket(-(-g.n_nodes // n_parts), node_multiple)
        rows_b = saved_bytes_per_layer(cfg, g.n_feats, batch_nodes)
        peak_fp32 = sum(r["fp32_bytes"] for r in rows_b)
        peak = (sum(r.get("compressed_bytes", r["fp32_bytes"])
                    for r in rows_b)
                if has_comp else peak_fp32)
        key = "mesh" if mesh_kind else "batched"
        out[key] = {
            "n_parts": n_parts, "batch_nodes": batch_nodes,
            "peak_fp32_bytes": peak_fp32, "peak_saved_bytes": peak,
            "full_graph_saved_bytes": full_saved,
            "peak_reduction_vs_full": full_saved / peak,
            "per_layer": rows_b,
        }
        if mesh_kind:
            # per-DEVICE ledger: the mesh forward stashes local rows only
            # (mesh_stash_plan — the halo strip saves nothing), so the
            # per-device peak is the per-partition plan verbatim
            out[key]["per_device_saved_bytes"] = peak
    if offload is not None:
        # an explicit batch_nodes wins even at n_parts == 1: the batched
        # engine pads its single batch, and the ledger must describe the
        # plan training actually laid out
        stash_nodes = batch_nodes if batch_nodes is not None else g.n_nodes
        arena_plan = plan_gnn_stashes(cfg, g.n_feats, stash_nodes)
        stats = device_memory_stats()
        out["arena"] = {
            "policy": offload,
            "stash_nodes": stash_nodes,
            "planned_bytes": arena_plan.total_bytes,
            "u32_bytes": arena_plan.u32_bytes,
            "f32_bytes": arena_plan.f32_bytes,
            "per_layer": arena_plan.per_layer_rows(),
            "device_resident_bytes":
                device_resident_stash_bytes(arena_plan, offload),
            "measured_live_bytes": measure_live_bytes(),
            "device_peak_bytes":
                stats.get("peak_bytes_in_use") if stats else None,
        }
    if quant_health:
        out["quant_health"] = quant_health
    return out
