"""GNN training engines: the paper's full-graph loop (Table 1) and the
partition-sampled mini-batch engine (Cluster-GCN flavor) that opens the
large-graph regime the memory wins actually target.

``train_gnn`` is the original whole-graph ``value_and_grad`` step;
``train_gnn_batched`` scans over padded subgraph batches (built by
:mod:`repro.graph.sampling`) with per-batch activation seeds, optional
gradient accumulation, donated params/opt state, and data-parallel batch
sharding over a device mesh — the same shape as
:func:`repro.launch.steps.make_train_step`.  ``n_parts=1`` is the
full-graph special case and reproduces ``train_gnn`` results.
"""
from __future__ import annotations

import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.compressor import CompressionConfig
from repro.graph.analysis import saved_bytes_per_layer
from repro.graph.data import Graph
from repro.graph.models import GNNConfig, gnn_forward, graph_tuple, init_gnn_params
from repro.graph.sampling import _bucket, make_subgraph_batches, stack_batches
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import dp_size, graph_batch_pspecs, to_named


def _loss_fn(params, graph, labels, mask, cfg, seed, node_mask=None):
    logits = gnn_forward(params, graph, cfg, seed=seed, node_mask=node_mask)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def _accuracy(params, graph, labels, mask, cfg):
    logits = gnn_forward(params, graph, cfg, seed=0)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1)


def _result(eval_fn, params, g, gt, history, n_epochs, dt, **extra):
    """Final full-graph val/test metrics + the shared engine result dict
    (both training engines report through this one contract)."""
    val = float(eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32)))
    test = float(eval_fn(params, gt, g.labels, g.test_mask.astype(jnp.float32)))
    return {"test_acc": test, "val_acc": val, "history": history,
            "epochs_per_sec": n_epochs / dt, "params": params, **extra}


def train_gnn(g: Graph, cfg: GNNConfig, opt: AdamWConfig | None = None,
              n_epochs: int = 100, seed: int = 0, eval_every: int = 10,
              verbose: bool = False, impl: str | None = None):
    """Returns dict(test_acc, val_acc, history, epochs_per_sec, params).

    ``impl`` (optional) reroutes the compression stack onto a specific
    kernel backend for the whole job — "jnp" | "interp" | "pallas" | "auto"
    (see :mod:`repro.core.backend`); codes are bit-identical across impls.
    Ignored when ``cfg.compression`` is None (fp32 baseline).
    """
    if impl is not None:
        cfg = cfg.with_impl(impl)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    gt = graph_tuple(g)
    tr_mask = g.train_mask.astype(jnp.float32)

    @partial(jax.jit, donate_argnums=(0, 1), static_argnames=())
    def step(params, state, epoch, gt, labels, tr_mask):
        sr_seed = (epoch + 1).astype(jnp.uint32) * jnp.uint32(7919)
        loss, grads = jax.value_and_grad(_loss_fn)(
            params, gt, labels, tr_mask, cfg, sr_seed)
        params, state = adamw_update(grads, state, params, opt)
        return params, state, loss

    eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
    history = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        params, state, loss = step(params, state, jnp.asarray(epoch), gt,
                                   g.labels, tr_mask)
        if verbose and (epoch % eval_every == 0 or epoch == n_epochs - 1):
            va = eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32))
            history.append((epoch, float(loss), float(va)))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    return _result(eval_fn, params, g, gt, history, n_epochs, dt)


def train_gnn_batched(g: Graph, cfg: GNNConfig, n_parts: int,
                      opt: AdamWConfig | None = None, n_epochs: int = 100,
                      seed: int = 0, *, method: str = "bfs", halo: int = 0,
                      grad_accum: int = 1, mesh=None, impl: str | None = None,
                      node_multiple: int = 64, edge_multiple: int = 256,
                      renormalize: bool = False, shuffle: bool = True,
                      batches=None, eval_every: int = 10,
                      verbose: bool = False):
    """Partition-sampled mini-batch GNN training (Cluster-GCN flavor).

    Splits ``g`` into ``n_parts`` padded subgraph batches (see
    :func:`repro.graph.sampling.make_subgraph_batches` for ``method``,
    ``halo``, bucket multiples, ``renormalize``), then runs one jitted
    epoch step that ``lax.scan``s over per-batch optimizer updates with
    donated params/opt state.  Peak live activation stash is one batch, not
    the whole graph — the regime where the paper's block-wise compression
    matters.

    grad_accum   accumulate gradients over this many consecutive batches
                 per optimizer update (make_train_step's scheme).
    mesh         optional jax device mesh: each update consumes
                 ``dp_size(mesh)`` batches in parallel, sharded over the
                 data axes via :func:`repro.parallel.sharding.graph_batch_pspecs`
                 (grads are averaged across the group).  ``n_parts`` must be
                 a multiple of ``dp_size(mesh) * grad_accum``.
    impl         kernel backend override for the compression stack, as in
                 :func:`train_gnn`.
    batches      prebuilt ``SubgraphBatch`` list (skips partitioning —
                 lets benchmarks/tests reuse one sampling pass).

    Per-batch activation seeds extend the full-graph scheme: batch ordinal
    ``b = epoch * n_parts + position`` gets ``sr_seed = (b + 1) * 7919``,
    so ``n_parts=1`` reproduces ``train_gnn`` seeds exactly.

    Evaluation runs full-graph on the final params (the padded batches are
    a *training*-time construct).  Returns the ``train_gnn`` result dict
    plus ``n_parts``, ``updates_per_epoch``, ``batch_nodes``,
    ``batch_edges``.
    """
    if impl is not None:
        cfg = cfg.with_impl(impl)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    if batches is None:
        batches = make_subgraph_batches(
            g, n_parts, method=method, halo=halo, seed=seed,
            node_multiple=node_multiple, edge_multiple=edge_multiple,
            renormalize=renormalize)
    elif len(batches) != n_parts:
        raise ValueError(f"prebuilt batches list has {len(batches)} entries "
                         f"but n_parts={n_parts}")
    n_batches = len(batches)
    dp = dp_size(mesh) if mesh is not None else 1
    group = dp * grad_accum
    if n_batches % group:
        raise ValueError(
            f"n_parts={n_batches} must be a multiple of dp*grad_accum="
            f"{dp}*{grad_accum}={group} (whole update groups per epoch)")
    n_updates = n_batches // group

    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    stacked = stack_batches(batches)

    @partial(jax.jit, donate_argnums=(0, 1))
    def epoch_step(params, state, epoch, grouped):
        # grouped leaves: (n_updates, grad_accum, dp, ...)
        def update(carry, inp):
            params, state = carry
            u, grp = inp
            base = epoch * n_batches + u * group

            def micro(gsum, inp2):
                a, mb = inp2
                ords = base + a * dp + jnp.arange(dp)
                seeds = (ords + 1).astype(jnp.uint32) * jnp.uint32(7919)

                def group_loss(p):
                    losses = jax.vmap(
                        lambda b, s: _loss_fn(p, b.graph_tuple(), b.labels,
                                              b.train_mask, cfg, s,
                                              node_mask=b.node_mask)
                    )(mb, seeds)
                    return losses.mean()

                loss, grads = jax.value_and_grad(group_loss)(params)
                return jax.tree.map(jnp.add, gsum, grads), loss

            zeros = jax.tree.map(jnp.zeros_like, params)
            gsum, losses = jax.lax.scan(
                micro, zeros, (jnp.arange(grad_accum), grp))
            grads = jax.tree.map(lambda x: x / grad_accum, gsum)
            params, state = adamw_update(grads, state, params, opt)
            return (params, state), losses.mean()

        (params, state), losses = jax.lax.scan(
            update, (params, state), (jnp.arange(n_updates), grouped))
        return params, state, losses.mean()

    eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
    gt = graph_tuple(g)
    order_rng = np.random.default_rng(seed ^ 0x5EEDBA5E)

    def make_grouped(order):
        grouped = jax.tree.map(
            lambda x: x[order].reshape(n_updates, grad_accum, dp,
                                       *x.shape[1:]), stacked)
        if mesh is not None:
            specs = graph_batch_pspecs(grouped, mesh, axis=2)
            grouped = jax.device_put(grouped, to_named(specs, mesh))
        return grouped

    reshuffle = shuffle and n_batches > 1
    grouped = None if reshuffle else make_grouped(np.arange(n_batches))
    history = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        if reshuffle:
            grouped = make_grouped(order_rng.permutation(n_batches))
        params, state, loss = epoch_step(params, state, jnp.asarray(epoch),
                                         grouped)
        if verbose and (epoch % eval_every == 0 or epoch == n_epochs - 1):
            va = eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32))
            history.append((epoch, float(loss), float(va)))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    return _result(eval_fn, params, g, gt, history, n_epochs, dt,
                   n_parts=n_batches, updates_per_epoch=n_updates,
                   batch_nodes=batches[0].n_nodes,
                   batch_edges=batches[0].n_edges)


def activation_memory_report(g: Graph, cfg: GNNConfig, n_parts: int = 1,
                             batch_nodes: int | None = None,
                             node_multiple: int = 64) -> dict:
    """Bytes of *saved-for-backward* activations — the paper's Table-1 "M"
    column model, per layer and (optionally) per subgraph batch.

    Full-graph keys (always present):

    * ``fp32_bytes`` — f32 input of every linear + f32 ReLU context;
    * ``compressed_bytes`` / ``reduction`` (when ``cfg.compression`` is
      set) — packed codes + one (zero, range) f32 pair per quantization
      block + 1-bit ReLU masks;
    * ``per_layer`` — the same accounting, one dict per GNN layer
      (``layer``, ``fp32_bytes``[, ``compressed_bytes``]).

    With ``n_parts > 1`` the mini-batch regime is modeled too: batches run
    sequentially, so the *peak* stash is a single padded batch.
    ``batch_nodes`` defaults to ceil(N / n_parts) rounded up to
    ``node_multiple`` (matching ``make_subgraph_batches`` padding); pass
    the actual padded count (``train_gnn_batched``'s ``batch_nodes``) when
    using halo or custom buckets.  The ``batched`` sub-dict then reports
    ``peak_fp32_bytes``, ``peak_saved_bytes`` (compressed when configured),
    a per-batch-size ``per_layer`` breakdown, and
    ``peak_reduction_vs_full`` = full-graph saved bytes / per-batch peak.
    """
    per_layer = saved_bytes_per_layer(cfg, g.n_feats, g.n_nodes)
    comp = cfg.compression
    total_fp32 = sum(r["fp32_bytes"] for r in per_layer)
    out = {"fp32_bytes": total_fp32, "per_layer": per_layer}
    full_saved = total_fp32
    if comp is not None:
        total_c = sum(r["compressed_bytes"] for r in per_layer)
        out["compressed_bytes"] = total_c
        out["reduction"] = 1.0 - total_c / total_fp32
        full_saved = total_c
    if n_parts > 1:
        if batch_nodes is None:
            batch_nodes = _bucket(-(-g.n_nodes // n_parts), node_multiple)
        rows_b = saved_bytes_per_layer(cfg, g.n_feats, batch_nodes)
        peak_fp32 = sum(r["fp32_bytes"] for r in rows_b)
        peak = (sum(r["compressed_bytes"] for r in rows_b)
                if comp is not None else peak_fp32)
        out["batched"] = {
            "n_parts": n_parts, "batch_nodes": batch_nodes,
            "peak_fp32_bytes": peak_fp32, "peak_saved_bytes": peak,
            "full_graph_saved_bytes": full_saved,
            "peak_reduction_vs_full": full_saved / peak,
            "per_layer": rows_b,
        }
    return out
