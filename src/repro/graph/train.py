"""GNN training engines: the paper's full-graph loop (Table 1) and the
partition-sampled mini-batch engine (Cluster-GCN flavor) that opens the
large-graph regime the memory wins actually target.

``train_gnn`` is the original whole-graph ``value_and_grad`` step;
``train_gnn_batched`` scans over padded subgraph batches (built by
:mod:`repro.graph.sampling`) with per-batch activation seeds, optional
gradient accumulation, donated params/opt state, and data-parallel batch
sharding over a device mesh — the same shape as
:func:`repro.launch.steps.make_train_step`.  ``n_parts=1`` is the
full-graph special case and reproduces ``train_gnn`` results.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import autoprec
from repro.core.compressor import CompressionConfig
from repro.offload import (check_policy, device_resident_stash_bytes,
                           device_memory_stats, measure_live_bytes,
                           plan_gnn_stashes)
from repro.graph.analysis import collect_layer_stats, saved_bytes_per_layer
from repro.graph.data import Graph
from repro.graph.models import GNNConfig, gnn_forward, graph_tuple, init_gnn_params
from repro.graph.sampling import _bucket, make_subgraph_batches, stack_batches
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.parallel.sharding import dp_size, graph_batch_pspecs, to_named


def _loss_fn(params, graph, labels, mask, cfg, seed, node_mask=None,
             plan=None, offload=None):
    logits = gnn_forward(params, graph, cfg, seed=seed, node_mask=node_mask,
                         plan=plan, offload=offload)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def _accuracy(params, graph, labels, mask, cfg):
    logits = gnn_forward(params, graph, cfg, seed=0)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1)


class _Autoprec:
    """Variance-guided bit-allocation lifecycle shared by both engines.

    Owns the budget (frozen on the first allocation so refreshes re-split
    the *same* byte ceiling), the current per-layer widths, and the refresh
    cadence.  ``allocate`` runs the cheap stats pass on the calibration
    graph it was given — the full graph for ``train_gnn``, a single padded
    subgraph batch for ``train_gnn_batched`` (so the probe never
    re-materializes the full-graph activations the batched engine exists
    to avoid; per-layer moments and noise ratios are scale-invariant) —
    and calibrates each layer's ``grad_sens`` with a two-seed gradient
    probe: ``dx`` and the ReLU mask are SR-noise-free, so
    ``dw_l(s₁) − dw_l(s₂)`` isolates exactly the dequantization noise
    layer l's stash injects.
    """

    def __init__(self, gt, labels, tr_mask, cfg: GNNConfig,
                 bit_budget: float, refresh: int, seed: int, node_mask=None):
        self.templates = cfg.layer_compression()
        if all(c is None for c in self.templates):
            raise ValueError(
                "bit_budget= needs a GNNConfig with compression configured")
        self.base_cfg = cfg
        self.bit_budget = float(bit_budget)
        self.refresh = int(refresh)
        self.gt = gt
        self.labels = labels
        self.tr_mask = tr_mask
        self.node_mask = node_mask
        self.seed = seed
        self.budget_bytes = None
        self.bits: tuple[int, ...] | None = None
        self._grad_fn = jax.jit(jax.grad(_loss_fn), static_argnums=(4,))

    def _probe_grad_sens(self, params, stats):
        """Realized per-layer dw SR noise at template widths, divided by the
        bit-scaling curve — so any candidate width re-prices as
        ``grad_sens * normalized_sr_variance(candidate)``."""
        s1, s2 = (jnp.uint32((self.seed * 2654435761 + 101) & 0xFFFF_FFFF),
                  jnp.uint32((self.seed * 2654435761 + 211) & 0xFFFF_FFFF))
        g1 = self._grad_fn(params, self.gt, self.labels, self.tr_mask,
                           self.base_cfg, s1, self.node_mask)
        g2 = self._grad_fn(params, self.gt, self.labels, self.tr_mask,
                           self.base_cfg, s2, self.node_mask)
        out = []
        for st, tmpl, p1, p2 in zip(stats, self.templates, g1, g2):
            if st is None or tmpl is None:
                out.append(st)
                continue
            noise = float(0.5 * jnp.sum((p1["w"] - p2["w"]) ** 2))
            sens = noise / max(autoprec.normalized_sr_variance(tmpl), 1e-30)
            # a zero probe (e.g. untrained head with zero grads) keeps the
            # range-moment fallback rather than marking the layer free
            out.append(dataclasses.replace(st, grad_sens=sens or None))
        return out

    def allocate(self, params) -> tuple[GNNConfig, bool]:
        """(re)solve the allocation; returns (cfg, changed)."""
        stats = collect_layer_stats(params, self.gt, self.base_cfg,
                                    seed=self.seed)
        if self.budget_bytes is None:
            self.budget_bytes = autoprec.budget_bytes_for(
                stats, self.templates, self.bit_budget)
        stats = self._probe_grad_sens(params, stats)
        bits = autoprec.allocate_bits(stats, self.templates,
                                      self.budget_bytes)
        changed = bits != self.bits
        self.bits = bits
        return self.base_cfg.with_layer_bits(bits), changed

    def due(self, epoch: int) -> bool:
        return self.refresh > 0 and epoch > 0 and epoch % self.refresh == 0

    def extras(self) -> dict:
        return {"bits_per_layer": list(self.bits),
                "bit_budget_bytes": self.budget_bytes}


def _result(eval_fn, params, g, gt, history, n_epochs, dt, **extra):
    """Final full-graph val/test metrics + the shared engine result dict
    (both training engines report through this one contract)."""
    val = float(eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32)))
    test = float(eval_fn(params, gt, g.labels, g.test_mask.astype(jnp.float32)))
    return {"test_acc": test, "val_acc": val, "history": history,
            "epochs_per_sec": n_epochs / dt, "params": params, **extra}


def train_gnn(g: Graph, cfg: GNNConfig, opt: AdamWConfig | None = None,
              n_epochs: int = 100, seed: int = 0, eval_every: int = 10,
              verbose: bool = False, impl: str | None = None,
              bit_budget: float | None = None, autoprec_refresh: int = 0,
              offload: str | None = None):
    """Returns dict(test_acc, val_acc, history, epochs_per_sec, params).

    ``impl`` (optional) reroutes the compression stack onto a specific
    kernel backend for the whole job — "jnp" | "interp" | "pallas" | "auto"
    (see :mod:`repro.core.backend`); codes are bit-identical across impls.
    Ignored when ``cfg.compression`` is None (fp32 baseline).

    ``bit_budget`` (optional) turns on variance-guided adaptive precision
    (:mod:`repro.core.autoprec`): the value is the average stash bits per
    element (2.0 = the fixed-INT2 footprint), converted once to a byte
    ceiling and split across layers by minimizing total expected SR
    variance from first-epoch sensitivity stats.  ``autoprec_refresh=k``
    re-collects stats and re-solves every k epochs (0 = allocate once);
    a changed allocation re-jits the step.  The result dict then carries
    ``bits_per_layer`` and ``bit_budget_bytes``.

    ``offload`` (optional) routes every layer's saved-for-backward stash
    through one pooled arena (:mod:`repro.offload`): "device" keeps the
    arena on device, "host"/"pinned-paged" move each layer's segments to
    host after the forward stash and prefetch them one layer ahead of
    the backward walk.  Stash bits and the loss trajectory are identical
    across policies.
    """
    offload = check_policy(offload)
    if impl is not None:
        cfg = cfg.with_impl(impl)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    gt = graph_tuple(g)
    tr_mask = g.train_mask.astype(jnp.float32)

    ap = None
    if bit_budget is not None:
        ap = _Autoprec(gt, g.labels, tr_mask, cfg, bit_budget,
                       autoprec_refresh, seed)
        cfg, _ = ap.allocate(params)

    def make_step(cfg):
        plan = (plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
                if offload is not None else None)
        loss_fn = partial(_loss_fn, plan=plan, offload=offload)

        @partial(jax.jit, donate_argnums=(0, 1), static_argnames=())
        def step(params, state, epoch, gt, labels, tr_mask):
            sr_seed = (epoch + 1).astype(jnp.uint32) * jnp.uint32(7919)
            loss, grads = jax.value_and_grad(loss_fn)(
                params, gt, labels, tr_mask, cfg, sr_seed)
            params, state = adamw_update(grads, state, params, opt)
            return params, state, loss
        return step

    step = make_step(cfg)
    eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
    history = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        if ap is not None and ap.due(epoch):
            cfg, changed = ap.allocate(params)
            if changed:
                step = make_step(cfg)
        params, state, loss = step(params, state, jnp.asarray(epoch), gt,
                                   g.labels, tr_mask)
        if verbose and (epoch % eval_every == 0 or epoch == n_epochs - 1):
            va = eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32))
            history.append((epoch, float(loss), float(va)))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    extra = ap.extras() if ap is not None else {}
    extra["cfg"] = cfg
    return _result(eval_fn, params, g, gt, history, n_epochs, dt, **extra)


def train_gnn_batched(g: Graph, cfg: GNNConfig, n_parts: int,
                      opt: AdamWConfig | None = None, n_epochs: int = 100,
                      seed: int = 0, *, method: str = "bfs", halo: int = 0,
                      grad_accum: int = 1, mesh=None, impl: str | None = None,
                      node_multiple: int = 64, edge_multiple: int = 256,
                      renormalize: bool = False, shuffle: bool = True,
                      batches=None, eval_every: int = 10,
                      verbose: bool = False, bit_budget: float | None = None,
                      autoprec_refresh: int = 0, offload: str | None = None):
    """Partition-sampled mini-batch GNN training (Cluster-GCN flavor).

    Splits ``g`` into ``n_parts`` padded subgraph batches (see
    :func:`repro.graph.sampling.make_subgraph_batches` for ``method``,
    ``halo``, bucket multiples, ``renormalize``), then runs one jitted
    epoch step that ``lax.scan``s over per-batch optimizer updates with
    donated params/opt state.  Peak live activation stash is one batch, not
    the whole graph — the regime where the paper's block-wise compression
    matters.

    grad_accum   accumulate gradients over this many consecutive batches
                 per optimizer update (make_train_step's scheme).
    mesh         optional jax device mesh: each update consumes
                 ``dp_size(mesh)`` batches in parallel, sharded over the
                 data axes via :func:`repro.parallel.sharding.graph_batch_pspecs`
                 (grads are averaged across the group).  ``n_parts`` must be
                 a multiple of ``dp_size(mesh) * grad_accum``.
    impl         kernel backend override for the compression stack, as in
                 :func:`train_gnn`.
    batches      prebuilt ``SubgraphBatch`` list (skips partitioning —
                 lets benchmarks/tests reuse one sampling pass).
    bit_budget / autoprec_refresh
                 variance-guided adaptive per-layer precision, as in
                 :func:`train_gnn` (budget = average stash bits/element).
                 Sensitivity stats and the byte ceiling are computed on a
                 single padded batch — the engine's live stash unit — so
                 calibration never re-materializes full-graph activations;
                 a refresh that changes the allocation re-jits the epoch.
    offload      pooled-arena stash routing per batch, as in
                 :func:`train_gnn` ("device" | "host" | "pinned-paged");
                 the plan is laid out for one padded batch — the engine's
                 live stash unit.  Host policies require an unsharded run
                 (``dp_size(mesh) == 1``): the host store is keyed per
                 forward, not per shard.

    Per-batch activation seeds extend the full-graph scheme: batch ordinal
    ``b = epoch * n_parts + position`` gets ``sr_seed = (b + 1) * 7919``,
    so ``n_parts=1`` reproduces ``train_gnn`` seeds exactly.

    Evaluation runs full-graph on the final params (the padded batches are
    a *training*-time construct).  Returns the ``train_gnn`` result dict
    plus ``n_parts``, ``updates_per_epoch``, ``batch_nodes``,
    ``batch_edges``.
    """
    offload = check_policy(offload)
    if impl is not None:
        cfg = cfg.with_impl(impl)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    if batches is None:
        batches = make_subgraph_batches(
            g, n_parts, method=method, halo=halo, seed=seed,
            node_multiple=node_multiple, edge_multiple=edge_multiple,
            renormalize=renormalize)
    elif len(batches) != n_parts:
        raise ValueError(f"prebuilt batches list has {len(batches)} entries "
                         f"but n_parts={n_parts}")
    n_batches = len(batches)
    dp = dp_size(mesh) if mesh is not None else 1
    if offload in ("host", "pinned-paged") and dp > 1:
        raise ValueError(
            f"offload={offload!r} needs an unsharded run (dp_size==1); "
            f"got dp={dp}")
    group = dp * grad_accum
    if n_batches % group:
        raise ValueError(
            f"n_parts={n_batches} must be a multiple of dp*grad_accum="
            f"{dp}*{grad_accum}={group} (whole update groups per epoch)")
    n_updates = n_batches // group

    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    stacked = stack_batches(batches)

    ap = None
    if bit_budget is not None:
        # calibrate on one padded batch — the batched engine's live stash
        # unit — so the probe never re-materializes full-graph activations
        # (the budget is therefore per batch, matching the actual peak)
        b0 = batches[0]
        ap = _Autoprec(b0.graph_tuple(), b0.labels, b0.train_mask, cfg,
                       bit_budget, autoprec_refresh, seed,
                       node_mask=b0.node_mask)
        cfg, _ = ap.allocate(params)

    def make_epoch_step(cfg):
        plan = (plan_gnn_stashes(cfg, g.n_feats, batches[0].n_nodes)
                if offload is not None else None)

        @partial(jax.jit, donate_argnums=(0, 1))
        def epoch_step(params, state, epoch, grouped):
            # grouped leaves: (n_updates, grad_accum, dp, ...)
            def update(carry, inp):
                params, state = carry
                u, grp = inp
                base = epoch * n_batches + u * group

                def micro(gsum, inp2):
                    a, mb = inp2
                    ords = base + a * dp + jnp.arange(dp)
                    seeds = (ords + 1).astype(jnp.uint32) * jnp.uint32(7919)

                    def group_loss(p):
                        losses = jax.vmap(
                            lambda b, s: _loss_fn(p, b.graph_tuple(),
                                                  b.labels,
                                                  b.train_mask, cfg, s,
                                                  node_mask=b.node_mask,
                                                  plan=plan, offload=offload)
                        )(mb, seeds)
                        return losses.mean()

                    loss, grads = jax.value_and_grad(group_loss)(params)
                    return jax.tree.map(jnp.add, gsum, grads), loss

                zeros = jax.tree.map(jnp.zeros_like, params)
                gsum, losses = jax.lax.scan(
                    micro, zeros, (jnp.arange(grad_accum), grp))
                grads = jax.tree.map(lambda x: x / grad_accum, gsum)
                params, state = adamw_update(grads, state, params, opt)
                return (params, state), losses.mean()

            (params, state), losses = jax.lax.scan(
                update, (params, state), (jnp.arange(n_updates), grouped))
            return params, state, losses.mean()
        return epoch_step

    epoch_step = make_epoch_step(cfg)
    eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
    gt = graph_tuple(g)
    order_rng = np.random.default_rng(seed ^ 0x5EEDBA5E)

    def make_grouped(order):
        grouped = jax.tree.map(
            lambda x: x[order].reshape(n_updates, grad_accum, dp,
                                       *x.shape[1:]), stacked)
        if mesh is not None:
            specs = graph_batch_pspecs(grouped, mesh, axis=2)
            grouped = jax.device_put(grouped, to_named(specs, mesh))
        return grouped

    reshuffle = shuffle and n_batches > 1
    grouped = None if reshuffle else make_grouped(np.arange(n_batches))
    history = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        if ap is not None and ap.due(epoch):
            cfg, changed = ap.allocate(params)
            if changed:
                epoch_step = make_epoch_step(cfg)
        if reshuffle:
            grouped = make_grouped(order_rng.permutation(n_batches))
        params, state, loss = epoch_step(params, state, jnp.asarray(epoch),
                                         grouped)
        if verbose and (epoch % eval_every == 0 or epoch == n_epochs - 1):
            va = eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32))
            history.append((epoch, float(loss), float(va)))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0
    extra = ap.extras() if ap is not None else {}
    return _result(eval_fn, params, g, gt, history, n_epochs, dt,
                   n_parts=n_batches, updates_per_epoch=n_updates,
                   batch_nodes=batches[0].n_nodes,
                   batch_edges=batches[0].n_edges, cfg=cfg, **extra)


def activation_memory_report(g: Graph, cfg: GNNConfig, n_parts: int = 1,
                             batch_nodes: int | None = None,
                             node_multiple: int = 64,
                             offload: str | None = None) -> dict:
    """Bytes of *saved-for-backward* activations — the paper's Table-1 "M"
    column model, per layer and (optionally) per subgraph batch.

    Full-graph keys (always present):

    * ``fp32_bytes`` — f32 input of every linear + f32 ReLU context;
    * ``compressed_bytes`` / ``reduction`` / ``bits_per_layer`` (when any
      layer is compressed) — packed codes + one (zero, range) f32 pair per
      quantization block + word-aligned 1-bit ReLU masks; heterogeneous
      (autoprec) configs report each layer at its own width, and layers
      without compression contribute their fp32 bytes;
    * ``per_layer`` — the same accounting, one dict per GNN layer
      (``layer``, ``fp32_bytes``[, ``compressed_bytes``, ``bits``]).

    With ``n_parts > 1`` the mini-batch regime is modeled too: batches run
    sequentially, so the *peak* stash is a single padded batch.
    ``batch_nodes`` defaults to ceil(N / n_parts) rounded up to
    ``node_multiple`` (matching ``make_subgraph_batches`` padding); pass
    the actual padded count (``train_gnn_batched``'s ``batch_nodes``) when
    using halo or custom buckets.  The ``batched`` sub-dict then reports
    ``peak_fp32_bytes``, ``peak_saved_bytes`` (compressed when configured),
    a per-batch-size ``per_layer`` breakdown, and
    ``peak_reduction_vs_full`` = full-graph saved bytes / per-batch peak.

    With ``offload`` set ("device" | "host" | "pinned-paged") an ``arena``
    sub-dict is added: the pooled-arena ledger from the
    :class:`repro.offload.arena.StashPlan` (``planned_bytes`` split into
    u32/f32 arenas, per-layer rows) plus the *measured* device-peak
    column — ``device_resident_bytes`` is the ledger model of what stays
    on device under the policy (whole arena, or the double-buffered
    two-layer prefetch window for host policies), validated best-effort
    against ``jax.live_arrays`` (``measured_live_bytes``) and the
    backend's device memory stats where the platform exposes them.
    """
    per_layer = saved_bytes_per_layer(cfg, g.n_feats, g.n_nodes)
    # mixed precision: a layer without compression contributes fp32 bytes
    has_comp = any("compressed_bytes" in r for r in per_layer)
    total_fp32 = sum(r["fp32_bytes"] for r in per_layer)
    out = {"fp32_bytes": total_fp32, "per_layer": per_layer}
    full_saved = total_fp32
    if has_comp:
        total_c = sum(r.get("compressed_bytes", r["fp32_bytes"])
                      for r in per_layer)
        out["compressed_bytes"] = total_c
        out["reduction"] = 1.0 - total_c / total_fp32
        out["bits_per_layer"] = [r.get("bits") for r in per_layer]
        full_saved = total_c
    if n_parts > 1:
        if batch_nodes is None:
            batch_nodes = _bucket(-(-g.n_nodes // n_parts), node_multiple)
        rows_b = saved_bytes_per_layer(cfg, g.n_feats, batch_nodes)
        peak_fp32 = sum(r["fp32_bytes"] for r in rows_b)
        peak = (sum(r.get("compressed_bytes", r["fp32_bytes"])
                    for r in rows_b)
                if has_comp else peak_fp32)
        out["batched"] = {
            "n_parts": n_parts, "batch_nodes": batch_nodes,
            "peak_fp32_bytes": peak_fp32, "peak_saved_bytes": peak,
            "full_graph_saved_bytes": full_saved,
            "peak_reduction_vs_full": full_saved / peak,
            "per_layer": rows_b,
        }
    if offload is not None:
        offload = check_policy(offload)
        # an explicit batch_nodes wins even at n_parts == 1: the batched
        # engine pads its single batch, and the ledger must describe the
        # plan training actually laid out
        stash_nodes = batch_nodes if batch_nodes is not None else g.n_nodes
        plan = plan_gnn_stashes(cfg, g.n_feats, stash_nodes)
        stats = device_memory_stats()
        out["arena"] = {
            "policy": offload,
            "stash_nodes": stash_nodes,
            "planned_bytes": plan.total_bytes,
            "u32_bytes": plan.u32_bytes,
            "f32_bytes": plan.f32_bytes,
            "per_layer": plan.per_layer_rows(),
            "device_resident_bytes":
                device_resident_stash_bytes(plan, offload),
            "measured_live_bytes": measure_live_bytes(),
            "device_peak_bytes":
                stats.get("peak_bytes_in_use") if stats else None,
        }
    return out
