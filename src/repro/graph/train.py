"""Full-graph training loop for the paper's experiments (Table 1)."""
from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core.compressor import CompressionConfig
from repro.graph.data import Graph
from repro.graph.models import GNNConfig, _dims, gnn_forward, graph_tuple, init_gnn_params
from repro.optim import AdamWConfig, adamw_init, adamw_update


def _loss_fn(params, graph, labels, mask, cfg, seed):
    logits = gnn_forward(params, graph, cfg, seed=seed)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def _accuracy(params, graph, labels, mask, cfg):
    logits = gnn_forward(params, graph, cfg, seed=0)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1)


def train_gnn(g: Graph, cfg: GNNConfig, opt: AdamWConfig | None = None,
              n_epochs: int = 100, seed: int = 0, eval_every: int = 10,
              verbose: bool = False, impl: str | None = None):
    """Returns dict(test_acc, val_acc, history, epochs_per_sec, params).

    ``impl`` (optional) reroutes the compression stack onto a specific
    kernel backend for the whole job — "jnp" | "interp" | "pallas" | "auto"
    (see :mod:`repro.core.backend`); codes are bit-identical across impls.
    Ignored when ``cfg.compression`` is None (fp32 baseline).
    """
    if impl is not None:
        cfg = cfg.with_impl(impl)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    gt = graph_tuple(g)
    tr_mask = g.train_mask.astype(jnp.float32)

    @partial(jax.jit, donate_argnums=(0, 1), static_argnames=())
    def step(params, state, epoch, gt, labels, tr_mask):
        sr_seed = (epoch + 1).astype(jnp.uint32) * jnp.uint32(7919)
        loss, grads = jax.value_and_grad(_loss_fn)(
            params, gt, labels, tr_mask, cfg, sr_seed)
        params, state = adamw_update(grads, state, params, opt)
        return params, state, loss

    eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
    history = []
    t0 = time.perf_counter()
    for epoch in range(n_epochs):
        params, state, loss = step(params, state, jnp.asarray(epoch), gt,
                                   g.labels, tr_mask)
        if verbose and (epoch % eval_every == 0 or epoch == n_epochs - 1):
            va = eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32))
            history.append((epoch, float(loss), float(va)))
    jax.block_until_ready(params)
    dt = time.perf_counter() - t0

    val = float(eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32)))
    test = float(eval_fn(params, gt, g.labels, g.test_mask.astype(jnp.float32)))
    return {
        "test_acc": test, "val_acc": val, "history": history,
        "epochs_per_sec": n_epochs / dt, "params": params,
    }


def activation_memory_report(g: Graph, cfg: GNNConfig) -> dict:
    """Bytes of *saved-for-backward* activations per configuration — the
    paper's Table 1 "M" column model.

    FP32 baseline stores the f32 input of every linear + f32 ReLU context;
    compressed runs store packed codes + one (zero, range) f32 pair per
    quantization block + 1-bit ReLU masks.
    """
    dims = _dims(cfg, g.n_feats)
    n = g.n_nodes
    total_fp32 = 0
    total_c = 0
    comp = cfg.compression
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        total_fp32 += n * lin_in * 4                       # linear input
        if li < len(dims) - 2:
            total_fp32 += n * d_out * 4                    # relu ctx
        if comp is not None:
            d_eff = lin_in // comp.rp_ratio if comp.rp_ratio > 1 else lin_in
            total_c += packmod.packed_nbytes((n, d_eff), comp.bits,
                                             comp.group_size)
            if li < len(dims) - 2:
                total_c += n * d_out // 8                  # 1-bit mask
    out = {"fp32_bytes": total_fp32}
    if comp is not None:
        out["compressed_bytes"] = total_c
        out["reduction"] = 1.0 - total_c / total_fp32
    return out
