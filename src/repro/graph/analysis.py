"""Instrumentation for the paper's Table 2 / Fig. 2 / Fig. 4-5 analyses:
observed activation distributions, JS divergences against the uniform and
clipped-normal models, and empirical SR variance reduction (Eq. 19).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core import quant as quantmod
from repro.core import random_projection as rpmod
from repro.core.variance import js_divergence, model_histogram, optimize_levels
from repro.graph.models import GNNConfig, _dims, spmm


def saved_bytes_per_layer(cfg: GNNConfig, in_dim: int,
                          n_nodes: int) -> list[dict]:
    """Per-layer saved-for-backward bytes under the paper's Table-1 model.

    One row per GNN layer: ``fp32_bytes`` is the f32 linear input plus (on
    hidden layers) the f32 ReLU context; ``compressed_bytes`` (only when
    ``cfg.compression`` is set) is the packed post-RP code words + 8-byte
    per-block (zero, range) pairs + the 1-bit ReLU sign mask.  ``n_nodes``
    is whatever node count is live at once — the full graph, or one padded
    subgraph batch in the mini-batch regime (this is what makes the same
    model serve :func:`repro.graph.train.activation_memory_report` in both
    modes).
    """
    dims = _dims(cfg, in_dim)
    comp = cfg.compression
    rows = []
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        hidden = li < len(dims) - 2
        fp32 = n_nodes * lin_in * 4 + (n_nodes * d_out * 4 if hidden else 0)
        row = {"layer": li, "fp32_bytes": fp32}
        if comp is not None:
            d_eff = lin_in // comp.rp_ratio if comp.rp_ratio > 1 else lin_in
            c = packmod.packed_nbytes((n_nodes, d_eff), comp.bits,
                                      comp.group_size)
            if hidden:
                c += n_nodes * d_out // 8           # 1-bit ReLU mask
            row["compressed_bytes"] = c
        rows.append(row)
    return rows


def collect_projected_activations(params, graph, cfg: GNNConfig,
                                  rp_ratio: int = 8, seed: int = 0):
    """Forward pass capturing each layer's *normalized projected* activation
    H̄_proj (paper App. D: saved after RP, before quantization, normalized
    per row to [0, B])."""
    feats, src, dst, gcn_w, mean_w = graph
    n = feats.shape[0]
    h = feats
    captured = []
    for li, p in enumerate(params):
        if cfg.arch == "gcn":
            x = h
        else:
            agg = spmm(h, src, dst, mean_w, n)
            x = jnp.concatenate([h, agg], axis=1)
        r_dim = max(1, x.shape[1] // rp_ratio)
        proj = rpmod.rp(x, jnp.uint32(seed + li), r_dim)
        zero = proj.min(axis=1, keepdims=True)
        rng = jnp.maximum(proj.max(axis=1, keepdims=True) - zero, 1e-10)
        captured.append(np.asarray((proj - zero) / rng * 3.0))
        z = x @ p["w"] + p["b"]
        if cfg.arch == "gcn":
            z = spmm(z, src, dst, gcn_w, n)
        if li < len(params) - 1:
            z = jnp.maximum(z, 0.0)
        h = z
    return captured


def table2_row(hbar: np.ndarray, bits: int = 2, n_bins: int = 60) -> dict:
    """JS(uniform), JS(clipped-normal), empirical VM variance reduction."""
    R = hbar.shape[1]
    B = 2**bits - 1
    edges = np.linspace(0, B, n_bins + 1)
    obs, _ = np.histogram(hbar.reshape(-1), bins=edges)
    obs = obs / obs.sum()
    js_u = js_divergence(obs, model_histogram(R, bits, edges, "uniform"))
    js_cn = js_divergence(obs, model_histogram(R, bits, edges, "clipnorm"))

    # Eq. 19: Var.Red = 1 − Σ(h̄ − ⌊h̄⌉*)² / Σ(h̄ − ⌊h̄⌉)²
    h = jnp.asarray(hbar)
    lv_u = None
    lv_o = jnp.asarray(optimize_levels(R, bits), jnp.float32)
    err_u, err_o, n_rep = 0.0, 0.0, 4
    for s in range(n_rep):
        cu = quantmod.stochastic_round_to_levels(h, quantmod.uniform_levels(bits), s)
        co = quantmod.stochastic_round_to_levels(h, lv_o, s + 101)
        du = jnp.take(quantmod.uniform_levels(bits), cu)
        do = jnp.take(lv_o, co)
        err_u += float(jnp.sum((h - du) ** 2))
        err_o += float(jnp.sum((h - do) ** 2))
    return {
        "R": R,
        "js_uniform": float(js_u),
        "js_clipnorm": float(js_cn),
        "var_reduction_pct": 100.0 * (1.0 - err_o / max(err_u, 1e-30)),
    }
