"""Instrumentation for the paper's Table 2 / Fig. 2 / Fig. 4-5 analyses:
observed activation distributions, JS divergences against the uniform and
clipped-normal models, and empirical SR variance reduction (Eq. 19).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core import quant as quantmod
from repro.core import random_projection as rpmod
from repro.core.autoprec import LayerStats
from repro.core.variance import js_divergence, model_histogram, optimize_levels
from repro.engine.seeds import layer_seed
from repro.graph.models import GNNConfig, _dims, spmm


def relu_mask_nbytes(n_elements: int) -> int:
    """Bytes of the packed 1-bit ReLU sign mask for ``n_elements`` values.

    :func:`repro.graph.models.relu_1bit` packs the flattened tensor into
    whole uint32 words, so the count is word-aligned ceil — plain
    ``n // 8`` floor-divides away the partial word when the element count
    isn't 32-aligned.
    """
    return 4 * ((n_elements + 31) // 32)


def saved_bytes_per_layer(cfg: GNNConfig, in_dim: int,
                          n_nodes: int) -> list[dict]:
    """Per-layer saved-for-backward bytes under the paper's Table-1 model.

    One row per GNN layer: ``fp32_bytes`` is the f32 linear input plus (on
    hidden layers) the f32 ReLU context; ``compressed_bytes`` (only on
    layers with a compression config) is the packed post-RP code words +
    8-byte per-block (zero, range) pairs + the word-aligned 1-bit ReLU sign
    mask, and ``bits`` names the layer's quantization width so
    mixed-precision (autoprec) breakdowns read directly off the rows.
    ``n_nodes`` is whatever node count is live at once — the full graph, or
    one padded subgraph batch in the mini-batch regime (this is what makes
    the same model serve :func:`repro.graph.train.activation_memory_report`
    in both modes).
    """
    dims = _dims(cfg, in_dim)
    per_layer = cfg.layer_compression()
    rows = []
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        hidden = li < len(dims) - 2
        fp32 = n_nodes * lin_in * 4 + (n_nodes * d_out * 4 if hidden else 0)
        row = {"layer": li, "fp32_bytes": fp32}
        comp = per_layer[li]
        if comp is not None:
            d_eff = lin_in // comp.rp_ratio if comp.rp_ratio > 1 else lin_in
            # + 4: the uint32 rp_seed scalar every CompressedTensor stores
            # (CompressedTensor.nbytes counts it, so the model must too)
            c = packmod.packed_nbytes((n_nodes, d_eff), comp.bits,
                                      comp.group_size) + 4
            if hidden:
                c += relu_mask_nbytes(n_nodes * d_out)  # 1-bit ReLU mask
            row["compressed_bytes"] = c
            row["bits"] = comp.bits
        rows.append(row)
    return rows


def _iter_layer_inputs(params, graph, cfg: GNNConfig):
    """Yield ``(li, x)`` where ``x`` is the linear input layer li stashes.

    The single inference-mode traversal shared by every analysis collector
    (:func:`collect_layer_stats`, :func:`collect_projected_activations`),
    mirroring :func:`repro.graph.models.gnn_forward` — arch dispatch, sage
    concat, Â aggregation, interior ReLU — so the collectors cannot drift
    from what training actually saves.
    """
    feats, src, dst, gcn_w, mean_w = graph
    n = feats.shape[0]
    h = feats
    for li, p in enumerate(params):
        if cfg.arch == "gcn":
            x = h
        else:
            agg = spmm(h, src, dst, mean_w, n)
            x = jnp.concatenate([h, agg], axis=1)
        yield li, x
        z = x @ p["w"] + p["b"]
        if cfg.arch == "gcn":
            z = spmm(z, src, dst, gcn_w, n)
        if li < len(params) - 1:
            z = jnp.maximum(z, 0.0)
        h = z


def collect_layer_stats(params, graph, cfg: GNNConfig,
                        seed: int = 0) -> list[LayerStats | None]:
    """One forward pass collecting the allocator's per-layer sensitivities.

    For every compressed layer this captures exactly what
    ``compressed_matmul`` would stash — the linear input, post-RP at the
    layer's own ``rp_ratio`` and the forward pass's RP seed derivation,
    regrouped into the layer's quantization blocks — and summarizes it as
    a :class:`repro.core.autoprec.LayerStats` (stash shape, block count,
    E[range²]).  Uncompressed layers yield ``None``.  Cheap by design:
    moments only, no quantization, no grads — run it on the first epoch's
    params and refresh every few epochs.
    """
    per_layer = cfg.layer_compression()
    stats: list[LayerStats | None] = []
    for li, x in _iter_layer_inputs(params, graph, cfg):
        comp = per_layer[li]
        if comp is None:
            stats.append(None)
            continue
        xs = x
        if comp.rp_ratio > 1:
            # the same seed derivation gnn_forward -> compress uses
            rp_seed = layer_seed(seed, li) ^ jnp.uint32(0xA5A5_A5A5)
            xs = rpmod.rp(x, rp_seed, max(1, x.shape[1] // comp.rp_ratio))
        blocks, _ = quantmod.group_reshape(xs, comp.group_size)
        _, rng = quantmod.block_stats(blocks)
        stats.append(LayerStats(
            shape=tuple(int(s) for s in xs.shape),
            n_blocks=int(blocks.shape[0]),
            rng_sq_mean=float(jnp.mean(rng.astype(jnp.float32) ** 2))))
    return stats


def variance_validation_report(params, graph, cfg: GNNConfig,
                               seed: int = 0) -> list[dict]:
    """Measured SR dequantization variance vs the Eq. 10 prediction, one
    row per compressed layer.

    Runs the obs telemetry probe (:mod:`repro.obs.quantstats`) on
    ``params`` — the same quantize→dequantize the training stash performs,
    same per-layer seed scheme — and prices the layer's
    :class:`~repro.core.autoprec.LayerStats` through
    :func:`repro.core.autoprec.expected_layer_variance`.  Rows carry
    ``measured_var`` / ``predicted_var`` / ``ratio`` / ``sat_rate``; a
    ratio far from 1 on a real layer means the variance model the
    autoprec allocator prices with has drifted from what the quantizer
    does.
    """
    # obs.quantstats reaches back into this module for _iter_layer_inputs
    # (lazily, inside the probe) — import at call time, not module load
    from repro.obs.quantstats import health_rows, measure_quant_health

    measured = measure_quant_health(params, graph, cfg, seed=seed)
    return health_rows(measured, cfg.layer_compression())


def collect_projected_activations(params, graph, cfg: GNNConfig,
                                  rp_ratio: int = 8, seed: int = 0,
                                  bits: int = 2):
    """Forward pass capturing each layer's *normalized projected* activation
    H̄_proj (paper App. D: saved after RP, before quantization, normalized
    per row to [0, B] with B = 2**bits − 1)."""
    B = float(2**bits - 1)
    captured = []
    for li, x in _iter_layer_inputs(params, graph, cfg):
        r_dim = max(1, x.shape[1] // rp_ratio)
        proj = rpmod.rp(x, jnp.uint32(seed + li), r_dim)
        zero = proj.min(axis=1, keepdims=True)
        rng = jnp.maximum(proj.max(axis=1, keepdims=True) - zero,
                          quantmod.EPS)
        captured.append(np.asarray((proj - zero) / rng * B))
    return captured


def table2_row(hbar: np.ndarray, bits: int = 2, n_bins: int = 60) -> dict:
    """JS(uniform), JS(clipped-normal), empirical VM variance reduction."""
    R = hbar.shape[1]
    B = 2**bits - 1
    edges = np.linspace(0, B, n_bins + 1)
    obs, _ = np.histogram(hbar.reshape(-1), bins=edges)
    obs = obs / obs.sum()
    js_u = js_divergence(obs, model_histogram(R, bits, edges, "uniform"))
    js_cn = js_divergence(obs, model_histogram(R, bits, edges, "clipnorm"))

    # Eq. 19: Var.Red = 1 − Σ(h̄ − ⌊h̄⌉*)² / Σ(h̄ − ⌊h̄⌉)²
    h = jnp.asarray(hbar)
    lv_o = jnp.asarray(optimize_levels(R, bits), jnp.float32)
    err_u, err_o, n_rep = 0.0, 0.0, 4
    for s in range(n_rep):
        cu = quantmod.stochastic_round_to_levels(h, quantmod.uniform_levels(bits), s)
        co = quantmod.stochastic_round_to_levels(h, lv_o, s + 101)
        du = jnp.take(quantmod.uniform_levels(bits), cu)
        do = jnp.take(lv_o, co)
        err_u += float(jnp.sum((h - du) ** 2))
        err_o += float(jnp.sum((h - do) ** 2))
    return {
        "R": R,
        "js_uniform": float(js_u),
        "js_clipnorm": float(js_cn),
        "var_reduction_pct": 100.0 * (1.0 - err_o / max(err_u, 1e-30)),
    }
