"""GNN substrate: the paper's own experimental domain (GCN / GraphSAGE),
full-graph and partition-sampled mini-batch training."""
from repro.graph.analysis import collect_layer_stats, variance_validation_report
from repro.graph.data import (Graph, arxiv_like, cora_like, flickr_like,
                              papers100m_like, stream_edge_chunks,
                              synthetic_graph, synthetic_graph_streamed)
from repro.graph.models import GNNConfig, gnn_forward, init_gnn_params
from repro.graph.sampling import (SubgraphBatch, bfs_partition,
                                  group_batches, make_subgraph_batches,
                                  random_partition, stack_batches)
from repro.graph.train import (activation_memory_report, train_gnn,
                               train_gnn_batched, train_gnn_mesh)

__all__ = [
    "Graph", "arxiv_like", "cora_like", "flickr_like", "synthetic_graph",
    "papers100m_like", "stream_edge_chunks", "synthetic_graph_streamed",
    "GNNConfig", "gnn_forward", "init_gnn_params",
    "SubgraphBatch", "bfs_partition", "random_partition",
    "make_subgraph_batches", "stack_batches", "group_batches",
    "train_gnn", "train_gnn_batched", "train_gnn_mesh",
    "activation_memory_report",
    "collect_layer_stats", "variance_validation_report",
]
