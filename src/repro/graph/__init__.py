"""GNN substrate: the paper's own experimental domain (GCN / GraphSAGE),
full-graph and partition-sampled mini-batch training."""
from repro.graph.analysis import collect_layer_stats
from repro.graph.data import (Graph, arxiv_like, cora_like, flickr_like,
                              synthetic_graph)
from repro.graph.models import GNNConfig, gnn_forward, init_gnn_params
from repro.graph.sampling import (SubgraphBatch, bfs_partition,
                                  group_batches, make_subgraph_batches,
                                  random_partition, stack_batches)
from repro.graph.train import (activation_memory_report, train_gnn,
                               train_gnn_batched)

__all__ = [
    "Graph", "arxiv_like", "cora_like", "flickr_like", "synthetic_graph",
    "GNNConfig", "gnn_forward", "init_gnn_params",
    "SubgraphBatch", "bfs_partition", "random_partition",
    "make_subgraph_batches", "stack_batches", "group_batches",
    "train_gnn", "train_gnn_batched", "activation_memory_report",
    "collect_layer_stats",
]
