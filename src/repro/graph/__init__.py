"""GNN substrate: the paper's own experimental domain (GCN / GraphSAGE)."""
from repro.graph.data import Graph, arxiv_like, flickr_like, synthetic_graph
from repro.graph.models import GNNConfig, gnn_forward, init_gnn_params
from repro.graph.train import train_gnn, activation_memory_report

__all__ = [
    "Graph", "arxiv_like", "flickr_like", "synthetic_graph",
    "GNNConfig", "gnn_forward", "init_gnn_params",
    "train_gnn", "activation_memory_report",
]
