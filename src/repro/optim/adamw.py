"""AdamW over arbitrary pytrees, with optional 8-bit block-wise states.

``state_bits=8`` stores the first/second moments with the same block-wise
SR quantizer the paper applies to activations (and that its ref. [16],
Dettmers et al., applies to optimizer states) — 4x less state memory.
States re-quantize every step with a step-derived SR seed, so rounding
errors stay zero-mean instead of accumulating.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core import quant as quantmod


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 0.0          # 0 disables
    state_bits: int = 0             # 0 = float states; 8 = block-wise int8
    state_group: int = 256
    state_dtype: str = "float32"    # float moment dtype when state_bits == 0
    warmup_steps: int = 0
    decay_steps: int = 0            # 0 = constant lr after warmup


def schedule(cfg: AdamWConfig, step):
    """Linear warmup then (optional) cosine decay."""
    step = step.astype(jnp.float32)
    lr = jnp.asarray(cfg.lr, jnp.float32)
    if cfg.warmup_steps:
        lr = lr * jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    if cfg.decay_steps:
        frac = jnp.clip((step - cfg.warmup_steps) / cfg.decay_steps, 0.0, 1.0)
        lr = lr * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return lr


# -------------------------------------------------- quantized state leaves
def _q_state(x, bits, group, seed):
    codes, zero, rng, _ = quantmod.quantize(x, bits, group, seed)
    return {"p": packmod.pack(codes, bits), "z": zero, "r": rng}


def _dq_state(s, bits, group, shape):
    codes = packmod.unpack(s["p"], bits, group)
    return quantmod.dequantize(codes, s["z"], s["r"], bits, shape)


def adamw_init(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.state_bits:
            z = jnp.zeros_like(p, dtype=jnp.float32)
            return _q_state(z, cfg.state_bits, cfg.state_group, 0)
        return jnp.zeros_like(p, dtype=jnp.dtype(cfg.state_dtype))

    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zero_like, params),
        "v": jax.tree.map(zero_like, params),
    }


def adamw_update(grads, state, params, cfg: AdamWConfig):
    """Returns (new_params, new_state)."""
    step = state["step"]
    lr = schedule(cfg, step)
    t = (step + 1).astype(jnp.float32)

    if cfg.grad_clip:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
        grads = jax.tree.map(lambda g: g * scale, grads)

    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t
    seed = (step + 1).astype(jnp.uint32)

    def leaf(g, m, v, p):
        g = g.astype(jnp.float32)
        if cfg.state_bits:
            m_f = _dq_state(m, cfg.state_bits, cfg.state_group, g.shape)
            v_f = jnp.maximum(
                _dq_state(v, cfg.state_bits, cfg.state_group, g.shape), 0.0)
        else:
            m_f, v_f = m.astype(jnp.float32), v.astype(jnp.float32)
        m_f = cfg.b1 * m_f + (1 - cfg.b1) * g
        v_f = cfg.b2 * v_f + (1 - cfg.b2) * g * g
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        if cfg.weight_decay:
            upd = upd + cfg.weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * upd).astype(p.dtype)
        if cfg.state_bits:
            m_s = _q_state(m_f, cfg.state_bits, cfg.state_group, seed)
            v_s = _q_state(v_f, cfg.state_bits, cfg.state_group, seed + 1)
        else:
            sd = jnp.dtype(cfg.state_dtype)
            m_s, v_s = m_f.astype(sd), v_f.astype(sd)
        return new_p, m_s, v_s

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [leaf(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"step": step + 1, "m": new_m, "v": new_v}
