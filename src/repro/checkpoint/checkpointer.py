"""Sharded, manifest-based, atomic checkpointing.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf.
Writes go to ``step_<N>.tmp`` and rename atomically — a crash mid-save can
never corrupt the latest checkpoint (restart tests rely on this).

Elasticity: leaves are stored as full (unsharded) host arrays with the tree
structure in the manifest; ``load_checkpoint`` re-shards onto whatever mesh
the *restarted* job runs with (pass ``shardings``) — the saved layout is
mesh-agnostic, so a 256-chip checkpoint restores onto 512 chips or 1 CPU.

``save_checkpoint(..., async_write=True)`` snapshots to host synchronously
(cheap) and writes files on a daemon thread (off the training loop).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir, step: int, tree, *, async_write: bool = False):
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    leaves, treedef = _flatten(tree)
    host_leaves = [np.asarray(jax.device_get(l)) for l in leaves]

    def write():
        tmp = ckpt_dir / f"step_{step}.tmp"
        final = ckpt_dir / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir()
        manifest = {"step": step, "treedef": str(treedef),
                    "n_leaves": len(host_leaves),
                    "dtypes": [str(l.dtype) for l in host_leaves],
                    "shapes": [list(l.shape) for l in host_leaves]}
        for i, leaf in enumerate(host_leaves):
            np.save(tmp / f"leaf_{i}.npy", leaf)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def latest_step(ckpt_dir) -> int | None:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = []
    for d in ckpt_dir.iterdir():
        if d.is_dir() and d.name.startswith("step_") and \
                not d.name.endswith(".tmp") and (d / "manifest.json").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or shape
    structs).  ``shardings``: optional matching pytree of NamedSharding for
    elastic re-shard on load."""
    d = Path(ckpt_dir) / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())
    leaves_like, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves_like), \
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves_like)}"
    out = []
    shard_leaves = (_flatten(shardings)[0] if shardings is not None
                    else [None] * len(leaves_like))
    for i, (proto, shd) in enumerate(zip(leaves_like, shard_leaves)):
        arr = np.load(d / f"leaf_{i}.npy")
        if arr.dtype.kind == "V":
            # numpy round-trips ml_dtypes (bfloat16, ...) as raw void bytes;
            # view them back through the manifest dtype
            arr = arr.view(jax.numpy.dtype(manifest["dtypes"][i]))
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)
