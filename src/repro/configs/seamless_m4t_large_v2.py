"""seamless-m4t-large-v2 [audio]: enc-dec multimodal backbone
[arXiv:2308.11596; hf].  24L enc + 24L dec, d_model=1024, 16H (GQA kv=16),
d_ff=8192, vocab=256206.  Audio frontend is a stub: input_specs provides
precomputed frame embeddings (assignment rule)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, encoder_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, d_ff=8192, vocab=256206,
    frontend="audio",
)
