"""ArchConfig + assigned input shapes + smoke reduction + input_specs."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.compressor import CompressionConfig


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|encdec|vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                 # 0 -> d_model // n_heads
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual: bool = False
    # attention details
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1e4
    # ssm
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # encdec / frontends
    encoder_layers: int = 0
    frontend: str | None = None     # "audio" | "vision"
    frontend_len: int = 0
    # training integration
    act_mode: str = "remat"         # none | remat | act
    act_compression: CompressionConfig | None = None
    # host offload of the act-mode stash (None | "host" | "pinned-paged"):
    # compressed_block residuals become host-store tickets so the lax.scan
    # layer loop carries words per layer, not code arrays (repro.offload)
    act_offload: str | None = None
    # dtype the embedding table initializes to — the residual stream
    # inherits it, promoted against the bf16 dense weights (bf16 stays
    # bf16, float32 stays float32, float16 promotes to float32); the
    # activation-memory ledgers size the uncompressed baseline from the
    # promoted dtype
    act_dtype: str = "bfloat16"
    aux_loss_weight: float = 0.01
    # chunking knobs (perf-tunable; see EXPERIMENTS.md §Perf)
    k_chunk: int = 1024
    ssm_chunk: int = 128
    vocab_chunk: int = 2048
    grad_accum: int = 1

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)

    def shared_attn_sites(self) -> list[int]:
        if self.family != "hybrid":
            return []
        if self.n_layers < 6:
            return [1]
        return list(range(5, self.n_layers - 1, 6))

    @property
    def supports_long_context(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = 2 * v * d
        per = 0
        if self.family in ("dense", "vlm", "moe", "encdec"):
            attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
                + self.n_heads * self.d_head * d
            per += attn
        if self.family in ("dense", "vlm", "encdec"):
            per += 3 * d * self.d_ff
        if self.family == "moe":
            per += d * self.n_experts \
                + self.n_experts * 3 * d * self.moe_d_ff
            if self.dense_residual:
                per += 3 * d * self.d_ff
        if self.family in ("ssm", "hybrid"):
            di = self.ssm_expand * d
            h = di // self.ssm_headdim
            per += 2 * d * di + 2 * d * self.ssm_state + d * h + di * d
        total = emb + per * self.n_layers
        if self.family == "encdec":
            enc_per = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
                + self.n_heads * self.d_head * d + 3 * d * self.d_ff
            total += enc_per * self.encoder_layers
            # cross attention in decoder
            total += (d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
                      + self.n_heads * self.d_head * d) * self.n_layers
        if self.family == "hybrid":
            d2 = 2 * d
            total += d2 * (self.n_heads + 2 * self.n_kv_heads) * (d2 // self.n_heads) \
                + d2 * d2 + 3 * d2 * self.d_ff + d2 * d
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k experts count)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_experts * 3 * d * self.moe_d_ff \
            * self.n_layers
        return int(dense + self.top_k * 3 * d * self.moe_d_ff * self.n_layers)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg: ArchConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch × shape) runs; long_500k gates on sub-quadratic decode
    (DESIGN.md §7)."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, ("skipped: pure full-attention arch at 524k context "
                       "(sub-quadratic gate, DESIGN.md §7)")
    return True, ""


def reduce_for_smoke(cfg: ArchConfig) -> ArchConfig:
    """Tiny same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=3 if cfg.family == "hybrid" else 2,
        d_model=64, n_heads=4,
        n_kv_heads=4 if cfg.n_kv_heads == cfg.n_heads else 2,
        d_head=16, d_ff=128, vocab=512,
        k_chunk=32, ssm_chunk=16, vocab_chunk=32, grad_accum=1,
    )
    if cfg.family == "moe":
        kw.update(n_experts=8, top_k=min(cfg.top_k, 4), moe_d_ff=64)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_headdim=16, ssm_expand=2)
    if cfg.family == "encdec":
        kw.update(encoder_layers=2)
    if cfg.frontend:
        kw.update(frontend_len=8)
    return dataclasses.replace(cfg, **kw)


def input_specs(cfg: ArchConfig, shape: ShapeSpec):
    """ShapeDtypeStruct stand-ins for every step input (no allocation)."""
    b, s = shape.batch, shape.seq
    i32, bf16 = jnp.int32, jnp.bfloat16
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        spec = {"tokens": sds((b, s), i32)}
        if cfg.family == "encdec":
            spec["enc_embeds"] = sds((b, s), bf16)  # placeholder, fixed below
            spec["enc_embeds"] = sds((b, s, cfg.d_model), bf16)
        if cfg.frontend == "vision":
            spec["prefix_embeds"] = sds((b, cfg.frontend_len, cfg.d_model),
                                        bf16)
        return spec
    # decode: cache ShapeDtypeStructs via eval_shape on init_cache
    from repro.models.transformer import Model

    model = Model(cfg)
    enc_len = min(4096, s) if cfg.family == "encdec" else 0
    cache = jax.eval_shape(
        lambda: model.init_cache(b, s, enc_len=enc_len))
    return {"tokens": sds((b, 1), i32), "cache": cache}
