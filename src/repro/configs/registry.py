"""--arch <id> registry over the 10 assigned architectures."""
from repro.configs import (arctic_480b, internvl2_2b, mamba2_780m,
                           mistral_nemo_12b, qwen1_5_4b, qwen1_5_32b,
                           qwen3_32b, qwen3_moe_235b_a22b,
                           seamless_m4t_large_v2, zamba2_1_2b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    seamless_m4t_large_v2, qwen3_moe_235b_a22b, arctic_480b, qwen1_5_4b,
    qwen1_5_32b, mistral_nemo_12b, qwen3_32b, internvl2_2b, mamba2_780m,
    zamba2_1_2b,
)}


def get(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
