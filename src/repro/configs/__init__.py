from repro.configs.base import (ArchConfig, SHAPES, ShapeSpec,
                                cell_applicable, input_specs,
                                reduce_for_smoke)
from repro.configs.registry import ARCHS, get

__all__ = ["ArchConfig", "SHAPES", "ShapeSpec", "cell_applicable",
           "input_specs", "reduce_for_smoke", "ARCHS", "get"]
