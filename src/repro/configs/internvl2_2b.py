"""internvl2-2b [vlm]: 24L d_model=2048 16H (GQA kv=8) d_ff=8192
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821; hf].
ViT frontend is a stub: input_specs provides 256 precomputed patch
embeddings per image (assignment rule)."""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-2b", family="vlm",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92553, frontend="vision", frontend_len=256,
)
