"""Plan compiler: lower any :class:`ExecutionPlan` to ONE jitted epoch
step.

``compile_plan`` resolves the plan's sampling axis into a data layout
(the full graph tuple, or stacked padded subgraph batches grouped into
``(n_updates, grad_accum, dp, ...)``) and emits a single
``jax.jit``-compiled epoch step built on the engine's one stash-aware
``custom_vjp`` forward (:mod:`repro.engine.forward`).  The stash and
kernel axes are baked into that forward; the precision axis re-enters
through :meth:`CompiledPlan.recompile`, which swaps the step for a new
width allocation without touching the data layout.

Pre-engine, this logic lived as two divergent ``make_step`` /
``make_epoch_step`` closures inside ``graph/train.py`` plus a third
step assembly in the offload benchmarks — every policy knob re-plumbed
by hand in each.  The lowerings here are the same computations (the
parity gate in ``tests/test_engine.py`` holds them bit-identical), with
one owner.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine import seeds
from repro.engine.forward import (mesh_gnn_forward, mesh_stash_plan,
                                  plan_gnn_stashes, stash_gnn_forward)
from repro.engine.plan import ExecutionPlan
from repro.graph.models import graph_tuple
from repro.graph.sampling import (group_batches, make_subgraph_batches,
                                  stack_batches)
from repro.obs.session import NULL_SESSION
from repro.optim import adamw_update
from repro.parallel.halo import (build_halo_program, exchange_widths,
                                 graph_mesh, halo_bytes_per_epoch,
                                 halo_bytes_per_round)
from repro.parallel.sharding import dp_size, graph_batch_pspecs, to_named


def masked_nll(logits, labels, mask):
    """Mean masked softmax cross-entropy — the loss every GNN training
    path (engine lowerings and the legacy ``_loss_fn`` shim) shares."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def engine_loss(params, gt, labels, mask, cfg, seed, node_mask, stash_plan,
                stash, fused: str = "auto"):
    """Training loss over the engine's unified stash-aware forward."""
    logits = stash_gnn_forward(params, gt, cfg, stash_plan, stash,
                               seed=seed, node_mask=node_mask, fused=fused)
    return masked_nll(logits, labels, mask)


class _CompiledFull:
    """Full-graph lowering: one optimizer update per epoch step."""

    kind = "full"

    def __init__(self, g, cfg, plan: ExecutionPlan, opt):
        self.plan = plan
        self.opt = opt
        self.gt = graph_tuple(g)
        self.labels = g.labels
        self.tr_mask = g.train_mask.astype(jnp.float32)
        self.in_dim = g.n_feats
        self.n_nodes = g.n_nodes
        self._rebuild(cfg)

    def _rebuild(self, cfg):
        self.cfg = cfg
        self.stash_plan = plan_gnn_stashes(cfg, self.in_dim, self.n_nodes)
        stash, splan, opt = self.plan.stash, self.stash_plan, self.opt
        fused = self.plan.kernel.fused

        @partial(jax.jit, donate_argnums=(0, 1))
        def step(params, state, epoch, gt, labels, tr_mask):
            sr = seeds.sr_seed(epoch)
            loss, grads = jax.value_and_grad(engine_loss)(
                params, gt, labels, tr_mask, cfg, sr, None, splan, stash,
                fused)
            params, state = adamw_update(grads, state, params, opt)
            return params, state, loss

        self.step = step

    def recompile(self, cfg) -> "_CompiledFull":
        """Plan-recompile hook (autoprec refresh): new widths, same data."""
        self._rebuild(cfg)
        return self

    def epoch_data(self, order_rng):
        return (self.gt, self.labels, self.tr_mask)

    def calibration(self):
        """(gt, labels, mask, node_mask) the autoprec probe runs on."""
        return (self.gt, self.labels, self.tr_mask, None)

    def result_extras(self) -> dict:
        return {}


class _CompiledPartition:
    """Partition-sampled lowering: one jitted ``lax.scan`` epoch over
    grouped padded subgraph batches (grad accumulation inside, optional
    data-parallel batch sharding over a device mesh)."""

    kind = "partition"

    def __init__(self, g, cfg, plan: ExecutionPlan, opt, batches, mesh,
                 seed: int):
        sp = plan.sampling
        if batches is None:
            batches = make_subgraph_batches(
                g, sp.n_parts, method=sp.method, halo=sp.halo, seed=seed,
                node_multiple=sp.node_multiple,
                edge_multiple=sp.edge_multiple,
                renormalize=sp.renormalize)
        elif len(batches) != sp.n_parts:
            raise ValueError(f"prebuilt batches list has {len(batches)} "
                             f"entries but n_parts={sp.n_parts}")
        self.plan = plan
        self.opt = opt
        self.batches = batches
        self.n_batches = len(batches)
        self.dp = dp_size(mesh) if mesh is not None else 1
        if plan.stash.offload in ("host", "pinned-paged") and self.dp > 1:
            raise ValueError(
                f"offload={plan.stash.offload!r} needs an unsharded run "
                f"(dp_size==1); got dp={self.dp}")
        self.grad_accum = sp.grad_accum
        group = self.dp * self.grad_accum
        if self.n_batches % group:
            raise ValueError(
                f"n_parts={self.n_batches} must be a multiple of "
                f"dp*grad_accum={self.dp}*{self.grad_accum}={group} "
                f"(whole update groups per epoch)")
        self.group = group
        self.n_updates = self.n_batches // group
        self.mesh = mesh
        self.in_dim = g.n_feats
        self.stacked = stack_batches(batches)
        self.reshuffle = sp.shuffle and self.n_batches > 1
        self._static_grouped = None
        self._rebuild(cfg)

    def _rebuild(self, cfg):
        self.cfg = cfg
        self.stash_plan = plan_gnn_stashes(cfg, self.in_dim,
                                           self.batches[0].n_nodes)
        stash, splan, opt = self.plan.stash, self.stash_plan, self.opt
        fused = self.plan.kernel.fused
        n_batches, group, dp = self.n_batches, self.group, self.dp
        grad_accum, n_updates = self.grad_accum, self.n_updates

        @partial(jax.jit, donate_argnums=(0, 1))
        def epoch_step(params, state, epoch, grouped):
            # grouped leaves: (n_updates, grad_accum, dp, ...)
            def update(carry, inp):
                params, state = carry
                u, grp = inp

                def micro(gsum, inp2):
                    a, mb = inp2
                    ords = seeds.batch_ordinals(epoch, n_batches, u, group,
                                                a, dp)
                    srs = seeds.sr_seed(ords)

                    def group_loss(p):
                        losses = jax.vmap(
                            lambda b, s: engine_loss(p, b.graph_tuple(),
                                                     b.labels, b.train_mask,
                                                     cfg, s, b.node_mask,
                                                     splan, stash, fused)
                        )(mb, srs)
                        return losses.mean()

                    loss, grads = jax.value_and_grad(group_loss)(params)
                    return jax.tree.map(jnp.add, gsum, grads), loss

                zeros = jax.tree.map(jnp.zeros_like, params)
                gsum, losses = jax.lax.scan(
                    micro, zeros, (jnp.arange(grad_accum), grp))
                grads = jax.tree.map(lambda x: x / grad_accum, gsum)
                params, state = adamw_update(grads, state, params, opt)
                return (params, state), losses.mean()

            (params, state), losses = jax.lax.scan(
                update, (params, state), (jnp.arange(n_updates), grouped))
            return params, state, losses.mean()

        self.step = epoch_step

    def recompile(self, cfg) -> "_CompiledPartition":
        self._rebuild(cfg)
        return self

    def _make_grouped(self, order):
        grouped = group_batches(self.stacked, order, self.n_updates,
                                self.grad_accum, self.dp)
        if self.mesh is not None:
            specs = graph_batch_pspecs(grouped, self.mesh, axis=2)
            grouped = jax.device_put(grouped, to_named(specs, self.mesh))
        return grouped

    def epoch_data(self, order_rng):
        if not self.reshuffle:
            if self._static_grouped is None:
                self._static_grouped = self._make_grouped(
                    np.arange(self.n_batches))
            return (self._static_grouped,)
        return (self._make_grouped(order_rng.permutation(self.n_batches)),)

    def calibration(self):
        # one padded batch — the engine's live stash unit — so the probe
        # never re-materializes the full-graph activations this engine
        # exists to avoid (the budget is therefore per batch, matching
        # the actual peak)
        b0 = self.batches[0]
        return (b0.graph_tuple(), b0.labels, b0.train_mask, b0.node_mask)

    def result_extras(self) -> dict:
        return {"n_parts": self.n_batches,
                "updates_per_epoch": self.n_updates,
                "batch_nodes": self.batches[0].n_nodes,
                "batch_edges": self.batches[0].n_edges}


class _CompiledMesh:
    """Mesh-sharded lowering: partitions sharded over a ``graph`` mesh
    axis, trained ``m`` at a time in ``n_parts // m`` rounds with a
    per-layer halo exchange; features stay host-resident behind a
    :class:`~repro.offload.pager.FeaturePager`.

    One jitted round step serves every round (round index and epoch are
    traced); the loss is round-globally normalized —
    ``psum(Σ nll·mask) / psum(Σ mask)`` — so ``m == n_parts`` reproduces
    the full-graph ``masked_nll`` exactly and ``m == 1`` reproduces the
    batched engine's per-batch loss exactly.  Per-device grads are
    ``psum``-reduced inside the ``shard_map``; the optimizer update runs
    once per round on the replicated result.
    """

    kind = "mesh"

    def __init__(self, g, cfg, plan: ExecutionPlan, opt, batches, mesh,
                 seed: int, obs=NULL_SESSION):
        from jax.sharding import NamedSharding, PartitionSpec as P

        sp = plan.sampling
        if batches is not None:
            raise ValueError("mesh sampling builds its own partition "
                             "layout; prebuilt batches are a partition-"
                             "plan resource")
        if plan.stash.kind != "tensor":
            raise ValueError("mesh sampling stashes per-tensor residuals "
                             "on each device (the features are what is "
                             f"host-resident); stash kind "
                             f"{plan.stash.kind!r} is unsupported")
        if plan.precision.kind != "fixed":
            raise ValueError("mesh sampling does not support autoprec "
                             "(calibrate on a partition plan and pass the "
                             "allocated cfg)")
        if plan.kernel.fused == "on":
            raise ValueError("mesh sampling composes the per-op compressed "
                             "stack; fused='on' is unsupported (use "
                             "'auto'/'off')")
        if mesh is None or "graph" not in mesh.shape:
            mesh = graph_mesh(sp.n_parts)
        self.mesh = mesh
        self.m = int(mesh.shape["graph"])
        if sp.n_parts % self.m:
            raise ValueError(f"n_parts={sp.n_parts} must be a multiple of "
                             f"the graph-mesh size {self.m}")
        self.plan = plan
        self.opt = opt
        self.n_parts = sp.n_parts
        self.in_dim = g.n_feats
        self.prog = build_halo_program(
            g, sp.n_parts, self.m, method=sp.method, seed=seed,
            node_multiple=sp.node_multiple, edge_multiple=sp.edge_multiple)
        self.rounds = self.prog.rounds
        shard = NamedSharding(mesh, P("graph"))
        pr = self.prog
        self._round_const = [
            tuple(jax.device_put(np.asarray(a[r]), shard)
                  for a in (pr.labels, pr.train_mask, pr.node_mask,
                            pr.edge_src, pr.edge_dst, pr.gcn_weight,
                            pr.mean_weight, pr.send_idx))
            for r in range(self.rounds)]
        from repro.offload.pager import FeaturePager
        self._obs = obs
        self.pager = FeaturePager(pr.features, mesh, metrics=obs.registry)
        self.pager.prefetch(0)
        self._halo_ctr = obs.counter("halo/bytes")
        self._rebuild(cfg)

    def _rebuild(self, cfg):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        self.cfg = cfg
        self.stash_plan = mesh_stash_plan(cfg, self.in_dim, self.prog.n_pad)
        opt, mesh, m, n_parts = self.opt, self.mesh, self.m, self.n_parts
        axis = "graph" if m > 1 else None

        def device_update(params, srs, feats, labels, tmask, nmask,
                          esrc, edst, gw, mw, send_idx):
            # operands carry a leading per-device axis (size 1 inside the
            # shard_map body; the whole m axis on the single-device path,
            # where m == 1 makes [0] the same squeeze)
            feats, labels, tmask, nmask = (feats[0], labels[0], tmask[0],
                                           nmask[0])
            esrc, edst, gw, mw = esrc[0], edst[0], gw[0], mw[0]
            send, sr = send_idx[0], srs[0]

            def loss_fn(p):
                logits = mesh_gnn_forward(p, feats, esrc, edst, gw, mw,
                                          nmask, send, cfg, seed=sr,
                                          axis=axis)
                logp = jax.nn.log_softmax(logits, axis=-1)
                nll = -jnp.take_along_axis(logp, labels[:, None],
                                           axis=1)[:, 0]
                num, den = jnp.sum(nll * tmask), tmask.sum()
                if axis is not None:
                    num = jax.lax.psum(num, axis)
                    den = jax.lax.psum(den, axis)
                # the round-global masked_nll: identical to the batched
                # engine's per-batch loss at m == 1 and to the full-graph
                # masked_nll at m == n_parts
                return num / jnp.maximum(den, 1)

            loss, grads = jax.value_and_grad(loss_fn)(params)
            if axis is not None:
                grads = jax.lax.psum(grads, axis)
            return loss, grads

        if m > 1:
            update = shard_map(
                device_update, mesh=mesh,
                in_specs=(P(), P("graph")) + (P("graph"),) * 9,
                out_specs=(P(), P()), check_rep=False)
        else:
            update = device_update

        @partial(jax.jit, donate_argnums=(0, 1))
        def round_step(params, state, epoch, r, feats, *const):
            # partition r*m + i on device i: the same ordinal scheme as
            # the batched engine (update=r, group=dp=m), so m == 1 round
            # seeds equal the batched run's and n_parts == 1 reduces to
            # the full-graph sr_seed(epoch)
            srs = seeds.sr_seed(seeds.batch_ordinals(epoch, n_parts, r, m,
                                                     0, m))
            loss, grads = update(params, srs, feats, *const)
            params, state = adamw_update(grads, state, params, opt)
            return params, state, loss

        self._round_step = round_step
        dims = [self.in_dim, *cfg.hidden, cfg.n_classes]
        self._halo_round_bytes = halo_bytes_per_round(
            self.prog, exchange_widths(cfg.arch, dims))

    def recompile(self, cfg) -> "_CompiledMesh":
        self._rebuild(cfg)
        return self

    def step(self, params, state, epoch):
        losses = []
        obs = self._obs
        for r in range(self.rounds):
            with obs.span("mesh/round", round=r):
                with obs.span("pager/fetch", round=r):
                    feats = self.pager.fetch(r)
                # next round's pages (next epoch's round 0 on the last
                # round) move host->device while this round's step computes
                self.pager.prefetch((r + 1) % self.rounds)
                params, state, loss = self._round_step(
                    params, state, epoch, jnp.asarray(r), feats,
                    *self._round_const[r])
            self._halo_ctr.inc(self._halo_round_bytes)
            losses.append(loss)
        return params, state, jnp.mean(jnp.stack(losses))

    def epoch_data(self, order_rng):
        return ()

    def calibration(self):
        raise ValueError("mesh sampling does not support autoprec "
                         "calibration")

    def result_extras(self) -> dict:
        dims = [self.in_dim, *self.cfg.hidden, self.cfg.n_classes]
        widths = exchange_widths(self.cfg.arch, dims)
        return {"n_parts": self.n_parts,
                "mesh_devices": self.m,
                "updates_per_epoch": self.rounds,
                "batch_nodes": self.prog.n_pad,
                "batch_edges": self.prog.e_pad,
                "halo_width": self.prog.halo,
                "halo_edges": self.prog.halo_edges,
                "dropped_edges": self.prog.dropped_edges,
                "halo_bytes_per_epoch": halo_bytes_per_epoch(self.prog,
                                                             widths),
                "pager": self.pager.stats()}


def compile_plan(g, cfg, plan: ExecutionPlan, opt, *, batches=None,
                 mesh=None, seed: int = 0, obs=NULL_SESSION):
    """Lower ``plan`` for graph ``g``: returns a compiled object exposing
    ``step`` (the ONE jitted epoch step), ``epoch_data``, ``recompile``
    (the autoprec refresh hook), ``calibration``, and ``result_extras``.

    ``batches`` (prebuilt ``SubgraphBatch`` list) and ``mesh`` are runtime
    resources, not plan policy — benchmarks/tests reuse one sampling pass
    across plans, and the mesh is whatever hardware the process owns.
    ``obs`` is the run's :class:`~repro.obs.session.ObsSession`; the mesh
    lowering threads it into per-round spans, the pager's overlap
    histogram, and the halo byte counter (the default null session makes
    all of that free).
    """
    if plan.sampling.kind == "full":
        if batches is not None:
            raise ValueError("prebuilt batches need partition sampling")
        return _CompiledFull(g, cfg, plan, opt)
    if plan.sampling.kind == "mesh":
        return _CompiledMesh(g, cfg, plan, opt, batches, mesh, seed, obs=obs)
    return _CompiledPartition(g, cfg, plan, opt, batches, mesh, seed)
