"""Plan compiler: lower any :class:`ExecutionPlan` to ONE jitted epoch
step.

``compile_plan`` resolves the plan's sampling axis into a data layout
(the full graph tuple, or stacked padded subgraph batches grouped into
``(n_updates, grad_accum, dp, ...)``) and emits a single
``jax.jit``-compiled epoch step built on the engine's one stash-aware
``custom_vjp`` forward (:mod:`repro.engine.forward`).  The stash and
kernel axes are baked into that forward; the precision axis re-enters
through :meth:`CompiledPlan.recompile`, which swaps the step for a new
width allocation without touching the data layout.

Pre-engine, this logic lived as two divergent ``make_step`` /
``make_epoch_step`` closures inside ``graph/train.py`` plus a third
step assembly in the offload benchmarks — every policy knob re-plumbed
by hand in each.  The lowerings here are the same computations (the
parity gate in ``tests/test_engine.py`` holds them bit-identical), with
one owner.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro.engine import seeds
from repro.engine.forward import plan_gnn_stashes, stash_gnn_forward
from repro.engine.plan import ExecutionPlan
from repro.graph.models import graph_tuple
from repro.graph.sampling import (group_batches, make_subgraph_batches,
                                  stack_batches)
from repro.optim import adamw_update
from repro.parallel.sharding import dp_size, graph_batch_pspecs, to_named


def masked_nll(logits, labels, mask):
    """Mean masked softmax cross-entropy — the loss every GNN training
    path (engine lowerings and the legacy ``_loss_fn`` shim) shares."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=1)[:, 0]
    return jnp.sum(nll * mask) / jnp.maximum(mask.sum(), 1)


def engine_loss(params, gt, labels, mask, cfg, seed, node_mask, stash_plan,
                stash, fused: str = "auto"):
    """Training loss over the engine's unified stash-aware forward."""
    logits = stash_gnn_forward(params, gt, cfg, stash_plan, stash,
                               seed=seed, node_mask=node_mask, fused=fused)
    return masked_nll(logits, labels, mask)


class _CompiledFull:
    """Full-graph lowering: one optimizer update per epoch step."""

    kind = "full"

    def __init__(self, g, cfg, plan: ExecutionPlan, opt):
        self.plan = plan
        self.opt = opt
        self.gt = graph_tuple(g)
        self.labels = g.labels
        self.tr_mask = g.train_mask.astype(jnp.float32)
        self.in_dim = g.n_feats
        self.n_nodes = g.n_nodes
        self._rebuild(cfg)

    def _rebuild(self, cfg):
        self.cfg = cfg
        self.stash_plan = plan_gnn_stashes(cfg, self.in_dim, self.n_nodes)
        stash, splan, opt = self.plan.stash, self.stash_plan, self.opt
        fused = self.plan.kernel.fused

        @partial(jax.jit, donate_argnums=(0, 1))
        def step(params, state, epoch, gt, labels, tr_mask):
            sr = seeds.sr_seed(epoch)
            loss, grads = jax.value_and_grad(engine_loss)(
                params, gt, labels, tr_mask, cfg, sr, None, splan, stash,
                fused)
            params, state = adamw_update(grads, state, params, opt)
            return params, state, loss

        self.step = step

    def recompile(self, cfg) -> "_CompiledFull":
        """Plan-recompile hook (autoprec refresh): new widths, same data."""
        self._rebuild(cfg)
        return self

    def epoch_data(self, order_rng):
        return (self.gt, self.labels, self.tr_mask)

    def calibration(self):
        """(gt, labels, mask, node_mask) the autoprec probe runs on."""
        return (self.gt, self.labels, self.tr_mask, None)

    def result_extras(self) -> dict:
        return {}


class _CompiledPartition:
    """Partition-sampled lowering: one jitted ``lax.scan`` epoch over
    grouped padded subgraph batches (grad accumulation inside, optional
    data-parallel batch sharding over a device mesh)."""

    kind = "partition"

    def __init__(self, g, cfg, plan: ExecutionPlan, opt, batches, mesh,
                 seed: int):
        sp = plan.sampling
        if batches is None:
            batches = make_subgraph_batches(
                g, sp.n_parts, method=sp.method, halo=sp.halo, seed=seed,
                node_multiple=sp.node_multiple,
                edge_multiple=sp.edge_multiple,
                renormalize=sp.renormalize)
        elif len(batches) != sp.n_parts:
            raise ValueError(f"prebuilt batches list has {len(batches)} "
                             f"entries but n_parts={sp.n_parts}")
        self.plan = plan
        self.opt = opt
        self.batches = batches
        self.n_batches = len(batches)
        self.dp = dp_size(mesh) if mesh is not None else 1
        if plan.stash.offload in ("host", "pinned-paged") and self.dp > 1:
            raise ValueError(
                f"offload={plan.stash.offload!r} needs an unsharded run "
                f"(dp_size==1); got dp={self.dp}")
        self.grad_accum = sp.grad_accum
        group = self.dp * self.grad_accum
        if self.n_batches % group:
            raise ValueError(
                f"n_parts={self.n_batches} must be a multiple of "
                f"dp*grad_accum={self.dp}*{self.grad_accum}={group} "
                f"(whole update groups per epoch)")
        self.group = group
        self.n_updates = self.n_batches // group
        self.mesh = mesh
        self.in_dim = g.n_feats
        self.stacked = stack_batches(batches)
        self.reshuffle = sp.shuffle and self.n_batches > 1
        self._static_grouped = None
        self._rebuild(cfg)

    def _rebuild(self, cfg):
        self.cfg = cfg
        self.stash_plan = plan_gnn_stashes(cfg, self.in_dim,
                                           self.batches[0].n_nodes)
        stash, splan, opt = self.plan.stash, self.stash_plan, self.opt
        fused = self.plan.kernel.fused
        n_batches, group, dp = self.n_batches, self.group, self.dp
        grad_accum, n_updates = self.grad_accum, self.n_updates

        @partial(jax.jit, donate_argnums=(0, 1))
        def epoch_step(params, state, epoch, grouped):
            # grouped leaves: (n_updates, grad_accum, dp, ...)
            def update(carry, inp):
                params, state = carry
                u, grp = inp

                def micro(gsum, inp2):
                    a, mb = inp2
                    ords = seeds.batch_ordinals(epoch, n_batches, u, group,
                                                a, dp)
                    srs = seeds.sr_seed(ords)

                    def group_loss(p):
                        losses = jax.vmap(
                            lambda b, s: engine_loss(p, b.graph_tuple(),
                                                     b.labels, b.train_mask,
                                                     cfg, s, b.node_mask,
                                                     splan, stash, fused)
                        )(mb, srs)
                        return losses.mean()

                    loss, grads = jax.value_and_grad(group_loss)(params)
                    return jax.tree.map(jnp.add, gsum, grads), loss

                zeros = jax.tree.map(jnp.zeros_like, params)
                gsum, losses = jax.lax.scan(
                    micro, zeros, (jnp.arange(grad_accum), grp))
                grads = jax.tree.map(lambda x: x / grad_accum, gsum)
                params, state = adamw_update(grads, state, params, opt)
                return (params, state), losses.mean()

            (params, state), losses = jax.lax.scan(
                update, (params, state), (jnp.arange(n_updates), grouped))
            return params, state, losses.mean()

        self.step = epoch_step

    def recompile(self, cfg) -> "_CompiledPartition":
        self._rebuild(cfg)
        return self

    def _make_grouped(self, order):
        grouped = group_batches(self.stacked, order, self.n_updates,
                                self.grad_accum, self.dp)
        if self.mesh is not None:
            specs = graph_batch_pspecs(grouped, self.mesh, axis=2)
            grouped = jax.device_put(grouped, to_named(specs, self.mesh))
        return grouped

    def epoch_data(self, order_rng):
        if not self.reshuffle:
            if self._static_grouped is None:
                self._static_grouped = self._make_grouped(
                    np.arange(self.n_batches))
            return (self._static_grouped,)
        return (self._make_grouped(order_rng.permutation(self.n_batches)),)

    def calibration(self):
        # one padded batch — the engine's live stash unit — so the probe
        # never re-materializes the full-graph activations this engine
        # exists to avoid (the budget is therefore per batch, matching
        # the actual peak)
        b0 = self.batches[0]
        return (b0.graph_tuple(), b0.labels, b0.train_mask, b0.node_mask)

    def result_extras(self) -> dict:
        return {"n_parts": self.n_batches,
                "updates_per_epoch": self.n_updates,
                "batch_nodes": self.batches[0].n_nodes,
                "batch_edges": self.batches[0].n_edges}


def compile_plan(g, cfg, plan: ExecutionPlan, opt, *, batches=None,
                 mesh=None, seed: int = 0):
    """Lower ``plan`` for graph ``g``: returns a compiled object exposing
    ``step`` (the ONE jitted epoch step), ``epoch_data``, ``recompile``
    (the autoprec refresh hook), ``calibration``, and ``result_extras``.

    ``batches`` (prebuilt ``SubgraphBatch`` list) and ``mesh`` are runtime
    resources, not plan policy — benchmarks/tests reuse one sampling pass
    across plans, and the mesh is whatever hardware the process owns.
    """
    if plan.sampling.kind == "full":
        if batches is not None:
            raise ValueError("prebuilt batches need partition sampling")
        return _CompiledFull(g, cfg, plan, opt)
    return _CompiledPartition(g, cfg, plan, opt, batches, mesh, seed)
