"""The one place the activation-seed scheme is defined.

Every stochastic-rounding stash in the GNN stack derives its seed from
two constants:

* an **update ordinal** ``o`` (the epoch for full-graph training, or
  ``epoch * n_parts + position`` for the mini-batch engine) maps to the
  base SR seed ``(o + 1) * 7919`` — so ``n_parts = 1`` reproduces the
  full-graph seeds exactly and ordinal 0 never yields seed 0;
* layer ``li`` offsets the base seed by ``li * 1013`` so adjacent layers
  draw decorrelated codes from the counter PRNG.

Before the engine refactor this scheme was re-derived by hand in
``graph/train.py`` (both engines), ``graph/models.py``, and the arena
forward — four copies of the same two literals.  Everything now calls
these helpers; ``tests/test_engine.py`` pins the scheme numerically so a
drive-by change to either constant breaks loudly instead of silently
desynchronizing replays.

All helpers accept traced jax values or python ints and return uint32
(the dtype the counter PRNG consumes); arithmetic wraps mod 2**32 by
construction.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core.prng import KNUTH_MULT

#: Base multiplier of the update-ordinal seed scheme: ``(o + 1) * 7919``.
SR_SEED_PRIME = 7919

#: Per-layer seed stride: layer li stashes with ``base + li * 1013``.
LAYER_SEED_STRIDE = 1013

#: Salt for the batch-order shuffle rng of the mini-batch engine.
ORDER_SALT = 0x5EED_BA5E

#: Knuth multiplicative hash used to derive autoprec probe seeds and the
#: LM per-step activation seed (shared with the offload ticket hash via
#: :data:`repro.core.prng.KNUTH_MULT`).
_PROBE_MULT = int(KNUTH_MULT)


def sr_seed(ordinal):
    """Base stochastic-rounding seed for one optimizer-update ordinal.

    ``ordinal`` is the epoch (full-graph) or ``epoch * n_parts + pos``
    (mini-batch); scalars and arrays (a whole dp group at once) both work.
    """
    if isinstance(ordinal, (int, np.integer)):
        ordinal = np.uint32(ordinal & 0xFFFF_FFFF)
    return (jnp.asarray(ordinal).astype(jnp.uint32) + jnp.uint32(1)) * \
        jnp.uint32(SR_SEED_PRIME)


def layer_seed(seed, li: int):
    """Layer li's stash seed given the update's base seed."""
    return jnp.asarray(seed, jnp.uint32) + jnp.uint32(li * LAYER_SEED_STRIDE)


def batch_ordinals(epoch, n_batches: int, update, group: int, micro, dp: int):
    """Update ordinals of one micro-batch's dp group inside the epoch scan.

    ``epoch``/``update``/``micro`` may be traced scalars (scan carries);
    returns a (dp,) vector feeding :func:`sr_seed`.
    """
    base = epoch * n_batches + update * group
    return base + micro * dp + jnp.arange(dp)


def step_seed(step):
    """Activation-compression base seed for one LM optimizer step.

    The transformer training step has no epoch/partition structure, so its
    stream is the Knuth hash of the step counter (``step`` may be a traced
    scalar — the optimizer state's step count inside a jitted train step).
    """
    return jnp.asarray(step).astype(jnp.uint32) * jnp.uint32(KNUTH_MULT)


#: Per-slot seed stride for the serving KV cache: decorrelates two slots
#: that sit at the same absolute position (next prime after the ordinal
#: scheme's 7919 so the streams never alias).
KV_SLOT_STRIDE = 7927


def kv_seed(pos, slot, li, field):
    """SR seed for one serving KV-cache write.

    ``pos`` is the token's absolute position (prompt + generated), ``slot``
    the scheduler slot, ``li`` the layer, ``field`` 0 for K / 1 for V.
    The base stream is the LM step hash of the position (so a request
    replayed through a different admission order quantizes identically as
    long as it lands in the same slot); slot and (layer, field) offsets
    draw decorrelated counter-PRNG streams.  All arguments may be traced —
    the decode step derives seeds inside its layer scan.
    """
    base = step_seed(pos) + \
        jnp.asarray(slot, jnp.uint32) * jnp.uint32(KV_SLOT_STRIDE)
    off = (jnp.asarray(li, jnp.uint32) * jnp.uint32(2)
           + jnp.asarray(field, jnp.uint32)) * jnp.uint32(LAYER_SEED_STRIDE)
    return base + off


def probe_seeds(seed: int):
    """Two decorrelated uint32 seeds for the autoprec two-seed grad probe."""
    h = seed * _PROBE_MULT
    return (jnp.uint32((h + 101) & 0xFFFF_FFFF),
            jnp.uint32((h + 211) & 0xFFFF_FFFF))


def order_rng(seed: int) -> np.random.Generator:
    """The numpy rng that draws per-epoch batch orders (host side)."""
    return np.random.default_rng(seed ^ ORDER_SALT)
