"""The engine's epoch driver: ``run(g, cfg, plan)`` is the single entry
point behind ``train_gnn``, ``train_gnn_batched``, ``launch.train
--graph-batches``, and the GNN benchmarks.

The loop is policy-free by construction: it asks the compiled plan for
its epoch data, calls the ONE jitted step, and services the autoprec
refresh as a plan-recompile hook.  Everything policy-shaped lives in the
plan and its compiler.

Observability (the plan's :class:`~repro.obs.policy.ObsPolicy`) wraps
the loop from the outside: spans around plan compile / epochs / autoprec
re-solves, a recompile counter, and the opt-in quant-health probe on its
epoch cadence.  All of it is host-side or a separate jitted pass — the
training step's jaxpr is untouched, so obs-on runs are bit-identical to
obs-off (gated in ``tests/test_obs.py``).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.engine import seeds
from repro.engine.compile import compile_plan
from repro.engine.plan import ExecutionPlan
from repro.engine.precision import AutoprecController
from repro.graph.models import gnn_forward, graph_tuple, init_gnn_params
from repro.obs.session import ObsSession
from repro.obs.trace import stopwatch
from repro.optim import AdamWConfig, adamw_init


def _accuracy(params, graph, labels, mask, cfg):
    logits = gnn_forward(params, graph, cfg, seed=0)
    correct = (jnp.argmax(logits, -1) == labels).astype(jnp.float32)
    return jnp.sum(correct * mask) / jnp.maximum(mask.sum(), 1)


def _result(eval_fn, params, g, gt, history, n_epochs, dt, **extra):
    """Final full-graph val/test metrics + the shared engine result dict
    (every plan reports through this one contract)."""
    val = float(eval_fn(params, gt, g.labels, g.val_mask.astype(jnp.float32)))
    test = float(eval_fn(params, gt, g.labels,
                         g.test_mask.astype(jnp.float32)))
    return {"test_acc": test, "val_acc": val, "history": history,
            "epochs_per_sec": n_epochs / dt, "params": params, **extra}


def _probe_graph(compiled, gt):
    """The graph tuple the quant-health probe runs on: the plan's
    calibration unit (one padded batch for partition plans, the full
    graph otherwise — mesh plans have no calibration unit and probe the
    full graph, which is a measurement pass, not a training stash)."""
    try:
        cal_gt, _, _, _ = compiled.calibration()
        return cal_gt
    except ValueError:
        return gt


def run(g, cfg, plan: ExecutionPlan | None = None, opt=None, *,
        n_epochs: int = 100, seed: int = 0, eval_every: int = 10,
        verbose: bool = False, batches=None, mesh=None) -> dict:
    """Train ``cfg`` on ``g`` under ``plan``; returns the engine result
    dict (``test_acc``, ``val_acc``, ``history``, ``epochs_per_sec``,
    ``params``, ``cfg``, ``plan``, plus the partition extras
    ``n_parts`` / ``updates_per_epoch`` / ``batch_nodes`` /
    ``batch_edges``, the autoprec extras ``bits_per_layer`` /
    ``bit_budget_bytes``, and — when the plan's obs policy is enabled —
    the live :class:`~repro.obs.session.ObsSession` under ``"obs"``).

    ``batches`` / ``mesh`` are runtime resources for partition plans
    (prebuilt sampling pass, device mesh) — see
    :func:`repro.engine.compile.compile_plan`.
    """
    plan = plan if plan is not None else ExecutionPlan()
    if (plan.precision.kind == "autoprec"
            and plan.precision.calibration == "obs"
            and not (plan.obs.enabled and plan.obs.quant_stats)):
        raise ValueError("precision.calibration='obs' sources sensitivities "
                         "from the quant-health telemetry channel; the plan "
                         "needs obs=ObsPolicy(enabled=True, "
                         "quant_stats=True)")
    obs = ObsSession.from_policy(plan.obs)
    opt = opt or AdamWConfig(lr=5e-3, weight_decay=0.0)
    cfg = plan.kernel.apply(cfg)
    key = jax.random.PRNGKey(seed)
    params = init_gnn_params(key, cfg, g.n_feats)
    state = adamw_init(params, opt)
    with obs.activate():
        with obs.span("plan/compile", plan=plan.describe()):
            compiled = compile_plan(g, cfg, plan, opt, batches=batches,
                                    mesh=mesh, seed=seed, obs=obs)
        ctrl = None
        if plan.precision.kind == "autoprec":
            cal_gt, cal_labels, cal_mask, cal_nm = compiled.calibration()
            ctrl = AutoprecController(cal_gt, cal_labels, cal_mask, cfg,
                                      plan.precision.bit_budget,
                                      plan.precision.refresh, seed,
                                      node_mask=cal_nm,
                                      calibration=plan.precision.calibration)
            with obs.span("autoprec/solve", epoch=0):
                cfg, _ = ctrl.allocate(params)
            with obs.span("plan/recompile", epoch=0):
                compiled = compiled.recompile(cfg)
            obs.counter("engine/recompiles").inc()
        eval_fn = jax.jit(partial(_accuracy, cfg=cfg))
        gt = graph_tuple(g)
        order_rng = seeds.order_rng(seed)
        history = []
        with stopwatch("train/epochs", epochs=n_epochs) as sw:
            for epoch in range(n_epochs):
                if ctrl is not None and ctrl.due(epoch):
                    with obs.span("autoprec/solve", epoch=epoch):
                        cfg, changed = ctrl.allocate(params)
                    if changed:
                        with obs.span("plan/recompile", epoch=epoch):
                            compiled = compiled.recompile(cfg)
                        obs.counter("engine/recompiles").inc()
                with obs.span("epoch", epoch=epoch):
                    data = compiled.epoch_data(order_rng)
                    params, state, loss = compiled.step(params, state,
                                                        jnp.asarray(epoch),
                                                        *data)
                if obs.quant_due(epoch):
                    with obs.span("obs/quant_probe", epoch=epoch):
                        obs.quant_probe(params, _probe_graph(compiled, gt),
                                        epoch, cfg)
                if verbose and (epoch % eval_every == 0
                                or epoch == n_epochs - 1):
                    va = eval_fn(params, gt, g.labels,
                                 g.val_mask.astype(jnp.float32))
                    history.append((epoch, float(loss), float(va)))
            jax.block_until_ready(params)
    extra = ctrl.extras() if ctrl is not None else {}
    extra.update(compiled.result_extras())
    extra["cfg"] = cfg
    extra["plan"] = plan
    if obs.enabled:
        extra["obs"] = obs
    return _result(eval_fn, params, g, gt, history, n_epochs, sw.elapsed_s,
                   **extra)
