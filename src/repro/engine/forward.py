"""THE stash-aware GNN forward: one ``custom_vjp`` over the whole network
for every training path.

Every combination of the engine's stash axis routes through this single
implementation — classic per-tensor residuals (``StashPolicy(kind=
"tensor")``), pooled device arenas, and host-offloaded arenas — by
swapping the writer/reader pair from :mod:`repro.offload.engine`.  Before
the engine refactor this forward existed twice: implicitly, as the
composition of the per-op ``compressed_matmul`` / ``relu_1bit``
``custom_vjp``s autodiff stitched together inside ``graph/train.py``'s
two step builders, and explicitly as the arena-routed whole-net
``custom_vjp`` in ``offload/gnn.py``.  Both spellings produce
bit-identical gradients (the manual walk below *is* what autodiff
emitted), so they collapsed into this one.

Forward: exactly :func:`repro.graph.models.gnn_forward` — same layer
math, same per-layer seeds (:func:`repro.engine.seeds.layer_seed`), same
padding-mask pinning — except every layer's stash (compressed linear
input, or raw f32 for uncompressed layers, plus the packed 1-bit ReLU
sign mask) goes through the policy's writer.

Backward: a manual layer-by-layer reverse walk mirroring what autodiff
produces on the per-op path — ``dx = g @ wᵀ`` exact, ``dw = x̂ᵀ g`` at
the reconstruction (EXACT's estimator), ReLU via the saved sign mask,
and the Â-product transposed by swapping the edge list's src/dst roles.
Arena readers prefetch layer ``li-1``'s segments before layer ``li``'s
gradient math so host→device copies run one layer ahead
(double-buffered); the per-tensor reader's prefetch is a no-op.

Cotangents are returned for params and features; edge weights and the
padding mask are non-differentiable graph constants (zero cotangents) —
the training engines only ever differentiate with respect to params.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as packmod
from repro.core.act_compress import zero_ct
from repro.core.compressor import compress_matmul, decompress_matmul
from repro.engine import seeds
from repro.engine.plan import StashPolicy
from repro.offload import engine as stash_engine
from repro.offload.arena import StashPlan
from repro.offload.gnn import plan_gnn_stashes  # noqa: F401  (re-export)

#: The per-tensor policy every plain (non-offload) training path uses.
TENSOR_STASH = StashPolicy(kind="tensor", placement="device")


@functools.lru_cache(maxsize=None)
def _build(cfg, plan: StashPlan, stash: StashPolicy, fused: str = "auto"):
    """The custom_vjp forward for one (GNNConfig, StashPlan, StashPolicy,
    fused-mode) tuple.

    ``fused`` is :class:`repro.engine.plan.KernelPolicy`'s knob for the
    quantize-in-epilogue matmul pair; routing (and the per-layer unfused
    fallback) lives in :func:`repro.core.backend.route_fused`, reached
    here through the ``compress_matmul`` / ``decompress_matmul``
    orchestrators."""
    # deferred import: graph.models lazily dispatches into this module;
    # sharing models' spmm keeps the Â-product — and hence the bit-parity
    # contract — single-sourced
    from repro.graph.models import spmm as _spmm

    from repro.graph.models import gnn_forward
    from repro.obs.metrics import get_metrics

    # every _build body is an lru_cache miss — i.e. a fresh custom_vjp
    # trace the plan compiler will pay for; the obs registry counts them
    # as the engine's recompile pressure
    get_metrics().counter("engine/forward_builds").inc()

    per_layer = cfg.layer_compression()
    sage = cfg.arch == "sage"
    L = len(plan.layers)

    def layer_input(h, src, dst, mean_w, n):
        if not sage:
            return h
        return jnp.concatenate([h, _spmm(h, src, dst, mean_w, n)], axis=1)

    @jax.custom_vjp
    def f(params, feats, src, dst, gcn_w, mean_w, seed, nm):
        # primal path (un-differentiated calls): the per-op forward is
        # value-identical and stash-free (compressed_matmul / relu_1bit
        # primals are plain x @ w / maximum), so don't re-state the layer
        # math a third time
        return gnn_forward(params, (feats, src, dst, gcn_w, mean_w), cfg,
                           seed=seed, node_mask=nm)

    def f_fwd(params, feats, src, dst, gcn_w, mean_w, seed, nm):
        n = feats.shape[0]
        writer = stash_engine.make_writer(plan, stash.placement, seed,
                                          kind=stash.kind)
        h = feats * nm[:, None]
        for li, p in enumerate(params):
            lseed = seeds.layer_seed(seed, li)
            x = layer_input(h, src, dst, mean_w, n)
            comp = per_layer[li]
            if comp is None:
                writer.put_raw(li, x)
                z = x @ p["w"] + p["b"]
            else:
                # fused path: x is quantized+packed in the matmul epilogue
                # (one HBM read of x); routing falls back to the unfused
                # compress + x @ w spelling per layer when declined
                y, ct = compress_matmul(x, p["w"], comp, lseed, fused=fused)
                writer.put_ct(li, ct)
                z = y + p["b"]
            if not sage:
                z = _spmm(z, src, dst, gcn_w, n)
            if li < L - 1:
                writer.put_mask(li, packmod.pack(
                    (z > 0).astype(jnp.int32).reshape(1, -1), 1))
                z = jnp.maximum(z, 0.0)
            h = z * nm[:, None]
        return h, (params, src, dst, gcn_w, mean_w, nm, writer.residual())

    def f_bwd(res, gy):
        params, src, dst, gcn_w, mean_w, nm, residual = res
        n = nm.shape[0]
        reader = stash_engine.make_reader(plan, stash.placement, residual,
                                          kind=stash.kind)
        reader.prefetch(L - 1)
        gh = gy
        dparams = [None] * L
        for li in reversed(range(L)):
            if li > 0:
                reader.prefetch(li - 1)  # one layer ahead of the compute
            p = params[li]
            lp = plan.layers[li]
            g = gh * nm[:, None]
            if li < L - 1:
                m = packmod.unpack(reader.get_mask(li), 1, lp.mask_elems)
                g = g * m.reshape(g.shape).astype(g.dtype)
            # transpose of the output-side Â product (gcn applies it
            # after the linear): swap the edge list's src/dst roles
            gz = g if sage else _spmm(g, dst, src, gcn_w, n)
            g2 = gz.reshape(-1, gz.shape[-1])
            if lp.cfg is None:
                x_hat = reader.get_raw(li)
                x2 = x_hat.reshape(-1, x_hat.shape[-1])
                dw = x2.T @ g2
                xdtype = x_hat.dtype
            else:
                # fused path: stash dequantized in the backward matmul's
                # prologue (no f32 reconstruction round-trips HBM)
                ct = reader.get_ct(li)
                dw = decompress_matmul(ct, g2, fused=fused)
                xdtype = ct.dtype
            dparams[li] = {"w": dw.astype(p["w"].dtype),
                           "b": jnp.sum(gz, axis=0).astype(p["b"].dtype)}
            gx = (gz @ p["w"].T).astype(xdtype)
            if sage:
                d = gx.shape[1] // 2
                gh = gx[:, :d] + _spmm(gx[:, d:], dst, src, mean_w, n)
            else:
                gh = gx
        dfeats = gh * nm[:, None]
        return (dparams, dfeats, zero_ct(src), zero_ct(dst),
                jnp.zeros_like(gcn_w), jnp.zeros_like(mean_w),
                np.zeros((), jax.dtypes.float0), jnp.zeros_like(nm))

    f.defvjp(f_fwd, f_bwd)
    return f


def stash_gnn_forward(params, graph, cfg, plan: StashPlan,
                      stash: StashPolicy = TENSOR_STASH, seed=0,
                      node_mask=None, fused: str = "auto"):
    """The engine's forward: ``gnn_forward`` values with the layer stashes
    routed through ``stash``'s writer (per-tensor or pooled arena)."""
    if len(plan.layers) != cfg.n_layers:
        raise ValueError(f"plan has {len(plan.layers)} layers for a "
                         f"{cfg.n_layers}-layer model")
    feats, src, dst, gcn_w, mean_w = graph
    nm = (jnp.ones((feats.shape[0],), feats.dtype) if node_mask is None
          else node_mask.astype(feats.dtype))
    fn = _build(cfg, plan, stash, fused)
    return fn(params, feats, src, dst, gcn_w, mean_w,
              jnp.asarray(seed, jnp.uint32), nm)


def arena_gnn_forward(params, graph, cfg, plan: StashPlan, seed=0,
                      node_mask=None, policy: str = "device"):
    """Drop-in for :func:`repro.graph.models.gnn_forward` with the stash
    pooled into an arena under the given offload policy (the legacy
    arena-only spelling of :func:`stash_gnn_forward`)."""
    stash_engine.check_policy(policy)
    return stash_gnn_forward(params, graph, cfg, plan,
                             StashPolicy(kind="arena", placement=policy),
                             seed=seed, node_mask=node_mask)


# --------------------------------------------------------- mesh forward
def mesh_stash_plan(cfg, in_dim: int, n_local: int) -> StashPlan:
    """Halo-aware stash planning for the mesh lowering: the plan of ONE
    device's saved-for-backward bytes.

    Every stash the mesh forward creates is partition-local —
    ``compressed_matmul`` compresses the local ``(n_local, d)`` linear
    input (halo rows feed only the *aggregation*, whose VJP needs no
    float activations), and the ReLU sign mask covers local rows only.
    So the per-device plan is exactly the single-device plan at the
    partition's padded node count: the halo strip contributes zero stash
    bytes by construction.  This ledger backs the mesh arm of
    ``activation_memory_report`` and the ≥2x per-device peak gate in
    ``BENCH_gnn_dist.json``.
    """
    return plan_gnn_stashes(cfg, in_dim, n_local)


def mesh_gnn_forward(params, feats, esrc, edst, gcn_w, mean_w, nm, send_idx,
                     cfg, *, seed, axis: str | None = "graph"):
    """One device's slice of the mesh-sharded GNN forward.

    The same per-layer math as :func:`repro.graph.models.gnn_forward`
    composed from the per-op ``custom_vjp`` stack (``compressed_matmul``,
    ``relu_1bit``, ``spmm``) — bit-identical gradients to the engine's
    stash forward per the PR 5 parity gate — with one addition: before
    each aggregation, :func:`repro.parallel.halo.halo_exchange` extends
    the aggregated tensor with the round-mates' boundary rows.  GCN
    exchanges the biased pre-aggregation output (receivers need the
    sender's full ``x @ w + b`` value); SAGE exchanges ``h`` ahead of its
    input-side mean aggregation.  Edge tables come pre-extended from
    :func:`repro.parallel.halo.build_halo_program`; ``axis=None`` (or a
    zero halo width) runs the identical single-device computation.

    Only local activations are ever stashed for backward — see
    :func:`mesh_stash_plan`.
    """
    from repro.core.act_compress import compressed_matmul
    from repro.graph.models import relu_1bit, spmm
    from repro.parallel.halo import halo_exchange

    per_layer = cfg.layer_compression()
    n = feats.shape[0]
    seed = jnp.asarray(seed, jnp.uint32)
    h = feats * nm[:, None]
    for li, p in enumerate(params):
        lseed = seeds.layer_seed(seed, li)
        comp = per_layer[li]
        if cfg.arch == "gcn":
            z = (h @ p["w"] if comp is None
                 else compressed_matmul(h, p["w"], lseed, comp)) + p["b"]
            z = spmm(halo_exchange(z, send_idx, axis), esrc, edst, gcn_w, n)
        else:  # sage
            agg = spmm(halo_exchange(h, send_idx, axis), esrc, edst,
                       mean_w, n)
            x = jnp.concatenate([h, agg], axis=1)
            z = (x @ p["w"] if comp is None
                 else compressed_matmul(x, p["w"], lseed, comp)) + p["b"]
        if li < len(params) - 1:
            z = relu_1bit(z)
        h = z * nm[:, None]
    return h
