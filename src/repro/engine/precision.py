"""Autoprec as a plan policy: the variance-guided bit-allocation
lifecycle behind ``PrecisionPolicy(kind="autoprec")``.

Owns the budget (frozen on the first allocation so refreshes re-split
the *same* byte ceiling), the current per-layer widths, and the refresh
cadence.  The engine's run loop asks :meth:`AutoprecController.due` each
epoch and, when an :meth:`allocate` changes the widths, recompiles the
plan's epoch step — the refresh is a plan-recompile hook, not a bespoke
step rebuild (pre-engine, both training loops re-implemented this
make_step dance by hand).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import autoprec
from repro.engine import seeds


def _probe_loss(params, graph, labels, mask, cfg, seed, node_mask=None):
    """The calibration loss: the plain per-op forward (no stash routing —
    probing under a host-offload policy must not pay offload overhead;
    every stash policy produces bit-identical gradients anyway)."""
    # lazy: engine.compile imports the graph package
    from repro.engine.compile import masked_nll
    from repro.graph.models import gnn_forward

    logits = gnn_forward(params, graph, cfg, seed=seed, node_mask=node_mask)
    return masked_nll(logits, labels, mask)


class AutoprecController:
    """Variance-guided bit-allocation lifecycle shared by every plan.

    ``allocate`` runs the cheap stats pass on the calibration graph it
    was given — the full graph for full-graph sampling, a single padded
    subgraph batch for the partition engine (so the probe never
    re-materializes the full-graph activations the batched engine exists
    to avoid; per-layer moments and noise ratios are scale-invariant) —
    and calibrates each layer's ``grad_sens`` with a two-seed gradient
    probe: ``dx`` and the ReLU mask are SR-noise-free, so
    ``dw_l(s₁) − dw_l(s₂)`` isolates exactly the dequantization noise
    layer l's stash injects.

    ``calibration="obs"`` replaces the grad probe with the quant-health
    telemetry channel (:mod:`repro.obs.quantstats`): the *measured* SR
    dequantization variance at the template widths, divided by the same
    bit-scaling curve — one probe pass instead of two gradient passes,
    and the sensitivity source is the very statistic the runtime monitor
    reports against the Eq. 10 prediction.
    """

    def __init__(self, gt, labels, tr_mask, cfg, bit_budget: float,
                 refresh: int, seed: int, node_mask=None,
                 calibration: str = "probe"):
        self.templates = cfg.layer_compression()
        if all(c is None for c in self.templates):
            raise ValueError(
                "bit_budget= needs a GNNConfig with compression configured")
        self.base_cfg = cfg
        self.bit_budget = float(bit_budget)
        self.refresh = int(refresh)
        self.gt = gt
        self.labels = labels
        self.tr_mask = tr_mask
        self.node_mask = node_mask
        self.seed = seed
        self.calibration = calibration
        self.budget_bytes = None
        self.bits: tuple[int, ...] | None = None
        self._grad_fn = jax.jit(jax.grad(_probe_loss), static_argnums=(4,))

    def _probe_grad_sens(self, params, stats):
        """Realized per-layer dw SR noise at template widths, divided by the
        bit-scaling curve — so any candidate width re-prices as
        ``grad_sens * normalized_sr_variance(candidate)``."""
        s1, s2 = seeds.probe_seeds(self.seed)
        g1 = self._grad_fn(params, self.gt, self.labels, self.tr_mask,
                           self.base_cfg, s1, self.node_mask)
        g2 = self._grad_fn(params, self.gt, self.labels, self.tr_mask,
                           self.base_cfg, s2, self.node_mask)
        out = []
        for st, tmpl, p1, p2 in zip(stats, self.templates, g1, g2):
            if st is None or tmpl is None:
                out.append(st)
                continue
            noise = float(0.5 * jnp.sum((p1["w"] - p2["w"]) ** 2))
            sens = noise / max(autoprec.normalized_sr_variance(tmpl), 1e-30)
            # a zero probe (e.g. untrained head with zero grads) keeps the
            # range-moment fallback rather than marking the layer free
            out.append(dataclasses.replace(st, grad_sens=sens or None))
        return out

    def _obs_sens(self, params, stats):
        """Telemetry-sourced sensitivities: the measured dequantization
        variance of each layer's stash at the template width, re-priced
        through :func:`repro.core.autoprec.normalized_sr_variance` — the
        ``grad_sens`` contract without any gradient pass."""
        from repro.obs.quantstats import (measure_quant_health,
                                          measured_sensitivity)

        measured = measure_quant_health(params, self.gt, self.base_cfg,
                                        seed=self.seed)
        sens = measured_sensitivity(measured, self.templates)
        out = []
        for st, s in zip(stats, sens):
            if st is None or s is None:
                out.append(st)
                continue
            # a degenerate zero measurement (constant activations) keeps
            # the range-moment fallback, like a zero grad probe
            out.append(dataclasses.replace(st, grad_sens=s or None))
        return out

    def allocate(self, params):
        """(re)solve the allocation; returns (cfg, changed)."""
        from repro.graph.analysis import collect_layer_stats

        stats = collect_layer_stats(params, self.gt, self.base_cfg,
                                    seed=self.seed)
        if self.budget_bytes is None:
            self.budget_bytes = autoprec.budget_bytes_for(
                stats, self.templates, self.bit_budget)
        stats = (self._obs_sens(params, stats)
                 if self.calibration == "obs"
                 else self._probe_grad_sens(params, stats))
        bits = autoprec.allocate_bits(stats, self.templates,
                                      self.budget_bytes)
        changed = bits != self.bits
        self.bits = bits
        return self.base_cfg.with_layer_bits(bits), changed

    def due(self, epoch: int) -> bool:
        return self.refresh > 0 and epoch > 0 and epoch % self.refresh == 0

    def extras(self) -> dict:
        return {"bits_per_layer": list(self.bits),
                "bit_budget_bytes": self.budget_bytes}
