"""ExecutionPlan: the declarative contract between every GNN training
entry point and the engine compiler.

A plan composes five **orthogonal** policies:

* :class:`SamplingPolicy` — what is live at once: the full graph, or
  padded partition-sampled subgraph batches (Cluster-GCN flavor) with
  their bucketing / halo / shuffle / grad-accum knobs;
* :class:`PrecisionPolicy` — fixed per-layer widths (whatever the
  ``GNNConfig`` carries), or a variance-guided autoprec byte budget with
  an optional refresh cadence (a refresh that changes the allocation
  triggers a plan recompile, not a bespoke step rebuild);
* :class:`StashPolicy` — how saved-for-backward state is stored:
  scattered per-tensor pytree residuals, or one pooled arena, placed on
  device / host / pinned-paged host memory;
* :class:`KernelPolicy` — which kernel backend the compression stack
  runs on (``jnp | interp | pallas | auto``, see
  :mod:`repro.core.backend`);
* :class:`~repro.obs.policy.ObsPolicy` — runtime observability: spans,
  metrics, and the quant-health telemetry channel (:mod:`repro.obs`).
  Default-disabled; enabling it never changes trajectories (read-only
  taps, gated bit-identical in ``tests/test_obs.py``).

``train_gnn`` / ``train_gnn_batched`` are thin wrappers that build a plan
with :meth:`ExecutionPlan.from_legacy` and hand it to
:func:`repro.engine.runner.run`; ``launch.train``, the benchmarks, and
``activation_memory_report`` construct plans directly so the memory/bit
accounting reads the exact object training executed.

Plans are frozen, hashable dataclasses: they ride as static arguments of
jitted steps and key the compiler's forward cache.
"""
from __future__ import annotations

import dataclasses

from repro.core.backend import VALID_FUSED, VALID_IMPLS
from repro.obs.policy import ObsPolicy
from repro.offload.engine import POLICIES as STASH_PLACEMENTS

SAMPLING_KINDS = ("full", "partition", "mesh")
PRECISION_KINDS = ("fixed", "autoprec")
CALIBRATION_KINDS = ("probe", "obs")
STASH_KINDS = ("tensor", "arena")


@dataclasses.dataclass(frozen=True)
class SamplingPolicy:
    """Full-graph, partition-sampled padded mini-batches, or mesh-sharded
    partition-parallel training.

    ``kind="mesh"`` shards the ``n_parts`` partitions across a ``graph``
    device mesh axis of size ``m`` (``m`` must divide ``n_parts``) and
    trains them in ``n_parts // m`` rounds with a per-layer halo exchange
    between the round's co-resident partitions
    (:mod:`repro.parallel.halo`); the full feature matrix stays
    host-resident behind :class:`repro.offload.pager.FeaturePager`.
    ``m == 1`` is exactly the batched engine (static round order, one
    partition live at a time); ``m == n_parts`` is exact distributed
    full-graph training.  The ``halo``/``renormalize``/``grad_accum``/
    ``shuffle`` knobs belong to the partition engine: mesh halo context
    is structural (the exchange), rounds run one update each in static
    order.
    """

    kind: str = "full"            # "full" | "partition" | "mesh"
    n_parts: int = 1
    method: str = "bfs"           # "bfs" | "random"
    halo: int = 0
    node_multiple: int = 64
    edge_multiple: int = 256
    renormalize: bool = False
    shuffle: bool = True
    grad_accum: int = 1

    def __post_init__(self):
        # Validation errors name the offending field as ``policy.field=value``
        # so callers (and repro.staticcheck.plan_verify, which re-raises
        # these messages as findings) can point at the exact knob to fix.
        if self.kind not in SAMPLING_KINDS:
            raise ValueError(f"sampling.kind={self.kind!r} not in "
                             f"{SAMPLING_KINDS}")
        if self.n_parts < 1:
            raise ValueError(f"sampling.n_parts={self.n_parts} must be >= 1")
        if self.grad_accum < 1:
            raise ValueError(f"sampling.grad_accum={self.grad_accum} "
                             "must be >= 1")
        if self.kind == "full" and self.n_parts != 1:
            raise ValueError(f"sampling.n_parts={self.n_parts} is "
                             "incompatible with sampling.kind='full' "
                             "(full-graph sampling has exactly one "
                             "partition)")
        if self.kind == "mesh":
            if self.grad_accum != 1:
                raise ValueError(f"sampling.grad_accum={self.grad_accum} is "
                                 "incompatible with sampling.kind='mesh' "
                                 "(mesh rounds run one update each; "
                                 "grad_accum needs kind='partition')")
            if self.halo != 0:
                raise ValueError(f"sampling.halo={self.halo} is incompatible "
                                 "with sampling.kind='mesh' (mesh halo "
                                 "context is structural — the per-layer "
                                 "exchange; the sampling halo knob applies "
                                 "to kind='partition' only)")
            if self.renormalize:
                raise ValueError("sampling.renormalize=True is incompatible "
                                 "with sampling.kind='mesh' (mesh slices "
                                 "full-graph aggregation weights; "
                                 "renormalize needs kind='partition')")


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Fixed widths from the ``GNNConfig``, or an autoprec byte budget.

    ``bit_budget`` is the average stash bits per element (2.0 = the fixed
    INT2 footprint); ``refresh=k`` re-collects sensitivity stats and
    re-solves every k epochs (0 = allocate once).  A refresh that changes
    the allocation recompiles the plan's epoch step.

    ``calibration`` picks the per-layer sensitivity source: ``"probe"``
    (the two-seed gradient probe) or ``"obs"`` (the measured SR
    dequantization variance from the quant-health telemetry channel —
    requires ``ObsPolicy(enabled=True, quant_stats=True)`` on the plan).
    """

    kind: str = "fixed"           # "fixed" | "autoprec"
    bit_budget: float | None = None
    refresh: int = 0
    calibration: str = "probe"    # "probe" | "obs"

    def __post_init__(self):
        if self.kind not in PRECISION_KINDS:
            raise ValueError(f"precision.kind={self.kind!r} not in "
                             f"{PRECISION_KINDS}")
        if self.calibration not in CALIBRATION_KINDS:
            raise ValueError(f"precision.calibration={self.calibration!r} "
                             f"not in {CALIBRATION_KINDS}")
        if self.kind == "autoprec" and self.bit_budget is None:
            raise ValueError("precision.bit_budget=None is incompatible "
                             "with precision.kind='autoprec' (autoprec "
                             "needs a bits-per-element budget)")
        if self.kind == "fixed" and self.bit_budget is not None:
            raise ValueError(f"precision.bit_budget={self.bit_budget} is "
                             "incompatible with precision.kind='fixed' "
                             "(use kind='autoprec')")
        if self.kind == "fixed" and self.calibration != "probe":
            raise ValueError(f"precision.calibration={self.calibration!r} "
                             "is incompatible with precision.kind='fixed' "
                             "(calibration is an autoprec knob)")


@dataclasses.dataclass(frozen=True)
class StashPolicy:
    """Where saved-for-backward stashes live.

    kind "tensor"   — classic per-tensor pytree residuals (placement must
                      be "device"; there is nothing pooled to move);
    kind "arena"    — one pooled u32+f32 arena pair per forward
                      (:mod:`repro.offload.arena`), placed per
                      ``placement`` ∈ {"device", "host", "pinned-paged"}.
    """

    kind: str = "tensor"          # "tensor" | "arena"
    placement: str = "device"     # "device" | "host" | "pinned-paged"

    def __post_init__(self):
        if self.kind not in STASH_KINDS:
            raise ValueError(f"stash.kind={self.kind!r} not in "
                             f"{STASH_KINDS}")
        if self.placement not in STASH_PLACEMENTS:
            raise ValueError(f"stash.placement={self.placement!r} (the "
                             f"offload= policy) not in {STASH_PLACEMENTS}")
        if self.kind == "tensor" and self.placement != "device":
            raise ValueError(f"stash.placement={self.placement!r} is "
                             "incompatible with stash.kind='tensor' "
                             "(per-tensor stashes are device-resident; "
                             "pooled placements need kind='arena')")

    @property
    def offload(self) -> str | None:
        """The legacy ``offload=`` kwarg this policy corresponds to."""
        return None if self.kind == "tensor" else self.placement


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Kernel backend override for the compression stack (None = keep
    whatever each layer's ``CompressionConfig.impl`` already says).

    ``fused`` governs the quantize-in-epilogue matmul pair
    (:func:`repro.core.compress_matmul` / ``decompress_matmul``):

    * ``"auto"`` — fuse each layer where it wins: eligible stash shapes
      on the real Pallas backend; reference impls keep the unfused
      spelling (so CPU trajectories are unchanged by default);
    * ``"on"``  — force the fused pair on every layer (ineligible layer
      configs raise, see :func:`repro.core.backend.route_fused`);
    * ``"off"`` — never fuse.
    """

    impl: str | None = None
    fused: str = "auto"

    def __post_init__(self):
        if self.impl is not None and self.impl not in VALID_IMPLS:
            raise ValueError(f"kernel.impl={self.impl!r} not in "
                             f"{VALID_IMPLS}")
        if self.fused not in VALID_FUSED:
            raise ValueError(f"kernel.fused={self.fused!r} not in "
                             f"{VALID_FUSED}")

    def apply(self, cfg):
        """Reroute a GNNConfig's compression stack onto this backend."""
        return cfg if self.impl is None else cfg.with_impl(self.impl)


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    sampling: SamplingPolicy = SamplingPolicy()
    precision: PrecisionPolicy = PrecisionPolicy()
    stash: StashPolicy = StashPolicy()
    kernel: KernelPolicy = KernelPolicy()
    obs: ObsPolicy = ObsPolicy()

    @classmethod
    def from_legacy(cls, *, n_parts: int | None = None,
                    impl: str | None = None, fused: str = "auto",
                    offload: str | None = None,
                    bit_budget: float | None = None,
                    autoprec_refresh: int = 0, method: str = "bfs",
                    halo: int = 0, node_multiple: int = 64,
                    edge_multiple: int = 256, renormalize: bool = False,
                    shuffle: bool = True, grad_accum: int = 1,
                    obs: ObsPolicy | None = None) -> "ExecutionPlan":
        """Build the plan a pre-engine kwarg spelling means.

        ``n_parts=None`` is the full-graph loop; any integer (1 included)
        is the partition-sampled engine.  ``offload=None`` keeps classic
        per-tensor residuals; a policy string pools them into an arena at
        that placement.
        """
        if n_parts is None:
            sampling = SamplingPolicy()
        else:
            sampling = SamplingPolicy(
                kind="partition", n_parts=n_parts, method=method, halo=halo,
                node_multiple=node_multiple, edge_multiple=edge_multiple,
                renormalize=renormalize, shuffle=shuffle,
                grad_accum=grad_accum)
        if bit_budget is None:
            precision = PrecisionPolicy()
        else:
            precision = PrecisionPolicy(kind="autoprec",
                                        bit_budget=float(bit_budget),
                                        refresh=int(autoprec_refresh))
        stash = (StashPolicy() if offload is None
                 else StashPolicy(kind="arena", placement=offload))
        return cls(sampling=sampling, precision=precision, stash=stash,
                   kernel=KernelPolicy(impl=impl, fused=fused),
                   obs=obs if obs is not None else ObsPolicy())

    @property
    def offload(self) -> str | None:
        """Legacy ``offload=`` view of the stash policy (for reports)."""
        return self.stash.offload

    def describe(self) -> str:
        """One-line human summary (launcher / benchmark logs)."""
        s = self.sampling
        if s.kind == "full":
            samp = "full-graph"
        elif s.kind == "mesh":
            samp = f"mesh x{s.n_parts} ({s.method})"
        else:
            samp = f"partition x{s.n_parts} ({s.method}, halo={s.halo})"
        prec = ("fixed" if self.precision.kind == "fixed"
                else f"autoprec {self.precision.bit_budget} bits/elt "
                     f"(refresh {self.precision.refresh})")
        stash = (f"{self.stash.kind}@{self.stash.placement}")
        base = (f"sampling={samp} | precision={prec} | stash={stash} | "
                f"kernel={self.kernel.impl or 'cfg'}"
                f" fused={self.kernel.fused}")
        if self.obs.enabled:
            on = [tag for tag, flag in (("trace", self.obs.trace),
                                        ("metrics", self.obs.metrics),
                                        ("quant", self.obs.quant_stats))
                  if flag]
            base += f" | obs={'+'.join(on) or 'on'}"
        return base
