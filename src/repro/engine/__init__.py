"""Plan → compile → execute: the unified GNN training engine.

One :class:`ExecutionPlan` composes four orthogonal policies — sampling
(full-graph | partitioned mini-batch), precision (fixed | autoprec
budget with refresh), stash (per-tensor | arena, on device | host |
pinned-paged), and kernel backend (jnp | interp | pallas | auto).  The
compiler (:mod:`repro.engine.compile`) lowers any plan to ONE jitted
epoch step built on the single stash-aware ``custom_vjp`` forward
(:mod:`repro.engine.forward`), and :func:`repro.engine.runner.run` drives
it.  ``train_gnn`` / ``train_gnn_batched`` are thin plan-building
wrappers over this package.

Import shape: :mod:`~repro.engine.plan` and :mod:`~repro.engine.seeds`
are dependency-light and load eagerly (``graph.models`` pulls the seed
scheme at import time); the compiler/runtime modules import the graph
package and resolve lazily via PEP 562 so neither import order deadlocks.
"""
from __future__ import annotations

import importlib

from repro.engine import seeds  # noqa: F401
from repro.engine.plan import (ExecutionPlan, KernelPolicy,  # noqa: F401
                               ObsPolicy, PrecisionPolicy, SamplingPolicy,
                               StashPolicy)

_LAZY = {
    "run": "repro.engine.runner",
    "compile_plan": "repro.engine.compile",
    "engine_loss": "repro.engine.compile",
    "masked_nll": "repro.engine.compile",
    "stash_gnn_forward": "repro.engine.forward",
    "arena_gnn_forward": "repro.engine.forward",
    "plan_gnn_stashes": "repro.engine.forward",
    "TENSOR_STASH": "repro.engine.forward",
    "AutoprecController": "repro.engine.precision",
}

__all__ = ["ExecutionPlan", "SamplingPolicy", "PrecisionPolicy",
           "StashPolicy", "KernelPolicy", "ObsPolicy", "seeds", *_LAZY]


def __getattr__(name: str):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.engine' has no attribute {name!r}")
