"""Offload engine: where the arena bytes live between forward and backward.

Policies
--------
* ``"device"``       — the pooled arenas stay on device; the backward
                       pass slices segments straight out of them.
* ``"host"``         — every layer's segments move device→host right
                       after that layer's forward stash; the backward
                       walk prefetches them host→device one layer ahead
                       (double-buffered: at most two layers' segments
                       are device-resident at once).
* ``"pinned-paged"`` — like ``"host"`` but pins to the ``pinned_host``
                       memory space and pages the packed-code segment in
                       fixed-size pages (DMA-friendly granularity).

Mechanisms
----------
On platforms that expose a host memory space distinct from the device's
default (TPU/GPU: ``pinned_host``), segments are moved with memory-kind
``jax.device_put`` — asynchronous under XLA, so backward prefetch
overlaps with the previous layer's gradient math.  Everywhere else
(CPU: the default memory *is* unpinned host) the engine falls back to a
**synchronous pure-callback host store**: writes copy the segment into a
Python-side numpy store keyed by ``(forward key, layer tag)`` and return
a ticket; reads take the ticket as an operand, which both enforces
write-before-read ordering inside the XLA program and keeps the writes
from being dead-code-eliminated.  Both mechanisms are bit-preserving, so
``offload="host"`` training matches ``offload="device"`` exactly.

The per-tensor helpers :func:`offload_compressed` /
:func:`fetch_compressed` apply the same callback mechanism to a single
``CompressedTensor`` residual — that is what the transformer ``lax.scan``
path uses (scan stacks residuals across iterations, so its per-layer
residual must be a tiny ticket, not a host-kind array).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backend
from repro.core.compressor import CompressedTensor, CompressionConfig
from repro.core.prng import KNUTH_MULT
from repro.offload import arena as ar

POLICIES = ("device", "host", "pinned-paged")

#: Page size (uint32 words) for the "pinned-paged" packed-code paging.
PAGE_WORDS = 1 << 15


def check_policy(policy: str | None) -> str | None:
    if policy is not None and policy not in POLICIES:
        raise ValueError(f"offload={policy!r} not in {POLICIES}")
    return policy


def host_memory_kind(policy: str = "host") -> str | None:
    """The host memory space to offload into, or None if the platform has
    none distinct from the device default (then the callback store is
    used).  ``pinned-paged`` insists on ``pinned_host``; ``host`` takes
    any non-default host kind, preferring pinned."""
    try:
        dev = jax.devices()[0]
        kinds = {m.kind for m in dev.addressable_memories()}
        default = dev.default_memory().kind
    except Exception:
        return None
    candidates = (("pinned_host",) if policy == "pinned-paged"
                  else ("pinned_host", "unpinned_host"))
    for k in candidates:
        if k in kinds and k != default:
            return k
    return None


def resolve_mechanism(policy: str) -> str:
    check_policy(policy)
    if policy == "device":
        return "device"
    return "memkind" if host_memory_kind(policy) else "callback"


# ----------------------------------------------------- measurement helpers
def measure_live_bytes() -> int:
    """Total bytes of live jax arrays on this host (best-effort gauge the
    ledger is validated against in tests/benchmarks)."""
    return int(sum(int(getattr(a, "nbytes", 0)) for a in jax.live_arrays()))


def device_memory_stats() -> dict | None:
    """Raw device memory stats (``peak_bytes_in_use`` etc.) where the
    backend exposes them (TPU/GPU); None on CPU."""
    try:
        return jax.local_devices()[0].memory_stats()
    except Exception:
        return None


def device_resident_stash_bytes(plan: ar.StashPlan, policy: str) -> int:
    """Ledger model of *device-resident* stash bytes during backward.

    device: the whole pooled arena.  host / pinned-paged: the
    double-buffered prefetch window — the two largest consecutive layer
    segments (at most two layers are on device at once)."""
    if resolve_mechanism(policy) == "device":
        return plan.total_bytes
    sizes = [lp.nbytes for lp in plan.layers]
    if len(sizes) < 2:
        return sum(sizes)
    return max(a + b for a, b in zip(sizes[:-1], sizes[1:]))


# ------------------------------------------------------ callback host store
# Keyed by (int(forward key), int(tag)).  Entries carry a read refcount so
# the store drains exactly when the backward walk has fetched everything.
_HOST_STORE: dict[tuple[int, int], list[np.ndarray]] = {}
_HOST_REFS: dict[tuple[int, int], int] = {}


def host_store_bytes() -> int:
    return int(sum(a.nbytes for arrs in _HOST_STORE.values() for a in arrs))


def host_store_clear() -> None:
    """Drop leaked entries (tests / aborted differentiations)."""
    _HOST_STORE.clear()
    _HOST_REFS.clear()


def _ticket_of(key: int, tag: int) -> np.uint32:
    return np.uint32((int(key) ^ (tag * int(KNUTH_MULT))) & 0xFFFF_FFFF)


def host_put(key, ticket, tag: int, arrays, n_reads: int = 1):
    """Copy ``arrays`` into the host store under ``(key, tag)``.

    ``ticket`` is the previous put's ticket (or ``key`` itself for the
    first): threading it as an operand serializes the writes and keeps
    them live.  Returns this put's ticket.
    """
    def _cb(k, _t, *arrs):
        kk = (int(k), tag)
        _HOST_STORE[kk] = [np.asarray(a).copy() for a in arrs]
        _HOST_REFS[kk] = n_reads
        return _ticket_of(int(k), tag)

    return jax.pure_callback(
        _cb, jax.ShapeDtypeStruct((), jnp.uint32), key, ticket, *arrays,
        vmap_method="sequential")


def host_get(key, ticket, tag: int, out_shapes):
    """Fetch ``(key, tag)`` back from the host store (synchronous).

    ``ticket`` must (transitively) depend on the matching :func:`host_put`
    so XLA cannot hoist the read above the write.  The entry is freed
    once its refcount drains.
    """
    def _cb(k, _t):
        kk = (int(k), tag)
        arrs = _HOST_STORE[kk]
        out = tuple(a.copy() for a in arrs)
        _HOST_REFS[kk] -= 1
        if _HOST_REFS[kk] <= 0:
            del _HOST_STORE[kk], _HOST_REFS[kk]
        return out

    return jax.pure_callback(_cb, tuple(out_shapes), key, ticket,
                             vmap_method="sequential")


# ----------------------------------------------- per-tensor residual offload
@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class HostStash:
    """Tiny residual standing in for a host-offloaded ``CompressedTensor``.

    Only the ticket + forward key are traced; shape/dtype/config are
    static aux, so a ``lax.scan`` stacking these across layers carries a
    few words per layer instead of the codes themselves.
    """

    ticket: jnp.ndarray   # () uint32
    key: jnp.ndarray      # () uint32 — the layer seed that keyed the put
    # --- static ---
    shape: tuple[int, ...]
    dtype: str
    cfg: CompressionConfig

    def tree_flatten(self):
        return (self.ticket, self.key), (self.shape, self.dtype, self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        ticket, key = children
        return cls(ticket, key, *aux)


_CT_TAG = 0xC7  # store tag for per-tensor CompressedTensor residuals


def _ct_shapes(shape, cfg: CompressionConfig):
    lp = ar.plan_stashes((tuple(shape),), (cfg,)).layers[0]
    return (jax.ShapeDtypeStruct((lp.n_blocks, lp.words_per_block),
                                 jnp.uint32),
            jax.ShapeDtypeStruct((lp.n_blocks,), jnp.float32),
            jax.ShapeDtypeStruct((lp.n_blocks,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.uint32))


def offload_compressed(ct: CompressedTensor, key) -> HostStash:
    """Move one ``CompressedTensor``'s fields to the callback host store,
    keyed by the (unique-per-stash) ``key`` seed."""
    key = jnp.asarray(key, jnp.uint32)
    ticket = host_put(key, key, _CT_TAG,
                      (ct.packed, ct.zero, ct.rng, ct.rp_seed))
    return HostStash(ticket, key, shape=tuple(ct.shape),
                     dtype=str(jnp.dtype(ct.dtype)), cfg=ct.cfg)


def fetch_compressed(hs: HostStash) -> CompressedTensor:
    cfg = hs.cfg
    packed, zero, rng, rp_seed = host_get(hs.key, hs.ticket, _CT_TAG,
                                          _ct_shapes(hs.shape, cfg))
    impl = backend.route_quant(cfg.impl, cfg.bits, cfg.group_size,
                               cfg.levels())
    return CompressedTensor(packed, zero, rng, rp_seed, shape=hs.shape,
                            dtype=jnp.dtype(hs.dtype), cfg=cfg, impl=impl)


# ------------------------------------------------------- per-tensor writers
class _TensorWriter:
    """Stash kind "tensor": no pooling, no movement — the residual is the
    classic per-layer pytree of ``CompressedTensor`` / raw-f32 / packed
    ReLU-mask leaves, exactly what the pre-arena per-op ``custom_vjp``
    stack saved.  Under the unified engine forward this makes "plain"
    training and arena-routed training two policies of one code path."""

    def __init__(self, plan, policy, key):
        self._segs = [dict() for _ in plan.layers]

    def put_ct(self, li, ct):
        self._segs[li]["ct"] = ct

    def put_raw(self, li, x):
        self._segs[li]["raw"] = x

    def put_mask(self, li, words):
        self._segs[li]["mask"] = words

    def residual(self):
        return tuple(self._segs)


class _TensorReader:
    def __init__(self, plan, policy, res):
        self._segs = res

    def prefetch(self, li):
        pass  # residual leaves are live device arrays already

    def get_ct(self, li):
        return self._segs[li]["ct"]

    def get_raw(self, li):
        return self._segs[li]["raw"]

    def get_mask(self, li):
        return self._segs[li]["mask"]


# ------------------------------------------------------------ arena writers
def _stash_tag(li: int) -> int:
    return 2 * li


def _mask_tag(li: int) -> int:
    return 2 * li + 1


class _DeviceWriter:
    """Policy "device": write straight into the pooled device arenas."""

    def __init__(self, plan, policy, key):
        self.plan = plan
        self.arenas = ar.arena_init(plan)

    def put_ct(self, li, ct):
        self.arenas = ar.stash_write(self.arenas, self.plan, li, ct)

    def put_raw(self, li, x):
        self.arenas = ar.write_raw(self.arenas, self.plan, li, x)

    def put_mask(self, li, words):
        self.arenas = ar.write_mask(self.arenas, self.plan, li, words)

    def residual(self):
        return self.arenas


class _DeviceReader:
    def __init__(self, plan, policy, res):
        self.plan = plan
        self.arenas = res

    def prefetch(self, li):
        pass  # segments are device-resident slices already

    def get_ct(self, li):
        return ar.stash_read(self.arenas, self.plan, li)

    def get_raw(self, li):
        return ar.read_raw(self.arenas, self.plan, li)

    def get_mask(self, li):
        return ar.read_mask(self.arenas, self.plan, li)


class _MemkindWriter:
    """Host memory-space offload via memory-kind ``jax.device_put``.

    Each layer's segments become host-kind arrays right after the layer
    stashes them; the residual is the per-layer dict of host arrays.
    ``pinned-paged`` splits the packed codes into :data:`PAGE_WORDS`
    pages so prefetch granularity matches DMA-friendly page sizes.
    """

    def __init__(self, plan, policy, key):
        self.plan = plan
        self.paged = policy == "pinned-paged"
        kind = host_memory_kind(policy)
        dev = jax.devices()[0]
        self._host = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
        self.segs = {}

    def _off(self, x):
        return jax.device_put(x, self._host)

    def _off_paged(self, flat):
        if not self.paged or flat.size <= PAGE_WORDS:
            return (self._off(flat),)
        return tuple(self._off(flat[i:i + PAGE_WORDS])
                     for i in range(0, flat.size, PAGE_WORDS))

    def put_ct(self, li, ct):
        self.segs[li] = {"packed": self._off_paged(ct.packed.reshape(-1)),
                         "zero": self._off(ct.zero),
                         "rng": self._off(ct.rng),
                         "rp_seed": self._off(ct.rp_seed)}

    def put_raw(self, li, x):
        self.segs[li] = {"raw": self._off(x)}

    def put_mask(self, li, words):
        self.segs[li]["mask"] = self._off(words)

    def residual(self):
        return tuple(self.segs[li] for li in sorted(self.segs))


class _MemkindReader:
    def __init__(self, plan, policy, res):
        self.plan = plan
        dev = jax.devices()[0]
        self._dev = jax.sharding.SingleDeviceSharding(dev)
        self.segs = dict(enumerate(res))
        self._cache = {}

    def _pop(self, li, field):
        # drop the reader's reference once the field is consumed so the
        # double-buffer claim (≤ 2 layers device-resident) holds even in
        # eager backward walks, where this dict would otherwise pin every
        # fetched copy until the walk ends
        entry = self._cache[li]
        val = entry.pop(field)
        if not entry:
            del self._cache[li]
        return val

    def _fetch(self, li):
        # one device_put per segment — issued when ``prefetch`` runs, one
        # layer ahead of use, so the host→device copy overlaps the
        # previous layer's gradient math under XLA async dispatch
        back = {k: (tuple(jax.device_put(p, self._dev) for p in v)
                    if isinstance(v, tuple)
                    else jax.device_put(v, self._dev))
                for k, v in self.segs[li].items()}
        if "packed" in back:
            back["packed"] = jnp.concatenate(back["packed"])
        return back

    def prefetch(self, li):
        if li not in self._cache:
            self._cache[li] = self._fetch(li)

    def get_ct(self, li):
        lp = self.plan.layers[li]
        self.prefetch(li)
        cfg = lp.cfg
        impl = backend.route_quant(cfg.impl, cfg.bits, cfg.group_size,
                                   cfg.levels())
        packed, zero, rng, rp_seed = (self._pop(li, f) for f in
                                      ("packed", "zero", "rng", "rp_seed"))
        return CompressedTensor(
            packed=packed.reshape(lp.n_blocks, lp.words_per_block),
            zero=zero, rng=rng, rp_seed=rp_seed,
            shape=lp.shape, dtype=jnp.dtype(self.plan.dtype), cfg=cfg,
            impl=impl)

    def get_raw(self, li):
        lp = self.plan.layers[li]
        self.prefetch(li)
        return self._pop(li, "raw").reshape(lp.shape).astype(
            jnp.dtype(self.plan.dtype))

    def get_mask(self, li):
        lp = self.plan.layers[li]
        self.prefetch(li)
        return self._pop(li, "mask").reshape(1, lp.mask.size)


class _CallbackWriter:
    """Synchronous pure-callback host store (the no-host-memory-space
    fallback).  Residual is a single chained ticket + the forward key."""

    def __init__(self, plan, policy, key):
        self.plan = plan
        self.key = jnp.asarray(key, jnp.uint32)
        self.ticket = self.key

    def put_ct(self, li, ct):
        self.ticket = host_put(self.key, self.ticket, _stash_tag(li),
                               (ct.packed, ct.zero, ct.rng, ct.rp_seed))

    def put_raw(self, li, x):
        self.ticket = host_put(self.key, self.ticket, _stash_tag(li), (x,))

    def put_mask(self, li, words):
        self.ticket = host_put(self.key, self.ticket, _mask_tag(li), (words,))

    def residual(self):
        return (self.ticket, self.key)


class _CallbackReader:
    def __init__(self, plan, policy, res):
        self.plan = plan
        self.ticket, self.key = res
        self._cache = {}

    def prefetch(self, li):
        if li in self._cache:
            return
        lp = self.plan.layers[li]
        out = {}
        if lp.packed is not None:
            out["ct"] = host_get(
                self.key, self.ticket, _stash_tag(li),
                (jax.ShapeDtypeStruct((lp.n_blocks, lp.words_per_block),
                                      jnp.uint32),
                 jax.ShapeDtypeStruct((lp.n_blocks,), jnp.float32),
                 jax.ShapeDtypeStruct((lp.n_blocks,), jnp.float32),
                 jax.ShapeDtypeStruct((), jnp.uint32)))
        else:
            out["raw"] = host_get(
                self.key, self.ticket, _stash_tag(li),
                (jax.ShapeDtypeStruct(lp.shape, jnp.float32),))[0]
        if lp.mask is not None:
            out["mask"] = host_get(
                self.key, self.ticket, _mask_tag(li),
                (jax.ShapeDtypeStruct((1, lp.mask.size), jnp.uint32),))[0]
        self._cache[li] = out

    def _pop(self, li, field):
        # consumed fields leave the cache (see _MemkindReader._pop)
        entry = self._cache[li]
        val = entry.pop(field)
        if not entry:
            del self._cache[li]
        return val

    def get_ct(self, li):
        self.prefetch(li)
        lp = self.plan.layers[li]
        cfg = lp.cfg
        packed, zero, rng, rp_seed = self._pop(li, "ct")
        impl = backend.route_quant(cfg.impl, cfg.bits, cfg.group_size,
                                   cfg.levels())
        return CompressedTensor(packed, zero, rng, rp_seed, shape=lp.shape,
                                dtype=jnp.dtype(self.plan.dtype), cfg=cfg,
                                impl=impl)

    def get_raw(self, li):
        self.prefetch(li)
        return self._pop(li, "raw").astype(jnp.dtype(self.plan.dtype))

    def get_mask(self, li):
        self.prefetch(li)
        return self._pop(li, "mask")


_WRITERS = {"tensor": _TensorWriter, "device": _DeviceWriter,
            "memkind": _MemkindWriter, "callback": _CallbackWriter}
_READERS = {"tensor": _TensorReader, "device": _DeviceReader,
            "memkind": _MemkindReader, "callback": _CallbackReader}


def resolve_stash(kind: str, placement: str) -> str:
    """Mechanism for an engine :class:`~repro.engine.plan.StashPolicy`:
    kind "tensor" is its own mechanism (placement is always "device");
    kind "arena" resolves the placement policy as before."""
    if kind == "tensor":
        return "tensor"
    return resolve_mechanism(placement)


def make_writer(plan: ar.StashPlan, policy: str, key, *,
                kind: str = "arena"):
    """Trace-time stash writer for one forward pass.

    ``key`` is a uint32 scalar unique to this forward (the base SR seed) —
    the callback store keys entries by it, so vmapped/scanned forwards
    with distinct seeds never collide.  ``kind`` selects per-tensor vs
    pooled-arena storage (the engine's stash-policy axis); the legacy
    arena-only callers omit it.
    """
    return _WRITERS[resolve_stash(kind, policy)](plan, policy, key)


def make_reader(plan: ar.StashPlan, policy: str, residual, *,
                kind: str = "arena"):
    """Backward-walk reader over a writer's residual.  Call
    ``prefetch(li - 1)`` before consuming layer ``li`` to keep the
    host→device copy one layer ahead (double-buffered)."""
    return _READERS[resolve_stash(kind, policy)](plan, policy, residual)
