"""Arena planner: static layout of every layer's stash into pooled arenas.

A :class:`StashPlan` is computed once per (model config × live node count)
from *static* information only — per-layer :class:`CompressionConfig`
(including heterogeneous autoprec widths), stash shapes, and ReLU-mask
element counts.  It assigns every field a :class:`Segment` (arena +
offset + size) in one contiguous ``uint32`` arena (packed code words,
RP seeds, ReLU sign masks) and one ``float32`` arena (per-block
zero/range pairs, plus raw f32 stashes of uncompressed layers).

``stash_write`` / ``stash_read`` are bit-identical to the per-tensor
residuals: a write copies the exact ``CompressedTensor`` fields into the
arena slices, a read slices them back out and rebuilds the tensor, so
``decompress(stash_read(stash_write(x)))`` equals
``decompress(compress(x))`` word for word (see ``tests/test_offload.py``
for the parity gate across mixed bits and ragged blocks).

The plan is hashable (frozen dataclasses of tuples) so it can ride as a
static argument of jitted steps and key the engine's forward cache
(:mod:`repro.engine.forward` builds one ``custom_vjp`` per
(config, plan, stash-policy) triple); it doubles
as the byte *ledger* the memory report and the offload benchmarks read
(:meth:`StashPlan.per_layer_rows`, :attr:`StashPlan.total_bytes`).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import backend
from repro.core import pack as packmod
from repro.core.compressor import CompressedTensor, CompressionConfig


@dataclasses.dataclass(frozen=True)
class Segment:
    """A contiguous span of one arena: ``arena ∈ {"u32", "f32"}``."""

    arena: str
    offset: int
    size: int

    @property
    def nbytes(self) -> int:
        return 4 * self.size  # both arenas hold 4-byte elements


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    """Static geometry + segments of one layer's stash.

    Compressed layers carry ``packed``/``zero``/``rng``/``rp_seed``
    segments; uncompressed layers a ``raw`` f32 segment; hidden layers
    additionally a ``mask`` segment for the word-aligned 1-bit ReLU sign
    mask (``mask_elems`` pre-pack elements).
    """

    index: int
    cfg: CompressionConfig | None
    shape: tuple[int, ...]        # pre-RP stash shape
    proj_shape: tuple[int, ...]   # post-RP shape (== shape when no RP)
    n_blocks: int
    words_per_block: int
    packed: Segment | None
    zero: Segment | None
    rng: Segment | None
    rp_seed: Segment | None
    raw: Segment | None
    mask: Segment | None
    mask_elems: int

    @property
    def nbytes(self) -> int:
        return sum(s.nbytes for s in (self.packed, self.zero, self.rng,
                                      self.rp_seed, self.raw, self.mask)
                   if s is not None)

    @property
    def n_reads(self) -> int:
        """Backward-pass fetches this layer issues (stash + optional mask)."""
        return 1 + (1 if self.mask is not None else 0)


@dataclasses.dataclass(frozen=True)
class StashPlan:
    layers: tuple[LayerPlan, ...]
    u32_words: int
    f32_elems: int
    dtype: str = "float32"        # dtype the stashes decompress back to

    # ------------------------------------------------------------ ledger
    @property
    def u32_bytes(self) -> int:
        return 4 * self.u32_words

    @property
    def f32_bytes(self) -> int:
        return 4 * self.f32_elems

    @property
    def total_bytes(self) -> int:
        return self.u32_bytes + self.f32_bytes

    @property
    def max_layer_bytes(self) -> int:
        return max((lp.nbytes for lp in self.layers), default=0)

    @property
    def n_reads(self) -> int:
        return sum(lp.n_reads for lp in self.layers)

    def per_layer_rows(self) -> list[dict]:
        rows = []
        for lp in self.layers:
            row = {"layer": lp.index, "arena_bytes": lp.nbytes,
                   "bits": None if lp.cfg is None else lp.cfg.bits}
            if lp.mask is not None:
                row["mask_bytes"] = lp.mask.nbytes
            rows.append(row)
        return rows


def _stash_geometry(shape: tuple[int, ...], cfg: CompressionConfig):
    """(proj_shape, n_blocks, words_per_block) — must mirror ``compress``:
    optional RP on the last dim, then flatten + regroup into G-blocks."""
    if cfg.rp_ratio > 1:
        d = shape[-1]
        assert d % cfg.rp_ratio == 0, \
            f"last dim {d} not divisible by rp_ratio {cfg.rp_ratio}"
        proj_shape = (*shape[:-1], d // cfg.rp_ratio)
    else:
        proj_shape = tuple(shape)
    numel = 1
    for s in proj_shape:
        numel *= s
    n_blocks = (numel + cfg.group_size - 1) // cfg.group_size
    return proj_shape, n_blocks, packmod.packed_len(cfg.group_size, cfg.bits)


def plan_stashes(shapes: tuple[tuple[int, ...], ...],
                 cfgs: tuple[CompressionConfig | None, ...],
                 mask_elems: tuple[int, ...] | None = None,
                 dtype: str = "float32") -> StashPlan:
    """Lay one stash per layer into the pooled arenas.

    ``shapes[li]`` is the pre-RP shape of what layer li saves,
    ``cfgs[li]`` its compression config (``None`` → stored raw f32), and
    ``mask_elems[li]`` the element count of its 1-bit ReLU mask (0 = no
    mask).  Offsets are assigned sequentially with no padding, so the
    arena byte total equals the sum of the per-tensor residual bytes.
    """
    if mask_elems is None:
        mask_elems = (0,) * len(shapes)
    if not (len(shapes) == len(cfgs) == len(mask_elems)):
        raise ValueError("shapes/cfgs/mask_elems length mismatch")
    u_off, f_off = 0, 0
    layers = []
    for li, (shape, cfg, me) in enumerate(zip(shapes, cfgs, mask_elems)):
        packed = zero = rng = rp_seed = raw = mask = None
        if cfg is None:
            numel = 1
            for s in shape:
                numel *= s
            raw = Segment("f32", f_off, numel)
            f_off += numel
            proj_shape, n_blocks, wpb = tuple(shape), 0, 0
        else:
            proj_shape, n_blocks, wpb = _stash_geometry(shape, cfg)
            packed = Segment("u32", u_off, n_blocks * wpb)
            u_off += packed.size
            rp_seed = Segment("u32", u_off, 1)
            u_off += 1
            zero = Segment("f32", f_off, n_blocks)
            f_off += n_blocks
            rng = Segment("f32", f_off, n_blocks)
            f_off += n_blocks
        if me:
            mask = Segment("u32", u_off, packmod.packed_len(me, 1))
            u_off += mask.size
        layers.append(LayerPlan(
            index=li, cfg=cfg, shape=tuple(shape), proj_shape=proj_shape,
            n_blocks=n_blocks, words_per_block=wpb, packed=packed, zero=zero,
            rng=rng, rp_seed=rp_seed, raw=raw, mask=mask, mask_elems=me))
    return StashPlan(layers=tuple(layers), u32_words=u_off, f32_elems=f_off,
                     dtype=dtype)


# ---------------------------------------------------------------- arenas
def arena_init(plan: StashPlan):
    """Fresh zeroed (u32, f32) arena pair for one forward pass."""
    return (jnp.zeros((plan.u32_words,), jnp.uint32),
            jnp.zeros((plan.f32_elems,), jnp.float32))


def _seg_set(arena, seg: Segment, values):
    return arena.at[seg.offset:seg.offset + seg.size].set(
        values.reshape(-1).astype(arena.dtype))


def _seg_get(arena, seg: Segment):
    return arena[seg.offset:seg.offset + seg.size]


def stash_write(arenas, plan: StashPlan, li: int, ct: CompressedTensor):
    """Copy a ``CompressedTensor``'s fields into layer li's segments."""
    lp = plan.layers[li]
    if lp.packed is None:
        raise ValueError(f"layer {li} is planned raw; use write_raw")
    u32, f32 = arenas
    u32 = _seg_set(u32, lp.packed, ct.packed)
    u32 = u32.at[lp.rp_seed.offset].set(ct.rp_seed.astype(jnp.uint32))
    f32 = _seg_set(f32, lp.zero, ct.zero)
    f32 = _seg_set(f32, lp.rng, ct.rng)
    return (u32, f32)


def stash_read(arenas, plan: StashPlan, li: int) -> CompressedTensor:
    """Rebuild layer li's ``CompressedTensor`` from the arena slices.

    The concrete kernel backend is re-routed from the layer's config
    exactly as ``compress`` routed it (all impls write bit-identical
    words, so a re-route under a changed override still decompresses to
    the same values).
    """
    lp = plan.layers[li]
    if lp.packed is None:
        raise ValueError(f"layer {li} is planned raw; use read_raw")
    u32, f32 = arenas
    cfg = lp.cfg
    impl = backend.route_quant(cfg.impl, cfg.bits, cfg.group_size,
                               cfg.levels())
    return CompressedTensor(
        packed=_seg_get(u32, lp.packed).reshape(lp.n_blocks,
                                                lp.words_per_block),
        zero=_seg_get(f32, lp.zero),
        rng=_seg_get(f32, lp.rng),
        rp_seed=u32[lp.rp_seed.offset],
        shape=lp.shape, dtype=jnp.dtype(plan.dtype), cfg=cfg, impl=impl)


def write_raw(arenas, plan: StashPlan, li: int, x):
    """Store an uncompressed layer's f32 stash in the f32 arena."""
    lp = plan.layers[li]
    if lp.raw is None:
        raise ValueError(f"layer {li} is planned compressed; use stash_write")
    u32, f32 = arenas
    return (u32, _seg_set(f32, lp.raw, x))


def read_raw(arenas, plan: StashPlan, li: int):
    lp = plan.layers[li]
    u32, f32 = arenas
    return _seg_get(f32, lp.raw).reshape(lp.shape).astype(
        jnp.dtype(plan.dtype))


def write_mask(arenas, plan: StashPlan, li: int, mask_words):
    """Store a layer's packed 1-bit ReLU sign mask ((1, n_words) uint32)."""
    lp = plan.layers[li]
    u32, f32 = arenas
    return (_seg_set(u32, lp.mask, mask_words), f32)


def read_mask(arenas, plan: StashPlan, li: int):
    lp = plan.layers[li]
    u32, f32 = arenas
    return _seg_get(u32, lp.mask).reshape(1, lp.mask.size)
