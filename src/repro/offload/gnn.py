"""Arena-routed GNN forward/backward: one ``custom_vjp`` over the whole
network so the saved-for-backward state is a single pooled arena (or its
host-offloaded handle), not N scattered per-layer residuals.

Forward: exactly :func:`repro.graph.models.gnn_forward` — same layer
math, same per-layer seeds (``seed + li*1013``), same padding-mask
pinning — except every layer's stash (compressed linear input, or raw
f32 for uncompressed layers, plus the packed 1-bit ReLU sign mask) is
written into the :class:`~repro.offload.arena.StashPlan` arenas through
an :mod:`~repro.offload.engine` writer, which moves each segment to host
right after it is written when the policy asks for it.

Backward: a manual layer-by-layer reverse walk that mirrors what autodiff
produces on the per-tensor path — ``dx = g @ wᵀ`` exact, ``dw = x̂ᵀ g``
at the reconstruction (EXACT's estimator, see
:func:`repro.core.act_compress.compressed_matmul`), ReLU via the saved
sign mask, and the Â-product transposed by swapping the edge list's
src/dst roles.  The reader prefetches layer ``li-1``'s segments before
layer ``li``'s gradient math so host→device copies run one layer ahead
(double-buffered).

Cotangents are returned for params and features; the edge weights and
the padding mask are treated as non-differentiable graph constants
(zero cotangents) — both training engines only ever differentiate with
respect to params.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pack as packmod
from repro.core.act_compress import _zero_ct
from repro.core.compressor import compress, decompress
from repro.offload import engine
from repro.offload.arena import StashPlan, plan_stashes


def plan_gnn_stashes(cfg, in_dim: int, n_nodes: int) -> StashPlan:
    """Static arena layout for one GNN forward over ``n_nodes`` live rows
    (the full graph, or one padded subgraph batch).

    Layer li stashes its linear input ``(n_nodes, d_in·(2 if sage))`` at
    the layer's own :class:`CompressionConfig` (heterogeneous autoprec
    tuples included; ``None`` layers are planned as raw f32), and hidden
    layers add the word-aligned 1-bit ReLU mask over their output.
    """
    from repro.graph.models import _dims

    dims = _dims(cfg, in_dim)
    per_layer = cfg.layer_compression()
    shapes, masks = [], []
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        shapes.append((n_nodes, lin_in))
        masks.append(n_nodes * d_out if li < len(dims) - 2 else 0)
    return plan_stashes(tuple(shapes), per_layer, tuple(masks))


@functools.lru_cache(maxsize=None)
def _build(cfg, plan: StashPlan, policy: str):
    """The custom_vjp forward for one (GNNConfig, StashPlan, policy)."""
    # deferred for the same import-cycle reason as plan_gnn_stashes
    # (graph.models lazily dispatches into this module); sharing models'
    # spmm keeps the Â-product — and hence the bit-parity contract —
    # single-sourced
    from repro.graph.models import spmm as _spmm

    from repro.graph.models import gnn_forward

    per_layer = cfg.layer_compression()
    sage = cfg.arch == "sage"
    L = len(plan.layers)

    def layer_input(h, src, dst, mean_w, n):
        if not sage:
            return h
        return jnp.concatenate([h, _spmm(h, src, dst, mean_w, n)], axis=1)

    @jax.custom_vjp
    def f(params, feats, src, dst, gcn_w, mean_w, seed, nm):
        # primal path (un-differentiated calls): the per-tensor forward is
        # value-identical and stash-free (compressed_matmul / relu_1bit
        # primals are plain x @ w / maximum), so don't re-state the layer
        # math a third time
        return gnn_forward(params, (feats, src, dst, gcn_w, mean_w), cfg,
                           seed=seed, node_mask=nm)

    def f_fwd(params, feats, src, dst, gcn_w, mean_w, seed, nm):
        n = feats.shape[0]
        writer = engine.make_writer(plan, policy, seed)
        h = feats * nm[:, None]
        for li, p in enumerate(params):
            lseed = seed + jnp.uint32(li * 1013)
            x = layer_input(h, src, dst, mean_w, n)
            comp = per_layer[li]
            if comp is None:
                writer.put_raw(li, x)
            else:
                writer.put_ct(li, compress(x, comp, lseed))
            z = x @ p["w"] + p["b"]
            if not sage:
                z = _spmm(z, src, dst, gcn_w, n)
            if li < L - 1:
                writer.put_mask(li, packmod.pack(
                    (z > 0).astype(jnp.int32).reshape(1, -1), 1))
                z = jnp.maximum(z, 0.0)
            h = z * nm[:, None]
        return h, (params, src, dst, gcn_w, mean_w, nm, writer.residual())

    def f_bwd(res, gy):
        params, src, dst, gcn_w, mean_w, nm, stash = res
        n = nm.shape[0]
        reader = engine.make_reader(plan, policy, stash)
        reader.prefetch(L - 1)
        gh = gy
        dparams = [None] * L
        for li in reversed(range(L)):
            if li > 0:
                reader.prefetch(li - 1)  # one layer ahead of the compute
            p = params[li]
            lp = plan.layers[li]
            g = gh * nm[:, None]
            if li < L - 1:
                m = packmod.unpack(reader.get_mask(li), 1, lp.mask_elems)
                g = g * m.reshape(g.shape).astype(g.dtype)
            # transpose of the output-side Â product (gcn applies it
            # after the linear): swap the edge list's src/dst roles
            gz = g if sage else _spmm(g, dst, src, gcn_w, n)
            x_hat = (reader.get_raw(li) if lp.cfg is None
                     else decompress(reader.get_ct(li)))
            x2 = x_hat.reshape(-1, x_hat.shape[-1])
            g2 = gz.reshape(-1, gz.shape[-1])
            dparams[li] = {"w": (x2.T @ g2).astype(p["w"].dtype),
                           "b": jnp.sum(gz, axis=0).astype(p["b"].dtype)}
            gx = (gz @ p["w"].T).astype(x_hat.dtype)
            if sage:
                d = gx.shape[1] // 2
                gh = gx[:, :d] + _spmm(gx[:, d:], dst, src, mean_w, n)
            else:
                gh = gx
        dfeats = gh * nm[:, None]
        return (dparams, dfeats, _zero_ct(src), _zero_ct(dst),
                jnp.zeros_like(gcn_w), jnp.zeros_like(mean_w),
                np.zeros((), jax.dtypes.float0), jnp.zeros_like(nm))

    f.defvjp(f_fwd, f_bwd)
    return f


def arena_gnn_forward(params, graph, cfg, plan: StashPlan, seed=0,
                      node_mask=None, policy: str = "device"):
    """Drop-in for :func:`repro.graph.models.gnn_forward` with the stash
    routed through a pooled arena under the given offload policy."""
    engine.check_policy(policy)
    if len(plan.layers) != cfg.n_layers:
        raise ValueError(f"plan has {len(plan.layers)} layers for a "
                         f"{cfg.n_layers}-layer model")
    feats, src, dst, gcn_w, mean_w = graph
    nm = (jnp.ones((feats.shape[0],), feats.dtype) if node_mask is None
          else node_mask.astype(feats.dtype))
    fn = _build(cfg, plan, policy)
    return fn(params, feats, src, dst, gcn_w, mean_w,
              jnp.asarray(seed, jnp.uint32), nm)
