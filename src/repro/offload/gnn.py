"""GNN stash planning.

:func:`plan_gnn_stashes` — the static arena layout for one GNN forward —
lives here with the rest of the offload subsystem.  The whole-network
``custom_vjp`` that *consumes* the plan lives in
:mod:`repro.engine.forward`, where it serves every stash policy
(per-tensor included), not just arenas.
"""
from __future__ import annotations

from repro.offload.arena import StashPlan, plan_stashes


def plan_gnn_stashes(cfg, in_dim: int, n_nodes: int) -> StashPlan:
    """Static arena layout for one GNN forward over ``n_nodes`` live rows
    (the full graph, or one padded subgraph batch).

    Layer li stashes its linear input ``(n_nodes, d_in·(2 if sage))`` at
    the layer's own :class:`CompressionConfig` (heterogeneous autoprec
    tuples included; ``None`` layers are planned as raw f32), and hidden
    layers add the word-aligned 1-bit ReLU mask over their output.
    """
    # deferred import: graph.models lazily dispatches into the engine,
    # which plans through this module
    from repro.graph.models import _dims

    dims = _dims(cfg, in_dim)
    per_layer = cfg.layer_compression()
    shapes, masks = [], []
    for li, (d_in, d_out) in enumerate(zip(dims[:-1], dims[1:])):
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        shapes.append((n_nodes, lin_in))
        masks.append(n_nodes * d_out if li < len(dims) - 2 else 0)
    return plan_stashes(tuple(shapes), per_layer, tuple(masks))

