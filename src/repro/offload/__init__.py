"""Stash arena: pooled compressed-activation storage with async host
offload and backward prefetch.

The compression stack shrinks the *bytes* of every saved-for-backward
activation, but as long as each layer stashes its own scattered
``CompressedTensor`` the device peak is set by XLA's allocator, not by the
byte count the ledger reports.  This package turns the report into
allocator-visible savings:

* :mod:`repro.offload.arena` — a static **planner** that lays every
  layer's ``packed``/``zero``/``rng``/``rp_seed`` fields (plus 1-bit ReLU
  masks and raw f32 stashes of uncompressed layers) into one contiguous
  uint32 arena + one f32 arena with static offsets (:class:`StashPlan`),
  and ``stash_write``/``stash_read`` that round-trip bit-identically to
  the per-tensor residuals.
* :mod:`repro.offload.engine` — the **offload engine**: policies
  ``{"device", "host", "pinned-paged"}`` that move arena segments
  device→host after each layer's forward stash and prefetch them
  host→device one layer ahead of the backward walk.  Platforms with a
  host memory space (TPU/GPU) use memory-kind ``jax.device_put``;
  everywhere else a synchronous pure-callback host store keeps the same
  semantics (and the same bits).
* :mod:`repro.offload.gnn` — the GNN stash planner
  (:func:`plan_gnn_stashes`).  The whole-forward ``custom_vjp`` that
  consumes the plan lives in :mod:`repro.engine.forward`, where arenas
  are one stash policy among several.

Entry points: an arena :class:`~repro.engine.plan.StashPolicy` on any
``ExecutionPlan`` (legacy ``train_gnn(offload=...)`` /
``train_gnn_batched(offload=...)``), ``Model`` with
``ArchConfig.act_offload`` (transformer scan path), and
``launch.train --offload``.
"""
from repro.offload.arena import (StashPlan, arena_init, plan_stashes,
                                 read_mask, read_raw, stash_read, stash_write,
                                 write_mask, write_raw)
from repro.offload.engine import (POLICIES, check_policy,
                                  device_memory_stats,
                                  device_resident_stash_bytes,
                                  fetch_compressed, host_memory_kind,
                                  host_store_bytes, make_reader, make_writer,
                                  measure_live_bytes, offload_compressed)
from repro.offload.gnn import plan_gnn_stashes
from repro.offload.pager import FeaturePager

__all__ = [
    "StashPlan", "plan_stashes", "arena_init",
    "stash_write", "stash_read", "write_raw", "read_raw",
    "write_mask", "read_mask",
    "POLICIES", "check_policy", "host_memory_kind", "make_writer",
    "make_reader", "measure_live_bytes", "host_store_bytes",
    "device_resident_stash_bytes", "device_memory_stats",
    "offload_compressed", "fetch_compressed",
    "plan_gnn_stashes", "FeaturePager",
]
