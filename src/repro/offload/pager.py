"""Host-resident feature pager for mesh-sharded training.

The mesh engine never keeps the full feature matrix on device: the
:class:`~repro.parallel.halo.HaloProgram`'s ``(rounds, m, n_pad, F)``
feature tensor stays host-resident, split into fixed-size row pages
(:data:`repro.offload.engine.PAGE_WORDS` f32 words per page — the same
DMA-friendly granularity the stash arena's pinned-paged policy uses), and
:class:`FeaturePager` ships one round's pages to the mesh ahead of use:

* ``prefetch(r)`` issues the ``jax.device_put`` of every page of round
  ``r`` — asynchronous under XLA, so the host→device copies overlap the
  *current* round's layer compute (double-buffered, like the stash
  engine's one-layer-ahead backward prefetch);
* ``fetch(r)`` blocks until round ``r``'s pages are device-resident and
  concatenates them back into the ``(m, n_pad, F)`` round tensor, sharded
  over the ``graph`` axis.

On platforms with a pinned host memory space the pages are staged there
at construction (memory-kind ``device_put``); on CPU the host pages are
plain numpy (host memory *is* the default space).  The pager records
blocked-vs-inflight wall time per fetch; ``stats()['overlap_frac']`` is
the fraction of copy time hidden behind compute — the number
``BENCH_gnn_dist.json`` reports.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.offload.engine import PAGE_WORDS, host_memory_kind


class FeaturePager:
    """Pages one round of partition features to the mesh at a time."""

    def __init__(self, features: np.ndarray, mesh, *,
                 page_rows: int | None = None):
        if features.ndim != 4:
            raise ValueError("features must be (rounds, m, n_pad, F); got "
                             f"shape {features.shape}")
        self.rounds = int(features.shape[0])
        n_pad, f = int(features.shape[2]), int(features.shape[3])
        self.page_rows = (int(page_rows) if page_rows
                          else max(1, PAGE_WORDS // max(1, f)))
        self._dev = NamedSharding(mesh, P("graph"))
        kind = host_memory_kind("pinned-paged") or host_memory_kind("host")
        self.host_kind = kind or "numpy"
        host = (NamedSharding(mesh, P("graph"), memory_kind=kind)
                if kind else None)
        self._pages: list[list] = []
        for r in range(self.rounds):
            pages = [np.ascontiguousarray(features[r, :, i:i + self.page_rows])
                     for i in range(0, n_pad, self.page_rows)]
            if host is not None:
                pages = [jax.device_put(p, host) for p in pages]
            self._pages.append(pages)
        self.n_pages = len(self._pages[0])
        self.host_bytes = int(features.nbytes)
        self.round_bytes = int(features.nbytes // self.rounds)
        self._inflight: dict[int, tuple[list, float]] = {}
        self._blocked_s = 0.0
        self._span_s = 0.0
        self._fetches = 0
        self._prefetch_hits = 0

    def prefetch(self, r: int) -> None:
        """Start moving round ``r``'s pages to the mesh (idempotent until
        the round is fetched)."""
        if r in self._inflight:
            return
        t0 = time.perf_counter()
        handles = [jax.device_put(p, self._dev) for p in self._pages[r]]
        self._inflight[r] = (handles, t0)

    def fetch(self, r: int):
        """Round ``r``'s ``(m, n_pad, F)`` features, device-resident and
        sharded over the ``graph`` axis.  Consumes the prefetch."""
        if r in self._inflight:
            self._prefetch_hits += 1
        else:
            self.prefetch(r)
        handles, t0 = self._inflight.pop(r)
        t_wait = time.perf_counter()
        for h in handles:
            h.block_until_ready()
        t_done = time.perf_counter()
        self._blocked_s += t_done - t_wait
        self._span_s += max(t_done - t0, 1e-12)
        self._fetches += 1
        if len(handles) == 1:
            return handles[0]
        return jnp.concatenate(handles, axis=1)

    def stats(self) -> dict:
        span = self._span_s
        return {
            "fetches": self._fetches,
            "prefetch_hits": self._prefetch_hits,
            "n_pages": self.n_pages,
            "page_rows": self.page_rows,
            "host_kind": self.host_kind,
            "host_bytes": self.host_bytes,
            "round_bytes": self.round_bytes,
            "blocked_s": self._blocked_s,
            "span_s": span,
            "overlap_frac": (0.0 if span == 0.0
                             else max(0.0, 1.0 - self._blocked_s / span)),
        }
