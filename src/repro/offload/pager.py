"""Host-resident feature pager for mesh-sharded training.

The mesh engine never keeps the full feature matrix on device: the
:class:`~repro.parallel.halo.HaloProgram`'s ``(rounds, m, n_pad, F)``
feature tensor stays host-resident, split into fixed-size row pages
(:data:`repro.offload.engine.PAGE_WORDS` f32 words per page — the same
DMA-friendly granularity the stash arena's pinned-paged policy uses), and
:class:`FeaturePager` ships one round's pages to the mesh ahead of use:

* ``prefetch(r)`` issues the ``jax.device_put`` of every page of round
  ``r`` — asynchronous under XLA, so the host→device copies overlap the
  *current* round's layer compute (double-buffered, like the stash
  engine's one-layer-ahead backward prefetch);
* ``fetch(r)`` blocks until round ``r``'s pages are device-resident and
  concatenates them back into the ``(m, n_pad, F)`` round tensor, sharded
  over the ``graph`` axis.

On platforms with a pinned host memory space the pages are staged there
at construction (memory-kind ``device_put``); on CPU the host pages are
plain numpy (host memory *is* the default space).  The pager records
blocked-vs-inflight wall time per fetch; ``stats()['overlap_frac']`` is
the lifetime fraction of copy time hidden behind compute — the number
``BENCH_gnn_dist.json`` reports — and every fetch also lands a per-fetch
overlap observation in a windowed histogram (through the obs metrics
registry when one is passed), so ``overlap_frac_window`` shows *recent*
behavior: a single end-of-run scalar averages early-epoch stalls away,
the window does not.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.obs.metrics import MetricsRegistry
from repro.offload.engine import PAGE_WORDS, host_memory_kind

#: Default size of the per-fetch overlap window (rounds, not epochs).
OVERLAP_WINDOW = 32


class FeaturePager:
    """Pages one round of partition features to the mesh at a time."""

    def __init__(self, features: np.ndarray, mesh, *,
                 page_rows: int | None = None,
                 metrics: MetricsRegistry | None = None,
                 window: int = OVERLAP_WINDOW):
        if features.ndim != 4:
            raise ValueError("features must be (rounds, m, n_pad, F); got "
                             f"shape {features.shape}")
        self.rounds = int(features.shape[0])
        n_pad, f = int(features.shape[2]), int(features.shape[3])
        self.page_rows = (int(page_rows) if page_rows
                          else max(1, PAGE_WORDS // max(1, f)))
        self._dev = NamedSharding(mesh, P("graph"))
        kind = host_memory_kind("pinned-paged") or host_memory_kind("host")
        self.host_kind = kind or "numpy"
        host = (NamedSharding(mesh, P("graph"), memory_kind=kind)
                if kind else None)
        self._pages: list[list] = []
        for r in range(self.rounds):
            pages = [np.ascontiguousarray(features[r, :, i:i + self.page_rows])
                     for i in range(0, n_pad, self.page_rows)]
            if host is not None:
                pages = [jax.device_put(p, host) for p in pages]
            self._pages.append(pages)
        self.n_pages = len(self._pages[0])
        self.host_bytes = int(features.nbytes)
        self.round_bytes = int(features.nbytes // self.rounds)
        self._inflight: dict[int, tuple[list, float]] = {}
        self._blocked_s = 0.0
        self._span_s = 0.0
        self._fetches = 0
        self._prefetch_hits = 0
        # a private enabled registry when the caller passes none, so the
        # windowed stats exist even without an obs session
        reg = metrics if metrics is not None else MetricsRegistry()
        self._overlap = reg.histogram("pager/overlap_frac", window=window)
        self._fetch_ctr = reg.counter("pager/fetches")
        self._hit_ctr = reg.counter("pager/prefetch_hits")
        reg.gauge("pager/round_bytes").set(self.round_bytes)
        reg.gauge("pager/host_bytes").set(self.host_bytes)

    def prefetch(self, r: int) -> None:
        """Start moving round ``r``'s pages to the mesh (idempotent until
        the round is fetched)."""
        if r in self._inflight:
            return
        t0 = time.perf_counter()
        handles = [jax.device_put(p, self._dev) for p in self._pages[r]]
        self._inflight[r] = (handles, t0)

    def fetch(self, r: int):
        """Round ``r``'s ``(m, n_pad, F)`` features, device-resident and
        sharded over the ``graph`` axis.  Consumes the prefetch."""
        if r in self._inflight:
            self._prefetch_hits += 1
            self._hit_ctr.inc()
        else:
            self.prefetch(r)
        handles, t0 = self._inflight.pop(r)
        t_wait = time.perf_counter()
        for h in handles:
            h.block_until_ready()
        t_done = time.perf_counter()
        blocked = t_done - t_wait
        span = max(t_done - t0, 1e-12)
        self._blocked_s += blocked
        self._span_s += span
        self._fetches += 1
        self._fetch_ctr.inc()
        self._overlap.observe(max(0.0, 1.0 - blocked / span))
        if len(handles) == 1:
            return handles[0]
        return jnp.concatenate(handles, axis=1)

    def stats(self) -> dict:
        span = self._span_s
        return {
            "fetches": self._fetches,
            "prefetch_hits": self._prefetch_hits,
            "n_pages": self.n_pages,
            "page_rows": self.page_rows,
            "host_kind": self.host_kind,
            "host_bytes": self.host_bytes,
            "round_bytes": self.round_bytes,
            "blocked_s": self._blocked_s,
            "span_s": span,
            "overlap_frac": (0.0 if span == 0.0
                             else max(0.0, 1.0 - self._blocked_s / span)),
            # windowed running stat: the last OVERLAP_WINDOW fetches'
            # per-fetch overlap, not the lifetime average
            "overlap_frac_window": self._overlap.window_mean,
            "overlap_frac_window_min": self._overlap.window_min,
            "overlap_window_size": self._overlap.window_size,
        }
