"""Dense bit-packing of quantization codes into int32 words.

INT2 codes pack 16-to-a-word (the EXACT repo stores 2-bit codes in int8,
wasting 4x; HBM bytes are exactly what activation compression attacks, so we
pack densely).  Pure shift/or trees — vectorize on the VPU and run unchanged
in Pallas interpret mode.
"""
from __future__ import annotations

import jax.numpy as jnp


def vals_per_word(bits: int) -> int:
    assert 32 % bits == 0, f"bits={bits} must divide 32"
    return 32 // bits


def packed_len(n: int, bits: int) -> int:
    v = vals_per_word(bits)
    return (n + v - 1) // v


def pack(codes: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Pack int codes (values < 2**bits) along the last axis into uint32.

    STRIDED layout: with W = n/v words per row, word j holds codes
    ``[j, j+W, j+2W, ...]`` in its bit-fields (low bits first).  On TPU this
    packs/unpacks with full-lane slices + shifts — no sublane reshuffles —
    and the Pallas kernels produce bit-identical words to this reference.
    """
    v = vals_per_word(bits)
    *lead, n = codes.shape
    pad = (-n) % v
    c = codes.astype(jnp.uint32)
    if pad:
        c = jnp.concatenate(
            [c, jnp.zeros((*lead, pad), jnp.uint32)], axis=-1
        )
    c = c.reshape(*lead, v, -1)  # chunk k = columns [k*W, (k+1)*W)
    shifts = (jnp.arange(v, dtype=jnp.uint32) * jnp.uint32(bits))
    return (c << shifts[..., :, None]).sum(axis=-2, dtype=jnp.uint32)


def unpack(words: jnp.ndarray, bits: int, n: int) -> jnp.ndarray:
    """Unpack uint32 words back to int32 codes; ``n`` = valid count per row."""
    v = vals_per_word(bits)
    mask = jnp.uint32(2**bits - 1)
    shifts = (jnp.arange(v, dtype=jnp.uint32) * jnp.uint32(bits))
    c = (words[..., None, :] >> shifts[:, None]) & mask
    *lead, _, nw = c.shape
    c = c.reshape(*lead, v * nw)
    return c[..., :n].astype(jnp.int32)


def packed_nbytes(shape: tuple[int, ...], bits: int, group_size: int) -> int:
    """Total storage (bytes) of a packed block-quantized tensor:

    packed codes + one (float32 zero, float32 range) pair per block.
    This is the paper's memory model: larger G amortizes the 8-byte
    per-block overhead (Table 1, M column).
    """
    n = 1
    for s in shape:
        n *= s
    n_blocks = (n + group_size - 1) // group_size
    code_words = n_blocks * packed_len(group_size, bits)
    return 4 * code_words + 8 * n_blocks
