"""Counter-based deterministic RNG used by stochastic rounding.

Both the pure-jnp reference path and the Pallas kernels draw their rounding
noise from this hash, so codes are bit-identical across paths (tests assert
exact equality, not allclose).  The hash is the murmur3 finalizer — cheap,
vectorizes to VPU ops on TPU, and runs unchanged in ``interpret=True``.
"""
from __future__ import annotations

import jax.numpy as jnp

import numpy as np

# numpy-scalar constants: inlined as literals at trace time so Pallas kernels
# don't capture closure arrays (python ints > int32 max would overflow).
_M1 = np.uint32(0x85EB_CA6B)
_M2 = np.uint32(0xC2B2_AE35)
_GOLDEN = np.uint32(0x9E37_79B9)
_RADEMACHER_SALT = np.uint32(0x517C_C1B7)

#: Knuth's 32-bit multiplicative-hash constant (⌊2³²/φ⌋, odd).  The single
#: home for every derived-stream multiply outside the murmur3 mix above:
#: autoprec probe seeds and the LM per-step activation seed
#: (:mod:`repro.engine.seeds`) and the offload callback-store tickets
#: (:mod:`repro.offload.engine`) all hash through this constant.  It lives
#: here — not in ``engine.seeds`` — because ``repro.offload`` must not
#: import the engine package (``engine.plan`` imports ``offload.engine``).
KNUTH_MULT = np.uint32(2654435761)


def hash_u32(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 fmix32 over a uint32 array."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = (x * _M1).astype(jnp.uint32)
    x = x ^ (x >> 13)
    x = (x * _M2).astype(jnp.uint32)
    x = x ^ (x >> 16)
    return x


def uniform_from_counter(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """U[0,1) floats from (seed, uint32 counter array).

    24 mantissa bits — exactly representable in float32.  ``seed`` is
    normally a scalar (the kernel path); an array seed broadcastable
    against ``counter`` selects a distinct stream per element (the
    wraparound-safe 64-bit counter path in :mod:`repro.core.quant`) and
    is bit-identical to the scalar path wherever the values coincide.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    hs = hash_u32(seed.reshape(1) if seed.ndim == 0 else seed)
    mixed = hash_u32((counter.astype(jnp.uint32) * _GOLDEN).astype(jnp.uint32)
                     + hs)
    return (mixed >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def rademacher_from_counter(seed, counter: jnp.ndarray) -> jnp.ndarray:
    """±1 int8 signs from (scalar seed, uint32 counter array)."""
    seed = jnp.asarray(seed, jnp.uint32)
    mixed = hash_u32((counter.astype(jnp.uint32) * _GOLDEN).astype(jnp.uint32)
                     + hash_u32(seed.reshape(1) + jnp.uint32(_RADEMACHER_SALT)))
    return (jnp.int8(1) - (jnp.int8(2) * (mixed & 1).astype(jnp.int8)))
