"""Kernel-backend dispatch for the whole compression stack.

This module owns the *execution strategy* of every compress/decompress
primitive.  Callers (``core.compressor``, and through it the GNN models,
the transformer ``compressed_block`` path and the benchmarks) never pick a
kernel themselves — they name an ``impl`` and this layer routes:

  * ``"jnp"``     — the pure-jnp reference path (``repro.kernels.ref``)
  * ``"interp"``  — Pallas interpret mode (CPU validation of the kernels)
  * ``"pallas"``  — real Pallas lowering (the TPU deployment path)
  * ``"auto"``    — pallas on TPU, jnp elsewhere; unsupported shapes fall
                    back to jnp instead of erroring

All impls produce **bit-identical packed words** for quantize+pack (the SR
noise is a counter hash and the strided pack layout is shared; see
``tests/test_backend.py`` for the parity gate).  Random projection is a
float matmul, so impls agree to float tolerance, not bit-exactly — RP
routing is therefore best-effort: shapes that don't meet the Pallas tile
constraints silently use the jnp matmul.

Static-argument discipline: VM level tables are normalized to *hashable
tuples of python floats* before they reach ``pallas_call`` (the kernels
unroll them into compare/select chains).  Passing a traced array as a
level table is an error by construction.

``use_impl`` installs a trace-time override (operator switch) that takes
precedence over per-config ``impl`` fields.  It affects *tracing* — an
already-compiled jit executable is not retraced when the override changes.
"""
from __future__ import annotations

import contextlib
import functools

import jax
import jax.numpy as jnp

from repro.core import quant as quantmod
from repro.kernels import ops

VALID_IMPLS = ("auto", "jnp", "interp", "pallas")
VALID_FUSED = ("auto", "on", "off")

_OVERRIDE: list[str] = []  # stack managed by use_impl()


@functools.lru_cache(maxsize=1)
def _platform() -> str:
    """Memoized ``jax.default_backend()`` — ``auto`` resolution sits on
    every compress/decompress trace, and the platform cannot change
    within a process, so probe the backend exactly once."""
    return jax.default_backend()


def _check_impl(impl: str) -> str:
    if impl not in VALID_IMPLS:
        raise ValueError(f"impl={impl!r} not in {VALID_IMPLS}")
    return impl


@contextlib.contextmanager
def use_impl(impl: str | None):
    """Trace-time backend override; ``None`` is a no-op (plumbing-friendly)."""
    if impl is None:
        yield
        return
    _OVERRIDE.append(_check_impl(impl))
    try:
        yield
    finally:
        _OVERRIDE.pop()


def current_override() -> str | None:
    return _OVERRIDE[-1] if _OVERRIDE else None


def resolve_impl(impl: str = "auto") -> str:
    """Concrete impl after applying the ``use_impl`` override and ``auto``."""
    impl = _check_impl(_OVERRIDE[-1] if _OVERRIDE else impl)
    if impl == "auto":
        return "pallas" if _platform() == "tpu" else "jnp"
    return impl


def available_impl(impl: str) -> str:
    """Downgrade a *recorded* concrete impl to one runnable on this host.

    ``CompressedTensor.impl`` may say "pallas" in a checkpoint written on
    TPU; all impls are bit-identical, so restoring on a CPU host should
    quietly re-route through ``auto`` rather than fail to lower.
    """
    if impl == "pallas" and _platform() != "tpu":
        return "auto"
    return impl


# ------------------------------------------------------------- level tables
def normalize_levels(levels):
    """Coerce a VM level table to a static hashable tuple of floats.

    Single definition lives next to the kernels (the consumer that makes
    the static-tuple requirement real) — this delegates at *call* time
    rather than aliasing at import time, because this module sits inside
    the core<->kernels import cycle: entering the cycle from the
    ``repro.kernels`` side reaches here while ``ops`` is still
    half-initialized, and an eager ``ops.static_levels`` lookup crashes.
    """
    return ops.static_levels(levels)


# ----------------------------------------------------------------- routing
def quant_kernel_unsupported(bits: int, group_size: int,
                             levels) -> str | None:
    """Why the fused quant kernel can't run this config (None = it can)."""
    if 32 % bits:
        return f"bits={bits} does not divide 32"
    vpw = 32 // bits
    if group_size % vpw:
        return (f"group_size={group_size} is not a multiple of the "
                f"{vpw} codes-per-word pack width")
    if levels is not None and len(levels) > 16:
        return (f"VM table has {len(levels)} levels; the unrolled kernel "
                "chain supports at most 16 (bits <= 4)")
    return None


def route_quant(impl: str, bits: int, group_size: int, levels=None) -> str:
    """Concrete impl for quantize/dequantize.

    ``auto`` falls back to jnp when the kernel can't run the config; an
    *explicitly* requested kernel impl raises instead — the parity contract
    must never be silently narrowed.
    """
    requested = _check_impl(_OVERRIDE[-1] if _OVERRIDE else impl)
    concrete = resolve_impl(requested)
    if concrete == "jnp":
        return "jnp"
    reason = quant_kernel_unsupported(bits, group_size,
                                      normalize_levels(levels))
    if reason is None:
        return concrete
    if requested == "auto":
        return "jnp"
    raise ValueError(f"impl={requested!r} cannot run this config: {reason}")


# ----------------------------------------------------------- fused routing
def fused_unsupported(shape, bits: int, group_size: int,
                      levels=None) -> str | None:
    """Why the fused matmul+quant kernels can't run this stash (None =
    they can).  THE eligibility check — dispatch, the engine forward,
    the benchmarks, and the tests all call this one predicate (or its
    boolean face :func:`supports_fused`); it may not be re-derived
    anywhere else.

    Eligibility means the quantization blocks of the stashed operand
    coincide with whole kernel row tiles:

    * the base quant-kernel constraints hold (bits divides 32, pack-width
      divides the group, VM table fits the unrolled chain);
    * the operand is a 2-D (M, D) matrix (that is what the matmul sees);
    * blocks align to rows — ``D % G == 0`` (whole blocks per row) or
      ``G % D == 0`` (whole rows per block) — and the element count is
      whole blocks (``M*D % G == 0``), since the fused pad appends zero
      *rows* and cannot reproduce the reference replicate-padded ragged
      tail inside a real block.
    """
    reason = quant_kernel_unsupported(bits, group_size,
                                      normalize_levels(levels))
    if reason is not None:
        return reason
    if len(shape) != 2:
        return f"fused matmul needs a 2-D operand, got shape {shape}"
    m, d = int(shape[0]), int(shape[1])
    if d % group_size and group_size % d:
        return (f"blocks (G={group_size}) straddle rows of width {d}: "
                "need D % G == 0 or G % D == 0")
    if (m * d) % group_size:
        return (f"{m}x{d} is not whole blocks of {group_size} (the ragged "
                "tail needs the reference replicate-padding)")
    return None


def supports_fused(shape, bits: int, group_size: int, levels=None) -> bool:
    """Boolean face of :func:`fused_unsupported`."""
    return fused_unsupported(shape, bits, group_size, levels) is None


def route_fused(fused: str, impl: str, shape, bits: int, group_size: int,
                levels=None, rp_ratio: int = 0) -> str | None:
    """Concrete impl the fused matmul-quant pair should run on, or None
    for the unfused per-layer fallback.

    ``fused="off"`` never fuses.  ``fused="auto"`` fuses only where it
    wins: eligible shapes on a real kernel backend (resolved "pallas");
    the jnp/interp reference paths keep the unfused spelling.
    ``fused="on"`` forces the fused pair on whatever ``impl`` resolves
    to (the jnp resolution runs the fused *composition* — same bits,
    useful for parity tests) and raises on ineligible configs instead of
    silently narrowing the contract.
    """
    if fused not in VALID_FUSED:
        raise ValueError(f"fused={fused!r} not in {VALID_FUSED}")
    if fused == "off":
        return None
    concrete = resolve_impl(impl)
    reason = fused_unsupported(shape, bits, group_size, levels)
    if reason is None and rp_ratio > 1:
        reason = (f"rp_ratio={rp_ratio} projects before quantization; the "
                  "fused epilogue quantizes the matmul operand itself")
    if fused == "on":
        if reason is not None:
            raise ValueError(f"fused='on' cannot run this config: {reason}")
        return concrete
    # auto: fuse only on the real kernel path
    if reason is not None or concrete != "pallas":
        return None
    return concrete


def rp_kernel_unsupported(d_in: int, d_out: int, *, tn: int = 128,
                          tk: int = 128) -> str | None:
    if d_out % tn or d_in % tk:
        return (f"rp dims ({d_in}->{d_out}) not multiples of the "
                f"({tk},{tn}) tile")
    return None


def route_rp(impl: str, d_in: int, d_out: int, *, tn: int = 128,
             tk: int = 128) -> str:
    """Concrete impl for RP/IRP — best-effort (jnp fallback, never raises).

    RP across impls agrees to float tolerance only (matmul accumulation
    order), so forcing a kernel here buys no bit-parity; shapes off the
    tile grid quietly take the reference matmul.
    """
    concrete = resolve_impl(impl)
    if concrete == "jnp":
        return "jnp"
    if rp_kernel_unsupported(d_in, d_out, tn=tn, tk=tk):
        return "jnp"
    return concrete


# ------------------------------------------------------------ block helpers
def to_blocks(x: jnp.ndarray, group_size: int) -> tuple[jnp.ndarray, int]:
    """Flatten + regroup into (n_blocks, G) with replicate tail padding.

    The *within-block* tail is padded by replicating the last element
    (cannot widen the final block's [min, max] envelope — zeros would).
    Whole-row padding to the kernel tile (``ops._pad_rows``) happens below
    this layer and only ever appends fake blocks that are sliced off, so
    real block stats are never contaminated.
    """
    return quantmod.group_reshape(x, group_size)


def from_blocks(blocks: jnp.ndarray, shape: tuple[int, ...],
                dtype=jnp.float32) -> jnp.ndarray:
    """Drop tail padding and restore the original shape."""
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


# -------------------------------------------------------------- primitives
def quantize_blocks(blocks, bits: int, seed, levels=None, *,
                    impl: str = "auto", rows_per_tile: int = 8):
    """(n_blocks, G) f32 -> (packed u32, zero (n,), rng (n,))."""
    concrete = route_quant(impl, bits, blocks.shape[-1], levels)
    return ops.quantize_packed(blocks, bits, seed, normalize_levels(levels),
                               impl=concrete, rows_per_tile=rows_per_tile)


def dequantize_blocks(packed, zero, rng, bits: int, group_size: int,
                      levels=None, *, impl: str = "auto",
                      rows_per_tile: int = 8):
    """(packed, zero (n,), rng (n,)) -> (n_blocks, G) f32."""
    concrete = route_quant(impl, bits, group_size, levels)
    return ops.dequantize_packed(packed, zero, rng, bits, group_size,
                                 normalize_levels(levels), impl=concrete,
                                 rows_per_tile=rows_per_tile)


def matmul_quantize(x2d, w, bits: int, seed, levels=None, *,
                    impl: str, group_size: int, tm: int | None = None,
                    tn: int | None = None):
    """Fused ``y = x @ w`` + quantize/pack ``x`` in the epilogue.

    ``impl`` must already be a *routed concrete* impl (the return value
    of :func:`route_fused`); this layer only normalizes the level table
    and forwards tile choices to the autotuned kernel entry.
    """
    return ops.matmul_quantize_packed(x2d, w, bits, seed,
                                      normalize_levels(levels), impl=impl,
                                      group_size=group_size, tm=tm, tn=tn)


def dequant_matmul(packed, zero, rng, g2d, bits: int, group_size: int,
                   d: int, levels=None, *, impl: str,
                   tile_rows: int | None = None, tn: int | None = None):
    """Fused ``dw = dequant(packed)ᵀ @ g`` (backward-prologue dequant)."""
    return ops.dequant_matmul_packed(packed, zero, rng, g2d, bits,
                                     group_size, d,
                                     normalize_levels(levels), impl=impl,
                                     tile_rows=tile_rows, tn=tn)


def rp(x, seed, d_out: int, *, impl: str = "auto"):
    """Project the last dim D -> d_out (any leading rank)."""
    d_in = x.shape[-1]
    concrete = route_rp(impl, d_in, d_out)
    lead = x.shape[:-1]
    out = ops.rp_project(x.reshape(-1, d_in), seed, d_out, impl=concrete)
    return out.reshape(*lead, d_out)


def irp(x, seed, d_in: int, *, impl: str = "auto"):
    """Recover the last dim r -> d_in (any leading rank)."""
    r = x.shape[-1]
    concrete = route_rp(impl, d_in, r)  # kernel reads (d_in x r) transposed
    lead = x.shape[:-1]
    out = ops.irp_project(x.reshape(-1, r), seed, d_in, impl=concrete)
    return out.reshape(*lead, d_in)
