"""Kernel-backend dispatch for the whole compression stack.

This module owns the *execution strategy* of every compress/decompress
primitive.  Callers (``core.compressor``, and through it the GNN models,
the transformer ``compressed_block`` path and the benchmarks) never pick a
kernel themselves — they name an ``impl`` and this layer routes:

  * ``"jnp"``     — the pure-jnp reference path (``repro.kernels.ref``)
  * ``"interp"``  — Pallas interpret mode (CPU validation of the kernels)
  * ``"pallas"``  — real Pallas lowering (the TPU deployment path)
  * ``"auto"``    — pallas on TPU, jnp elsewhere; unsupported shapes fall
                    back to jnp instead of erroring

All impls produce **bit-identical packed words** for quantize+pack (the SR
noise is a counter hash and the strided pack layout is shared; see
``tests/test_backend.py`` for the parity gate).  Random projection is a
float matmul, so impls agree to float tolerance, not bit-exactly — RP
routing is therefore best-effort: shapes that don't meet the Pallas tile
constraints silently use the jnp matmul.

Static-argument discipline: VM level tables are normalized to *hashable
tuples of python floats* before they reach ``pallas_call`` (the kernels
unroll them into compare/select chains).  Passing a traced array as a
level table is an error by construction.

``use_impl`` installs a trace-time override (operator switch) that takes
precedence over per-config ``impl`` fields.  It affects *tracing* — an
already-compiled jit executable is not retraced when the override changes.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp

from repro.core import quant as quantmod
from repro.kernels import ops

VALID_IMPLS = ("auto", "jnp", "interp", "pallas")

_OVERRIDE: list[str] = []  # stack managed by use_impl()


def _check_impl(impl: str) -> str:
    if impl not in VALID_IMPLS:
        raise ValueError(f"impl={impl!r} not in {VALID_IMPLS}")
    return impl


@contextlib.contextmanager
def use_impl(impl: str | None):
    """Trace-time backend override; ``None`` is a no-op (plumbing-friendly)."""
    if impl is None:
        yield
        return
    _OVERRIDE.append(_check_impl(impl))
    try:
        yield
    finally:
        _OVERRIDE.pop()


def current_override() -> str | None:
    return _OVERRIDE[-1] if _OVERRIDE else None


def resolve_impl(impl: str = "auto") -> str:
    """Concrete impl after applying the ``use_impl`` override and ``auto``."""
    impl = _check_impl(_OVERRIDE[-1] if _OVERRIDE else impl)
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def available_impl(impl: str) -> str:
    """Downgrade a *recorded* concrete impl to one runnable on this host.

    ``CompressedTensor.impl`` may say "pallas" in a checkpoint written on
    TPU; all impls are bit-identical, so restoring on a CPU host should
    quietly re-route through ``auto`` rather than fail to lower.
    """
    if impl == "pallas" and jax.default_backend() != "tpu":
        return "auto"
    return impl


# ------------------------------------------------------------- level tables
# Single definition lives next to the kernels (the consumer that makes the
# static-tuple requirement real); re-exported here as the public name.
normalize_levels = ops.static_levels


# ----------------------------------------------------------------- routing
def quant_kernel_unsupported(bits: int, group_size: int,
                             levels) -> str | None:
    """Why the fused quant kernel can't run this config (None = it can)."""
    if 32 % bits:
        return f"bits={bits} does not divide 32"
    vpw = 32 // bits
    if group_size % vpw:
        return (f"group_size={group_size} is not a multiple of the "
                f"{vpw} codes-per-word pack width")
    if levels is not None and len(levels) > 16:
        return (f"VM table has {len(levels)} levels; the unrolled kernel "
                "chain supports at most 16 (bits <= 4)")
    return None


def route_quant(impl: str, bits: int, group_size: int, levels=None) -> str:
    """Concrete impl for quantize/dequantize.

    ``auto`` falls back to jnp when the kernel can't run the config; an
    *explicitly* requested kernel impl raises instead — the parity contract
    must never be silently narrowed.
    """
    requested = _check_impl(_OVERRIDE[-1] if _OVERRIDE else impl)
    concrete = resolve_impl(requested)
    if concrete == "jnp":
        return "jnp"
    reason = quant_kernel_unsupported(bits, group_size,
                                      normalize_levels(levels))
    if reason is None:
        return concrete
    if requested == "auto":
        return "jnp"
    raise ValueError(f"impl={requested!r} cannot run this config: {reason}")


def rp_kernel_unsupported(d_in: int, d_out: int, *, tn: int = 128,
                          tk: int = 128) -> str | None:
    if d_out % tn or d_in % tk:
        return (f"rp dims ({d_in}->{d_out}) not multiples of the "
                f"({tk},{tn}) tile")
    return None


def route_rp(impl: str, d_in: int, d_out: int, *, tn: int = 128,
             tk: int = 128) -> str:
    """Concrete impl for RP/IRP — best-effort (jnp fallback, never raises).

    RP across impls agrees to float tolerance only (matmul accumulation
    order), so forcing a kernel here buys no bit-parity; shapes off the
    tile grid quietly take the reference matmul.
    """
    concrete = resolve_impl(impl)
    if concrete == "jnp":
        return "jnp"
    if rp_kernel_unsupported(d_in, d_out, tn=tn, tk=tk):
        return "jnp"
    return concrete


# ------------------------------------------------------------ block helpers
def to_blocks(x: jnp.ndarray, group_size: int) -> tuple[jnp.ndarray, int]:
    """Flatten + regroup into (n_blocks, G) with replicate tail padding.

    The *within-block* tail is padded by replicating the last element
    (cannot widen the final block's [min, max] envelope — zeros would).
    Whole-row padding to the kernel tile (``ops._pad_rows``) happens below
    this layer and only ever appends fake blocks that are sliced off, so
    real block stats are never contaminated.
    """
    return quantmod.group_reshape(x, group_size)


def from_blocks(blocks: jnp.ndarray, shape: tuple[int, ...],
                dtype=jnp.float32) -> jnp.ndarray:
    """Drop tail padding and restore the original shape."""
    n = 1
    for s in shape:
        n *= s
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)


# -------------------------------------------------------------- primitives
def quantize_blocks(blocks, bits: int, seed, levels=None, *,
                    impl: str = "auto", rows_per_tile: int = 8):
    """(n_blocks, G) f32 -> (packed u32, zero (n,), rng (n,))."""
    concrete = route_quant(impl, bits, blocks.shape[-1], levels)
    return ops.quantize_packed(blocks, bits, seed, normalize_levels(levels),
                               impl=concrete, rows_per_tile=rows_per_tile)


def dequantize_blocks(packed, zero, rng, bits: int, group_size: int,
                      levels=None, *, impl: str = "auto",
                      rows_per_tile: int = 8):
    """(packed, zero (n,), rng (n,)) -> (n_blocks, G) f32."""
    concrete = route_quant(impl, bits, group_size, levels)
    return ops.dequantize_packed(packed, zero, rng, bits, group_size,
                                 normalize_levels(levels), impl=concrete,
                                 rows_per_tile=rows_per_tile)


def rp(x, seed, d_out: int, *, impl: str = "auto"):
    """Project the last dim D -> d_out (any leading rank)."""
    d_in = x.shape[-1]
    concrete = route_rp(impl, d_in, d_out)
    lead = x.shape[:-1]
    out = ops.rp_project(x.reshape(-1, d_in), seed, d_out, impl=concrete)
    return out.reshape(*lead, d_out)


def irp(x, seed, d_in: int, *, impl: str = "auto"):
    """Recover the last dim r -> d_in (any leading rank)."""
    r = x.shape[-1]
    concrete = route_rp(impl, d_in, r)  # kernel reads (d_in x r) transposed
    lead = x.shape[:-1]
    out = ops.irp_project(x.reshape(-1, r), seed, d_in, impl=concrete)
    return out.reshape(*lead, d_in)
