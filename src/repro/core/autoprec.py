"""Variance-guided adaptive per-layer bit allocation (ActNN/GACT-style).

The paper's improved variance model (§3.2, Eq. 7-10) prices the expected
stochastic-rounding error of *one* level table; this module spends that
model across a whole network.  Given cheap per-layer sensitivity statistics
(:class:`LayerStats`: stash shape + second moment of the per-block ranges,
collected from a single forward pass) and a total activation-memory budget,
it solves for per-layer ``bits ∈ {1, 2, 4, 8}`` minimizing the total
expected dequantization variance

    Σ_layers  n_blocks · G · E[range²] · E[Var(⌊h⌉)] / B²     (B = 2^bits−1)

where ``E[Var(⌊h⌉)]`` is :func:`repro.core.variance.expected_sr_variance`
under the CN_[1/D] model with the layer's own level table (uniform or VM)
and ``E[range²]`` rescales the normalized variance back to activation units.
When a layer carries a calibrated ``grad_sens`` (two-seed gradient probe,
see :class:`LayerStats`), the objective prices the *gradient* noise the
stash actually induces in ``dw = x̂ᵀg`` instead of the raw moment product —
same bit-scaling curve, empirically weighted per layer.

Everything here runs at configuration time in numpy/python — the output is
a tuple of ints that becomes a per-layer ``CompressionConfig`` tuple on
``GNNConfig`` (see :meth:`repro.graph.models.GNNConfig.with_layer_bits`).
The training-time lifecycle — budget freezing, the two-seed gradient
probe, refresh cadence, and the plan-recompile hook — is owned by
:class:`repro.engine.precision.AutoprecController` behind
``PrecisionPolicy(kind="autoprec")``.

The solver is a greedy marginal-gain ascent (start every layer at the
cheapest width, repeatedly buy the upgrade with the best Δvariance/Δbyte
that still fits), backstopped by an exhaustive sweep of the uniform
allocations: the returned allocation never costs more bytes than the budget
and never has higher modeled variance than any uniform bit-width that fits
the same budget — so "allocated mixed" dominates "fixed INT-b at equal
bytes" by construction.
"""
from __future__ import annotations

import dataclasses

from repro.core.compressor import CompressionConfig
from repro.core.pack import packed_nbytes
from repro.core.variance import expected_sr_variance, expected_sr_variance_uniform

#: Bit-widths the packer supports densely (32 % bits == 0, <= 8).
BIT_CHOICES = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class LayerStats:
    """Per-layer sensitivity statistics for the allocator.

    shape        post-RP stash shape (what actually gets quantized+packed)
    n_blocks     quantization blocks at the layer's group_size
    rng_sq_mean  E[range²] over blocks — the layer's sensitivity scale:
                 dequantization variance is proportional to it (Eq. 3 scales
                 codes by range/B, so SR noise re-enters squared).
    grad_sens    optional calibrated dequantization-*gradient* sensitivity:
                 the layer's realized SR noise in ``dw = x̂ᵀg`` divided by
                 :func:`normalized_sr_variance` at the width it was measured
                 at (a two-seed gradient probe isolates it exactly — ``dx``
                 and the ReLU mask are SR-noise-free, so only the layer's
                 own stash contributes).  When present it replaces the pure
                 range-moment scale, folding E[g²] into the objective.
    """

    shape: tuple[int, ...]
    n_blocks: int
    rng_sq_mean: float
    grad_sens: float | None = None

    @property
    def n_elements(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


def normalized_sr_variance(cfg: CompressionConfig) -> float:
    """E[Var(⌊h⌉)]/B² under CN_[1/D] with ``cfg``'s level table.

    The per-unit-range² bit-scaling curve: dequantization multiplies the
    normalized SR noise by range/B, so dividing Eq. 10 by B² prices a
    width/table change independent of the activation scale (≈ 4^-bits for
    uniform tables; VM tables sit below their uniform counterpart).
    """
    B = 2**cfg.bits - 1
    lv = cfg.levels()
    d = cfg.cn_dim()
    evar = (expected_sr_variance(lv, d, cfg.bits) if lv is not None
            else expected_sr_variance_uniform(d, cfg.bits))
    return evar / B**2


def expected_layer_variance(stat: LayerStats, cfg: CompressionConfig) -> float:
    """Total expected dequantization(-gradient) SR variance of one layer."""
    e = normalized_sr_variance(cfg)
    if stat.grad_sens is not None:
        return stat.grad_sens * e
    return stat.n_blocks * cfg.group_size * stat.rng_sq_mean * e


def layer_stash_bytes(stat: LayerStats, cfg: CompressionConfig) -> int:
    """Packed bytes of one layer's quantized stash (codes + block stats)."""
    return packed_nbytes(stat.shape, cfg.bits, cfg.group_size)


def total_expected_variance(stats, cfgs) -> float:
    """Σ expected layer variance over (stats, per-layer config) pairs;
    ``None`` entries (uncompressed layers) contribute zero."""
    return sum(expected_layer_variance(s, c)
               for s, c in zip(stats, cfgs)
               if s is not None and c is not None)


def total_stash_bytes(stats, cfgs) -> int:
    return sum(layer_stash_bytes(s, c)
               for s, c in zip(stats, cfgs)
               if s is not None and c is not None)


def budget_bytes_for(stats, templates, avg_bits: float) -> int:
    """Byte budget equivalent to ``avg_bits`` bits per stashed element.

    Word-aligned per layer exactly like the packer, plus the 8-byte
    per-block (zero, range) overhead — so an integer ``avg_bits`` in
    :data:`BIT_CHOICES` reproduces the fixed-width footprint bit for bit
    (``budget_bytes_for(stats, t, 2) == Σ packed_nbytes(..., 2, G)`` when
    G is a pack-width multiple).
    """
    total = 0
    for s, t in zip(stats, templates):
        if s is None or t is None:
            continue
        words_per_block = int(-(-(t.group_size * float(avg_bits)) // 32))
        total += (4 * words_per_block + 8) * s.n_blocks
    return total


def allocate_bits(stats, templates, budget_bytes: int,
                  choices=BIT_CHOICES) -> tuple[int, ...]:
    """Solve per-layer bit-widths under a total byte budget.

    stats        list of :class:`LayerStats` (or ``None`` for layers with no
                 compression) — one entry per network layer
    templates    matching list of ``CompressionConfig`` (or ``None``); each
                 layer keeps its own group_size / rp_ratio / vm settings and
                 only ``bits`` is reassigned
    budget_bytes ceiling on the summed packed stash bytes of all compressed
                 layers (block-stat overhead included; it is width-invariant)

    Returns one ``int`` per layer (0 for uncompressed layers).  If even the
    cheapest width exceeds the budget, the all-minimum allocation is
    returned — the closest feasible point, never an exception (a too-tight
    budget should degrade, not kill a training run).
    """
    choices = tuple(sorted(choices))
    live = [i for i, (s, t) in enumerate(zip(stats, templates))
            if s is not None and t is not None]
    if not live:
        return tuple(0 for _ in stats)

    bytes_tab = {}
    var_tab = {}
    for i in live:
        for b in choices:
            c = dataclasses.replace(templates[i], bits=b)
            bytes_tab[i, b] = layer_stash_bytes(stats[i], c)
            var_tab[i, b] = expected_layer_variance(stats[i], c)

    level = {i: 0 for i in live}  # index into choices
    cur_bytes = sum(bytes_tab[i, choices[0]] for i in live)

    def alloc_of(level):
        return {i: choices[level[i]] for i in live}

    # greedy: buy the best Δvariance per Δbyte upgrade that still fits
    while True:
        best, best_gain = None, 0.0
        for i in live:
            if level[i] + 1 >= len(choices):
                continue
            b0, b1 = choices[level[i]], choices[level[i] + 1]
            dbytes = bytes_tab[i, b1] - bytes_tab[i, b0]
            if cur_bytes + dbytes > budget_bytes:
                continue
            dvar = var_tab[i, b0] - var_tab[i, b1]
            if dvar <= 0.0:
                continue
            # word-padding can make an upgrade byte-free — always take it
            gain = dvar / max(dbytes, 1e-9) if dbytes > 0 else float("inf")
            if best is None or gain > best_gain:
                best, best_gain = i, gain
        if best is None:
            break
        cur_bytes += bytes_tab[best, choices[level[best] + 1]] \
            - bytes_tab[best, choices[level[best]]]
        level[best] += 1

    cand = alloc_of(level)
    cand_var = sum(var_tab[i, cand[i]] for i in live)

    # backstop: never worse than any *uniform* width that fits the budget
    for b in choices:
        ub = sum(bytes_tab[i, b] for i in live)
        if ub > budget_bytes:
            continue
        uv = sum(var_tab[i, b] for i in live)
        if uv < cand_var:
            cand = {i: b for i in live}
            cand_var = uv

    return tuple(cand.get(i, 0) for i in range(len(stats)))
