"""Improved variance minimization (paper §3.2, App. A-C).

Models normalized activations with the clipped normal
``CN_[1/D](μ=B/2, σ=-μ/Φ⁻¹(1/D))`` (paper Eq. 7), computes the expected
stochastic-rounding variance for an arbitrary level table (Eq. 9/10), and
numerically optimizes the interior quantization levels (App. B).

All of this runs at *configuration* time in numpy/scipy; the resulting level
table is a tiny constant fed into the jnp/Pallas quantizers.
"""
from __future__ import annotations

import functools

import numpy as np

_SQRT2 = float(np.sqrt(2.0))


def _ndtri(p: float) -> float:
    """Φ⁻¹ — prefer scipy, fall back to a rational approximation."""
    try:
        from scipy.special import ndtri

        return float(ndtri(p))
    except Exception:  # pragma: no cover - scipy is installed here
        # Acklam's approximation
        a = [-3.969683028665376e01, 2.209460984245205e02, -2.759285104469687e02,
             1.383577518672690e02, -3.066479806614716e01, 2.506628277459239e00]
        b = [-5.447609879822406e01, 1.615858368580409e02, -1.556989798598866e02,
             6.680131188771972e01, -1.328068155288572e01]
        c = [-7.784894002430293e-03, -3.223964580411365e-01, -2.400758277161838e00,
             -2.549732539343734e00, 4.374664141464968e00, 2.938163982698783e00]
        d = [7.784695709041462e-03, 3.224671290700398e-01, 2.445134137142996e00,
             3.754408661907416e00]
        plow, phigh = 0.02425, 1 - 0.02425
        if p < plow:
            q = np.sqrt(-2 * np.log(p))
            return (((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
                   ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)
        if p <= phigh:
            q = p - 0.5
            r = q * q
            return (((((a[0] * r + a[1]) * r + a[2]) * r + a[3]) * r + a[4]) * r + a[5]) * q / \
                   (((((b[0] * r + b[1]) * r + b[2]) * r + b[3]) * r + b[4]) * r + 1)
        q = np.sqrt(-2 * np.log(1 - p))
        return -(((((c[0] * q + c[1]) * q + c[2]) * q + c[3]) * q + c[4]) * q + c[5]) / \
               ((((d[0] * q + d[1]) * q + d[2]) * q + d[3]) * q + 1)


def clipped_normal_params(D: int, bits: int = 2) -> tuple[float, float]:
    """(μ, σ) of CN_[1/D] (paper Eq. 7): μ = B/2, σ = -μ/Φ⁻¹(1/D)."""
    B = 2**bits - 1
    mu = B / 2.0
    sigma = -mu / _ndtri(1.0 / D)
    return mu, sigma


def _normal_pdf(h: np.ndarray, mu: float, sigma: float) -> np.ndarray:
    z = (h - mu) / sigma
    return np.exp(-0.5 * z * z) / (sigma * np.sqrt(2 * np.pi))


def clipped_normal_pdf_grid(
    D: int, bits: int = 2, n_grid: int = 8192
) -> tuple[np.ndarray, np.ndarray]:
    """(h_grid, density) of the *continuous part* of CN on (0, B).

    The clip masses at h=0 and h=B (each exactly 1/D) sit at quantization
    levels and contribute zero SR variance, so the expected-variance integral
    only needs the continuous part.
    """
    B = 2**bits - 1
    mu, sigma = clipped_normal_params(D, bits)
    h = np.linspace(0.0, float(B), n_grid)
    return h, _normal_pdf(h, mu, sigma)


def sr_variance(h: np.ndarray, levels: np.ndarray) -> np.ndarray:
    """Var(⌊h⌉) for each h given a strictly-increasing level table (Eq. 9).

    For h in bin [α_{i-1}, α_i] of width δ_i:
    Var = δ_i (h − α_{i-1}) − (h − α_{i-1})².
    """
    levels = np.asarray(levels, dtype=np.float64)
    idx = np.clip(np.searchsorted(levels, h, side="right"), 1, len(levels) - 1)
    lo = levels[idx - 1]
    hi = levels[idx]
    t = h - lo
    return (hi - lo) * t - t * t


def expected_sr_variance(
    levels, D: int, bits: int = 2, n_grid: int = 8192
) -> float:
    """E[Var(⌊h⌉)] under CN_[1/D] (paper Eq. 10), trapezoid-integrated."""
    h, pdf = clipped_normal_pdf_grid(D, bits, n_grid)
    v = sr_variance(h, np.asarray(levels, np.float64))
    return float(np.trapezoid(v * pdf, h))


def expected_sr_variance_uniform(D: int, bits: int = 2, n_grid: int = 8192) -> float:
    B = 2**bits - 1
    return expected_sr_variance(np.arange(B + 1.0), D, bits, n_grid)


@functools.lru_cache(maxsize=None)
def optimize_levels(D: int, bits: int = 2, n_grid: int = 8192) -> tuple[float, ...]:
    """Interior levels minimizing Eq. 10; returns the full level table.

    INT2: optimize [α, β] of the central bin (paper Fig. 1-B).  Generic in
    ``bits`` — 2**bits − 2 free interior levels.  App. B: computed once per
    D (the paper precomputes D ∈ {4..2048}); lru_cache is our table.
    """
    B = 2**bits - 1
    n_int = 2**bits - 2
    h, pdf = clipped_normal_pdf_grid(D, bits, n_grid)

    def unconstrain(free: np.ndarray) -> np.ndarray:
        # strictly-increasing interior levels in (0, B) via softmax-like gaps
        gaps = np.exp(free - np.max(free))
        gaps = gaps / gaps.sum()
        cuts = np.cumsum(gaps)[:-1] * B
        return cuts

    def objective(free: np.ndarray) -> float:
        interior = unconstrain(free)
        levels = np.concatenate([[0.0], interior, [float(B)]])
        v = sr_variance(h, levels)
        return float(np.trapezoid(v * pdf, h))

    x0 = np.zeros(n_int + 1)  # uniform gaps == EXACT levels
    try:
        from scipy.optimize import minimize

        res = minimize(objective, x0, method="Nelder-Mead",
                       options={"xatol": 1e-6, "fatol": 1e-12, "maxiter": 4000})
        best = res.x
    except Exception:  # pragma: no cover
        best = x0
        step = 0.5
        fb = objective(best)
        for _ in range(200):
            improved = False
            for i in range(len(best)):
                for s in (+step, -step):
                    cand = best.copy()
                    cand[i] += s
                    fc = objective(cand)
                    if fc < fb:
                        best, fb, improved = cand, fc, True
            if not improved:
                step *= 0.5
                if step < 1e-6:
                    break
    interior = unconstrain(best)
    return tuple([0.0, *interior.tolist(), float(B)])


def variance_reduction(D: int, bits: int = 2) -> float:
    """Fractional reduction of E[Var] from VM levels vs uniform (Table 2)."""
    u = expected_sr_variance_uniform(D, bits)
    o = expected_sr_variance(optimize_levels(D, bits), D, bits)
    return 1.0 - o / u


def js_divergence(p: np.ndarray, q: np.ndarray, eps: float = 1e-12) -> float:
    """Jensen-Shannon divergence between two histograms (Table 2 metric)."""
    p = np.asarray(p, np.float64) + eps
    q = np.asarray(q, np.float64) + eps
    p = p / p.sum()
    q = q / q.sum()
    m = 0.5 * (p + q)
    kl = lambda a, b: float(np.sum(a * np.log(a / b)))
    return 0.5 * kl(p, m) + 0.5 * kl(q, m)


def model_histogram(D: int, bits: int, edges: np.ndarray, kind: str) -> np.ndarray:
    """Histogram (over ``edges``) of the uniform or clipped-normal model.

    Used by the Table 2 benchmark to compare both models against observed
    normalized activations.  Includes the clip masses at 0 and B for the CN.
    """
    B = 2**bits - 1
    if kind == "uniform":
        w = np.diff(edges) / B
        return w
    mu, sigma = clipped_normal_params(D, bits)
    try:
        from scipy.stats import norm

        cdf = norm.cdf(edges, mu, sigma)
    except Exception:  # pragma: no cover
        from math import erf

        cdf = np.array([0.5 * (1 + erf((e - mu) / (sigma * _SQRT2))) for e in edges])
    hist = np.diff(cdf)
    hist[0] += cdf[0]          # mass clipped to 0
    hist[-1] += 1.0 - cdf[-1]  # mass clipped to B
    return hist
