"""User-facing compression API: config + compress/decompress.

``CompressedTensor`` is the stored form of an activation map: densely packed
codes + per-block (zero, range) + the RP seed if random projection was used.
It is a registered pytree so it can sit in ``custom_vjp`` residuals, scan
carries, and checkpoints.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core import quant as quantmod
from repro.core import random_projection as rpmod
from repro.core.variance import optimize_levels


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """How to compress an activation map.

    bits        quantization precision (2 = the paper's INT2 extreme setting)
    group_size  elements per quantization block (paper §3.1).  The paper
                parameterizes this as G/R; we take the absolute element count.
    rp_ratio    D/R random-projection ratio (paper uses 8); 0 disables RP.
    vm          use variance-minimized non-uniform levels (paper §3.2).
    vm_dim      D parameter of CN_[1/D] for level optimization; defaults to
                the quantization block size (paper App. C uses the row dim).
    """

    bits: int = 2
    group_size: int = 256
    rp_ratio: int = 0
    vm: bool = False
    vm_dim: int | None = None

    def levels(self) -> tuple[float, ...] | None:
        if not self.vm:
            return None
        d = self.vm_dim or self.group_size
        return optimize_levels(int(d), self.bits)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedTensor:
    packed: jnp.ndarray        # (n_blocks, words_per_block) uint32
    zero: jnp.ndarray          # (n_blocks,) f32
    rng: jnp.ndarray           # (n_blocks,) f32
    rp_seed: jnp.ndarray       # () uint32 (unused if cfg.rp_ratio == 0)
    # --- static ---
    shape: tuple[int, ...]     # original (pre-RP) shape
    dtype: object
    cfg: CompressionConfig

    def tree_flatten(self):
        return (self.packed, self.zero, self.rng, self.rp_seed), (
            self.shape, str(jnp.dtype(self.dtype)), self.cfg)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, dtype, cfg = aux
        return cls(*children, shape=shape, dtype=jnp.dtype(dtype), cfg=cfg)

    @property
    def nbytes(self) -> int:
        return int(self.packed.size * 4 + self.zero.size * 4 + self.rng.size * 4)

    @property
    def uncompressed_nbytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return int(n * jnp.dtype(self.dtype).itemsize)


def _proj_shape(shape: tuple[int, ...], rp_ratio: int) -> tuple[int, ...]:
    if rp_ratio <= 1:
        return shape
    d = shape[-1]
    assert d % rp_ratio == 0, f"last dim {d} not divisible by rp_ratio {rp_ratio}"
    return (*shape[:-1], d // rp_ratio)


def compress(x: jnp.ndarray, cfg: CompressionConfig, seed) -> CompressedTensor:
    """Forward-pass compression: (optional RP) → block-wise SR quant → pack."""
    seed = jnp.asarray(seed, jnp.uint32)
    orig_shape, orig_dtype = tuple(x.shape), x.dtype
    rp_seed = seed ^ jnp.uint32(0xA5A5_A5A5)
    if cfg.rp_ratio > 1:
        x = rpmod.rp(x.astype(jnp.float32), rp_seed, x.shape[-1] // cfg.rp_ratio)
    levels = cfg.levels()
    lv = None if levels is None else jnp.asarray(levels, jnp.float32)
    codes, zero, rng, _ = quantmod.quantize(
        x.astype(jnp.float32), cfg.bits, cfg.group_size, seed, lv)
    packed = packmod.pack(codes, cfg.bits)
    return CompressedTensor(packed, zero, rng, rp_seed,
                            shape=orig_shape, dtype=orig_dtype, cfg=cfg)


def decompress(ct: CompressedTensor) -> jnp.ndarray:
    """Backward-pass recovery: unpack → dequant → (optional IRP)."""
    cfg = ct.cfg
    proj_shape = _proj_shape(ct.shape, cfg.rp_ratio)
    levels = cfg.levels()
    lv = None if levels is None else jnp.asarray(levels, jnp.float32)
    codes = packmod.unpack(ct.packed, cfg.bits, cfg.group_size)
    x = quantmod.dequantize(codes, ct.zero, ct.rng, cfg.bits, proj_shape, lv)
    if cfg.rp_ratio > 1:
        x = rpmod.irp(x, ct.rp_seed, ct.shape[-1])
    return x.astype(ct.dtype)
