"""User-facing compression API: config + compress/decompress.

``CompressedTensor`` is the stored form of an activation map: densely packed
codes + per-block (zero, range) + the RP seed if random projection was used.
It is a registered pytree so it can sit in ``custom_vjp`` residuals, scan
carries, and checkpoints.

Execution strategy is owned by :mod:`repro.core.backend` — this module is a
thin orchestrator: RP → fused quantize+pack → store on the way in, and
unpack+dequantize → IRP on the way back.  ``CompressionConfig.impl`` (or a
``backend.use_impl`` override) flips the whole stack between the pure-jnp
reference and the fused Pallas kernels; every impl writes bit-identical
packed words, and the tensor records the concrete backend it was written
with so decompress round-trips under ``custom_vjp`` residuals, scan
carries, and checkpoints even if the override has since been lifted.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core.variance import optimize_levels


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    """How to compress an activation map.

    bits        quantization precision (2 = the paper's INT2 extreme setting)
    group_size  elements per quantization block (paper §3.1).  The paper
                parameterizes this as G/R; we take the absolute element count.
    rp_ratio    D/R random-projection ratio (paper uses 8); 0 disables RP.
    vm          use variance-minimized non-uniform levels (paper §3.2).
    vm_dim      D parameter of CN_[1/D] for level optimization; defaults to
                the *post-RP* quantization block size, i.e.
                ``group_size // rp_ratio`` when RP is on (paper App. C uses
                the projected row dim).  ``None`` means "use the default";
                explicit values < 2 are rejected.
    impl        kernel backend: "auto" | "jnp" | "interp" | "pallas"
                (see :mod:`repro.core.backend`).  One flag flips an entire
                training job between reference and fused kernels.
    """

    bits: int = 2
    group_size: int = 256
    rp_ratio: int = 0
    vm: bool = False
    vm_dim: int | None = None
    impl: str = "auto"

    def cn_dim(self) -> int:
        """The D parameter of the CN_[1/D] activation model.

        An explicit ``vm_dim`` always wins (``None`` is the only sentinel —
        0 is rejected, not silently replaced).  The default follows paper
        App. C: the dimension the clip model sees is the *post-RP* one, so
        with ``rp_ratio > 1`` the block size is divided down by the
        projection ratio.  Clamped to 2 (CN needs Φ⁻¹(1/D) finite).
        """
        if self.vm_dim is not None:
            if self.vm_dim < 2:
                raise ValueError(
                    f"vm_dim must be >= 2 (CN_[1/D] needs 1/D < 1/2), got "
                    f"{self.vm_dim}")
            return int(self.vm_dim)
        d = (self.group_size // self.rp_ratio if self.rp_ratio > 1
             else self.group_size)
        return max(int(d), 2)

    def levels(self) -> tuple[float, ...] | None:
        if not self.vm:
            return None
        return optimize_levels(self.cn_dim(), self.bits)

    def with_impl(self, impl: str) -> "CompressionConfig":
        """Same compression scheme on a different kernel backend."""
        return dataclasses.replace(self, impl=impl)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class CompressedTensor:
    packed: jnp.ndarray        # (n_blocks, words_per_block) uint32
    zero: jnp.ndarray          # (n_blocks,) f32
    rng: jnp.ndarray           # (n_blocks,) f32
    rp_seed: jnp.ndarray       # () uint32 (unused if cfg.rp_ratio == 0)
    # --- static ---
    shape: tuple[int, ...]     # original (pre-RP) shape
    dtype: object
    cfg: CompressionConfig
    impl: str = "auto"         # concrete backend the codes were written with

    def tree_flatten(self):
        return (self.packed, self.zero, self.rng, self.rp_seed), (
            self.shape, str(jnp.dtype(self.dtype)), self.cfg, self.impl)

    @classmethod
    def tree_unflatten(cls, aux, children):
        shape, dtype, cfg, impl = aux
        return cls(*children, shape=shape, dtype=jnp.dtype(dtype), cfg=cfg,
                   impl=impl)

    @property
    def nbytes(self) -> int:
        """Exact stored bytes: every child array at its actual itemsize
        (including the ``rp_seed`` scalar), so the ledger in
        ``analysis.saved_bytes_per_layer`` and the arena planner agree
        with the live residuals to the byte."""
        return int(sum(f.size * jnp.dtype(f.dtype).itemsize
                       for f in (self.packed, self.zero, self.rng,
                                 self.rp_seed)))

    @property
    def uncompressed_nbytes(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return int(n * jnp.dtype(self.dtype).itemsize)


def _proj_shape(shape: tuple[int, ...], rp_ratio: int) -> tuple[int, ...]:
    if rp_ratio <= 1:
        return shape
    d = shape[-1]
    assert d % rp_ratio == 0, f"last dim {d} not divisible by rp_ratio {rp_ratio}"
    return (*shape[:-1], d // rp_ratio)


def compress(x: jnp.ndarray, cfg: CompressionConfig, seed,
             impl: str | None = None) -> CompressedTensor:
    """Forward-pass compression: (optional RP) → fused block SR quant+pack.

    ``impl`` overrides ``cfg.impl`` for this call; a ``backend.use_impl``
    context overrides both.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    orig_shape, orig_dtype = tuple(x.shape), x.dtype
    rp_seed = seed ^ jnp.uint32(0xA5A5_A5A5)
    requested = impl if impl is not None else cfg.impl
    levels = cfg.levels()
    if cfg.rp_ratio > 1:
        x = backend.rp(x.astype(jnp.float32), rp_seed,
                       x.shape[-1] // cfg.rp_ratio, impl=requested)
    impl_q = backend.route_quant(requested, cfg.bits, cfg.group_size, levels)
    blocks, _ = backend.to_blocks(x.astype(jnp.float32), cfg.group_size)
    packed, zero, rng = backend.quantize_blocks(
        blocks, cfg.bits, seed, levels, impl=impl_q)
    return CompressedTensor(packed, zero, rng, rp_seed,
                            shape=orig_shape, dtype=orig_dtype, cfg=cfg,
                            impl=impl_q)


def compress_matmul(x: jnp.ndarray, w: jnp.ndarray, cfg: CompressionConfig,
                    seed, impl: str | None = None, fused: str = "auto"
                    ) -> tuple[jnp.ndarray, CompressedTensor]:
    """Forward matmul with the operand compressed in the epilogue.

    Returns ``(y, ct)`` with ``y = x @ w`` (f32) and ``ct`` the stash of
    ``x`` — bit-identical packed words to :func:`compress` on the same
    backend.  Routing is :func:`repro.core.backend.route_fused`: when it
    declines (``fused="off"``, ineligible shape, or ``auto`` off the real
    kernel path) this falls back to the unfused two-pass spelling, so the
    call is always safe as a per-layer drop-in.
    """
    seed = jnp.asarray(seed, jnp.uint32)
    requested = impl if impl is not None else cfg.impl
    levels = cfg.levels()
    concrete = backend.route_fused(fused, requested, tuple(x.shape),
                                   cfg.bits, cfg.group_size, levels,
                                   cfg.rp_ratio)
    if concrete is None:
        ct = compress(x, cfg, seed, impl=impl)
        return x.astype(jnp.float32) @ w.astype(jnp.float32), ct
    y, packed, zero, rng = backend.matmul_quantize(
        x.astype(jnp.float32), w.astype(jnp.float32), cfg.bits, seed,
        levels, impl=concrete, group_size=cfg.group_size)
    ct = CompressedTensor(packed, zero, rng,
                          seed ^ jnp.uint32(0xA5A5_A5A5),
                          shape=tuple(x.shape), dtype=x.dtype, cfg=cfg,
                          impl=concrete)
    return y, ct


def decompress_matmul(ct: CompressedTensor, g2d: jnp.ndarray,
                      impl: str | None = None,
                      fused: str = "auto") -> jnp.ndarray:
    """Backward matmul ``dw = x̂ᵀ @ g`` with dequantization fused into the
    prologue (no HBM materialization of the f32 reconstruction).

    ``g2d`` is the (M, N) output gradient of the layer whose (M, D) input
    ``ct`` stashes.  Same routing/fallback story as
    :func:`compress_matmul`; on the fallback path this is exactly
    ``decompress(ct).Tᵀ``-style two-pass math, so results are
    bit-identical per impl either way (single row tile).
    """
    cfg = ct.cfg
    requested = impl if impl is not None else backend.available_impl(ct.impl)
    levels = cfg.levels()
    concrete = backend.route_fused(fused, requested, ct.shape, cfg.bits,
                                   cfg.group_size, levels, cfg.rp_ratio)
    d = ct.shape[-1]
    if concrete is None:
        x_hat = decompress(ct, impl=impl)
        return (x_hat.reshape(-1, d).astype(jnp.float32).T
                @ g2d.astype(jnp.float32))
    return backend.dequant_matmul(ct.packed, ct.zero, ct.rng,
                                  g2d.astype(jnp.float32), cfg.bits,
                                  cfg.group_size, d, levels, impl=concrete)


def decompress(ct: CompressedTensor, impl: str | None = None) -> jnp.ndarray:
    """Backward-pass recovery: unpack+dequant → (optional IRP).

    Defaults to the concrete backend the tensor was compressed with
    (``ct.impl``), downgraded to one runnable on this host — all impls are
    bit-identical, so a pallas-written checkpoint restores fine on CPU.  A
    ``backend.use_impl`` context still takes precedence.
    """
    cfg = ct.cfg
    requested = impl if impl is not None else backend.available_impl(ct.impl)
    proj_shape = _proj_shape(ct.shape, cfg.rp_ratio)
    levels = cfg.levels()
    blocks = backend.dequantize_blocks(
        ct.packed, ct.zero, ct.rng, cfg.bits, cfg.group_size, levels,
        impl=requested)
    x = backend.from_blocks(blocks, proj_shape)
    if cfg.rp_ratio > 1:
        x = backend.irp(x, ct.rp_seed, ct.shape[-1], impl=requested)
    return x.astype(ct.dtype)
