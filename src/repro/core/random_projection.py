"""Normalized Rademacher random projection (paper Eq. 4-5, EXACT's RP/IRP).

``R ∈ {±1/√r}^{D×r}`` with ``E[R Rᵀ] = I`` so RP followed by IRP is an
unbiased reconstruction.  Signs come from the counter-based hash in
:mod:`repro.core.prng`, which means the matrix never needs to be stored —
the Pallas kernel (``repro.kernels.rp_matmul``) regenerates tiles of R on
the fly (beyond-paper optimization; see DESIGN.md §3), while this module
materializes the same matrix for the reference path.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.prng import rademacher_from_counter


def rp_matrix(seed, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    """The (d_in, d_out) normalized Rademacher matrix for ``seed``."""
    counter = jnp.arange(d_in * d_out, dtype=jnp.uint32)
    signs = rademacher_from_counter(seed, counter).reshape(d_in, d_out)
    return signs.astype(dtype) * jnp.asarray(1.0 / jnp.sqrt(d_out), dtype)


def rp(h: jnp.ndarray, seed, d_out: int) -> jnp.ndarray:
    """Project rows of ``h`` from D to d_out (paper Eq. 4)."""
    mat = rp_matrix(seed, h.shape[-1], d_out, h.dtype)
    return h @ mat


def irp(h_proj: jnp.ndarray, seed, d_in: int) -> jnp.ndarray:
    """Recover (an unbiased estimate of) the original rows (paper Eq. 5)."""
    mat = rp_matrix(seed, d_in, h_proj.shape[-1], h_proj.dtype)
    return h_proj @ mat.T
