"""i-EXACT core: block-wise SR quantization + RP + variance minimization.

Execution strategy (reference jnp vs fused Pallas kernels) is owned by
:mod:`repro.core.backend`; flip it per-config via ``CompressionConfig.impl``
or globally at trace time via :func:`use_impl`.
"""
from repro.core import autoprec, backend
from repro.core.autoprec import LayerStats, allocate_bits
from repro.core.backend import resolve_impl, use_impl
from repro.core.compressor import (
    CompressionConfig,
    CompressedTensor,
    compress,
    compress_matmul,
    decompress,
    decompress_matmul,
)
from repro.core.act_compress import (
    compressed_block,
    compressed_elementwise,
    compressed_linear,
    compressed_matmul,
)
from repro.core.variance import (
    clipped_normal_params,
    expected_sr_variance,
    expected_sr_variance_uniform,
    js_divergence,
    optimize_levels,
    variance_reduction,
)

__all__ = [
    "LayerStats", "allocate_bits", "autoprec",
    "CompressionConfig", "CompressedTensor", "backend", "compress",
    "compress_matmul", "decompress", "decompress_matmul",
    "compressed_block", "compressed_elementwise",
    "compressed_linear", "compressed_matmul", "clipped_normal_params",
    "expected_sr_variance", "expected_sr_variance_uniform", "js_divergence",
    "optimize_levels", "resolve_impl", "use_impl", "variance_reduction",
]
