"""i-EXACT core: block-wise SR quantization + RP + variance minimization."""
from repro.core.compressor import (
    CompressionConfig,
    CompressedTensor,
    compress,
    decompress,
)
from repro.core.act_compress import (
    compressed_block,
    compressed_elementwise,
    compressed_linear,
    compressed_matmul,
)
from repro.core.variance import (
    clipped_normal_params,
    expected_sr_variance,
    expected_sr_variance_uniform,
    js_divergence,
    optimize_levels,
    variance_reduction,
)

__all__ = [
    "CompressionConfig", "CompressedTensor", "compress", "decompress",
    "compressed_block", "compressed_elementwise", "compressed_linear",
    "compressed_matmul", "clipped_normal_params", "expected_sr_variance",
    "expected_sr_variance_uniform", "js_divergence", "optimize_levels",
    "variance_reduction",
]
