"""Block-wise stochastic-rounding quantization (paper §2, §3.1, §3.2).

Reference (pure-jnp) implementation; ``repro.kernels`` provides the fused
Pallas path and must agree bit-exactly with this module.

Semantics
---------
A tensor is flattened and regrouped into blocks of ``group_size`` elements
(paper Eq. 6).  Each block b stores:

* ``zero[b]  = min(block)``                      (the paper's Z)
* ``range[b] = max(block) - min(block)``         (the paper's r)

The block is normalized to ``[0, B]`` with ``B = 2**bits - 1`` and every
element is stochastically rounded to one of the quantization *levels*.
With uniform levels (EXACT) the levels are the integers ``0..B``.  With
variance minimization (paper §3.2) the interior levels move to the
optimized boundaries (e.g. ``[0, α*, β*, 3]`` for INT2); stochastic
rounding between adjacent levels keeps the estimator unbiased (paper
App. A).  Stored codes are *indices into the level table*, so the
bit-width is unchanged.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.prng import hash_u32, uniform_from_counter

#: Shared clamp constant for zero-width ranges / bins.  Both the jnp path
#: (:func:`quantize_grouped`) and the fused Pallas kernels
#: (:mod:`repro.kernels.quant_blockwise`) import this single definition so the
#: two implementations cannot drift apart bit-wise.
EPS = 1e-10
_EPS = EPS  # backward-compat alias


def uniform_levels(bits: int) -> jnp.ndarray:
    """EXACT's integer quantization levels 0..B."""
    return jnp.arange(2**bits, dtype=jnp.float32)


def group_reshape(x: jnp.ndarray, group_size: int) -> tuple[jnp.ndarray, int]:
    """Flatten ``x`` and regroup into (n_blocks, group_size) (paper Eq. 6).

    The tail is padded by replicating the last element, which cannot widen the
    final block's [min, max] envelope; returns (blocks, n_valid).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % group_size
    if pad:
        flat = jnp.concatenate([flat, jnp.broadcast_to(flat[-1], (pad,))])
    return flat.reshape(-1, group_size), n


def block_stats(blocks: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(zero, range) per block — the *raw* stats, exactly as stored.

    The range of a constant block is 0 here; consumers that divide by it
    (:func:`quantize_grouped` and the fused kernels) clamp with the shared
    :data:`EPS` at the point of use, so the stored range stays exact.
    """
    zero = blocks.min(axis=-1)
    rng = blocks.max(axis=-1) - zero
    return zero, rng


def stochastic_round_to_levels(
    hnorm: jnp.ndarray,
    levels: jnp.ndarray,
    seed,
    counter_base: int = 0,
) -> jnp.ndarray:
    """SR of normalized activations in [0, B] onto ``levels`` (paper Eq. 8).

    Returns int32 codes (indices into ``levels``).  Unbiased for any strictly
    increasing level table with levels[0]=0, levels[-1]=B (paper App. A).

    ``counter_base`` offsets the per-element counter stream so callers can
    chunk one logical tensor across calls.  It is a python int and may
    exceed 2³²: the effective per-element counter is the 64-bit
    ``counter_base + index``, carried as (low word, high word) — the low
    word is the uint32 counter as before and the high word (including the
    per-element carry where a chunk straddles a 2³² boundary) is folded
    into the seed through the counter PRNG hash.  Streams therefore never
    alias across any 2³² wrap.  Whenever the high word is 0 the fold is
    the identity (``hash_u32(0) == 0``), which keeps the common path —
    and the kernels, which always run with base 0 — bit-identical.
    A *single call* must stay under 2³² elements (its index array is
    uint32); callers with larger logical tensors chunk and advance
    ``counter_base``, which is exactly the case the 64-bit carry covers.
    """
    nlev = levels.shape[0]
    # bin index i in 1..B such that levels[i-1] <= h <= levels[i]
    upper_idx = jnp.clip(
        jnp.searchsorted(levels, hnorm, side="right"), 1, nlev - 1
    ).astype(jnp.int32)
    lo = jnp.take(levels, upper_idx - 1)
    hi = jnp.take(levels, upper_idx)
    p_up = (hnorm - lo) / jnp.maximum(hi - lo, _EPS)
    base_hi, base_lo = divmod(int(counter_base), 1 << 32)
    idx = jnp.arange(hnorm.size, dtype=jnp.uint32).reshape(hnorm.shape)
    counter = idx + jnp.uint32(base_lo)
    carry = (counter < jnp.uint32(base_lo)).astype(jnp.uint32)
    hi_word = jnp.uint32(base_hi & 0xFFFF_FFFF) + carry
    seed = jnp.asarray(seed, jnp.uint32) ^ hash_u32(hi_word)
    u = uniform_from_counter(seed, counter)
    return jnp.where(u < p_up, upper_idx, upper_idx - 1).astype(jnp.int32)


def quantize_grouped(
    blocks: jnp.ndarray,
    bits: int,
    seed,
    levels: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize (n_blocks, G) -> (codes int32, zero f32, range f32)."""
    if levels is None:
        levels = uniform_levels(bits)
    B = float(2**bits - 1)
    zero, rng = block_stats(blocks)
    safe = jnp.maximum(rng, _EPS)
    hnorm = (blocks - zero[:, None]) / safe[:, None] * B
    hnorm = jnp.clip(hnorm, 0.0, B)
    codes = stochastic_round_to_levels(hnorm, levels, seed)
    return codes, zero.astype(jnp.float32), rng.astype(jnp.float32)


def dequantize_grouped(
    codes: jnp.ndarray,
    zero: jnp.ndarray,
    rng: jnp.ndarray,
    bits: int,
    levels: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Inverse of :func:`quantize_grouped` (paper Eq. 3 with level table)."""
    if levels is None:
        levels = uniform_levels(bits)
    B = float(2**bits - 1)
    vals = jnp.take(levels, codes)
    return vals * (rng[:, None] / B) + zero[:, None]


def quantize(
    x: jnp.ndarray,
    bits: int,
    group_size: int,
    seed,
    levels: jnp.ndarray | None = None,
):
    """Block-wise quantize an arbitrary tensor.

    Returns (codes (n_blocks, G) int32, zero, range, n_valid).
    """
    blocks, n_valid = group_reshape(x, group_size)
    codes, zero, rng = quantize_grouped(blocks, bits, seed, levels)
    return codes, zero, rng, n_valid


def dequantize(
    codes: jnp.ndarray,
    zero: jnp.ndarray,
    rng: jnp.ndarray,
    bits: int,
    shape: tuple[int, ...],
    levels: jnp.ndarray | None = None,
    dtype=jnp.float32,
) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    blocks = dequantize_grouped(codes, zero, rng, bits, levels)
    return blocks.reshape(-1)[:n].reshape(shape).astype(dtype)
