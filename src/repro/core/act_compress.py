"""Activation-compressed training primitives (``custom_vjp``).

Three integration levels, lowest to highest:

* :func:`compressed_matmul` — ``y = x @ w`` saving a compressed ``x``.
  ``dx = g @ wᵀ`` stays exact (it only needs ``w``); only ``dw = x̂ᵀ g`` sees
  the unbiased reconstruction — exactly where EXACT injects its estimator.
* :func:`compressed_elementwise` — nonlinearity with compressed input stash.
* :func:`compressed_block` — wrap an arbitrary block ``f(x, params)``:
  forward runs exactly, the block *input* is stored compressed, and the
  backward recomputes the block from the reconstruction (ACT + remat hybrid;
  this is how transformer layers integrate under ``lax.scan``).

Seeds are threaded as uint32 scalars; their cotangents are float0.

Kernel backend: every primitive honors ``cfg.impl`` (routed through
:mod:`repro.core.backend`), and the residual ``CompressedTensor`` records
the concrete backend it was written with, so the backward pass decompresses
on the same path even across ``custom_vjp`` residuals and scan carries.
A ``backend.use_impl`` context at trace time overrides all of it.

Where the residuals *live* is a separate axis: ``offload=`` on
:func:`compressed_matmul` / :func:`compressed_block` moves the compressed
stash to host between forward and backward through
:mod:`repro.offload.engine` (the residual becomes a tiny
:class:`~repro.offload.engine.HostStash` ticket — scan-stackable, so the
transformer layer loop carries words, not code arrays).  Whole-network
stash routing — per-tensor or pooled-arena storage for *all* of a GNN's
layers behind one ``custom_vjp`` — lives one level up in
:mod:`repro.engine.forward` (planned by :mod:`repro.offload.arena`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.compressor import CompressionConfig, compress, decompress


def _maybe_offload(ct, seed, offload):
    """Residual placement: the CompressedTensor itself ("device"/None) or a
    host-store ticket (host policies; see repro.offload.engine)."""
    if offload in (None, "device"):
        return ct
    from repro.offload import engine

    engine.check_policy(offload)
    return engine.offload_compressed(ct, seed)


def _maybe_fetch(res, offload):
    if offload in (None, "device"):
        return res
    from repro.offload import engine

    return engine.fetch_compressed(res)


def zero_ct(x):
    """Cotangent for a non-differentiable (integer) input — shared by every
    stash-aware ``custom_vjp`` (the per-op primitives here and the engine's
    whole-network forward, :mod:`repro.engine.forward`)."""
    return np.zeros(np.shape(x), dtype=jax.dtypes.float0)


_zero_ct = zero_ct  # pre-engine private spelling


# ---------------------------------------------------------------- matmul
@partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def compressed_matmul(x, w, seed, cfg: CompressionConfig, offload=None):
    return x @ w


def _cm_fwd(x, w, seed, cfg, offload):
    y = x @ w
    ct = _maybe_offload(compress(x, cfg, seed), seed, offload)
    return y, (ct, w, seed)


def _cm_bwd(cfg, offload, res, g):
    ct, w, seed = res
    x_hat = decompress(_maybe_fetch(ct, offload))
    dx = g @ w.T
    x2 = x_hat.reshape(-1, x_hat.shape[-1])
    g2 = g.reshape(-1, g.shape[-1])
    dw = (x2.T @ g2).astype(w.dtype)
    return dx.astype(x_hat.dtype), dw, _zero_ct(seed)


compressed_matmul.defvjp(_cm_fwd, _cm_bwd)


def compressed_linear(x, w, b, seed, cfg: CompressionConfig):
    y = compressed_matmul(x, w, seed, cfg)
    return y if b is None else y + b


# ---------------------------------------------------------- elementwise
def compressed_elementwise(fn, x, seed, cfg: CompressionConfig):
    """``fn(x)`` whose backward re-evaluates fn' at the reconstruction."""

    @partial(jax.custom_vjp, nondiff_argnums=())
    def g(x, seed):
        return fn(x)

    def g_fwd(x, seed):
        return fn(x), (compress(x, cfg, seed), seed)

    def g_bwd(res, ct_y):
        ctens, seed = res
        x_hat = decompress(ctens)
        _, vjp = jax.vjp(fn, x_hat)
        (dx,) = vjp(ct_y)
        return dx, _zero_ct(seed)

    g.defvjp(g_fwd, g_bwd)
    return g(x, seed)


# ----------------------------------------------------------------- block
def compressed_block(f, cfg: CompressionConfig, offload: str | None = None):
    """Wrap ``f(x, params) -> y``: store compressed x, recompute f in bwd.

    Equivalent memory profile to ``jax.checkpoint`` except the stashed block
    input itself is block-quantized (the paper's technique applied at the
    residual-stream level).  Returns ``g(x, params, seed) -> y``.

    ``offload`` ("host" | "pinned-paged") parks the compressed stash in
    the host store between forward and backward: under ``lax.scan`` the
    per-layer residual is then a scan-stackable ticket instead of the
    code arrays, so the layer loop's saved state shrinks to a few words
    per layer (seeds must be distinct per layer — they key the store).
    """

    @jax.custom_vjp
    def g(x, params, seed):
        return f(x, params)

    def g_fwd(x, params, seed):
        y = f(x, params)
        ct = _maybe_offload(compress(x, cfg, seed), seed, offload)
        return y, (ct, params, seed)

    def g_bwd(res, ct_y):
        ctens, params, seed = res
        x_hat = decompress(_maybe_fetch(ctens, offload))
        _, vjp = jax.vjp(f, x_hat, params)
        dx, dparams = vjp(ct_y)
        return dx, dparams, _zero_ct(seed)

    g.defvjp(g_fwd, g_bwd)
    return g
