from repro.parallel.halo import (HaloProgram, build_halo_program,
                                 exchange_widths, graph_mesh,
                                 halo_bytes_per_epoch, halo_exchange)
from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                     param_pspecs, to_named)

__all__ = ["batch_pspecs", "cache_pspecs", "param_pspecs", "to_named",
           "HaloProgram", "build_halo_program", "exchange_widths",
           "graph_mesh", "halo_bytes_per_epoch", "halo_exchange"]
