from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                     param_pspecs, to_named)

__all__ = ["batch_pspecs", "cache_pspecs", "param_pspecs", "to_named"]
