"""Partition-parallel halo exchange over a ``graph`` mesh axis.

The mesh sampling policy (``SamplingPolicy(kind="mesh")``) shards graph
partitions across devices: a mesh of ``m`` devices trains ``n_parts``
partitions in ``rounds = n_parts // m`` rounds, round ``r`` hosting
partitions ``[r*m, (r+1)*m)`` with partition ``r*m + i`` on device ``i``.
Edges whose endpoints live in different *rounds* are dropped (the
Cluster-GCN approximation, applied at round granularity — ``m == n_parts``
keeps every edge and is exact distributed full-graph training, while
``m == 1`` degenerates to the batched engine's per-partition subgraphs);
edges that cross partitions *within* a round are kept and serviced by a
halo exchange: before each aggregation, every device gathers the boundary
rows its round-mates need into a padded ``(m, H, F)`` send buffer and one
``jax.lax.all_to_all`` ships them, so each device only ever materializes
its own partition's activations plus an ``m*H``-row halo strip.

Everything here is **static**: :func:`build_halo_program` precomputes, on
the host, the per-partition padded node/edge tables (extended source
indices pointing into the halo strip) and the ``send_idx`` gather maps,
with one global halo width ``H`` (max boundary-set size over all ordered
partition pairs) so a single jitted step serves every round.

Padding is inert by the same construction as
:mod:`repro.graph.sampling`: pad feature rows are zero, pad edges carry
weight 0 and point at local node 0, pad send slots gather local row 0 but
no edge ever references the corresponding halo rows — forward values and
(scatter-add transposed) gradients are untouched.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.graph.data import Graph
from repro.graph.sampling import _bucket, bfs_partition, random_partition


def graph_mesh(n_parts: int):
    """1-D device mesh over the ``graph`` axis: the largest divisor of
    ``n_parts`` that fits this host's device count, so every round hosts
    the same number of partitions."""
    devs = jax.devices()
    m = max(k for k in range(1, min(n_parts, len(devs)) + 1)
            if n_parts % k == 0)
    return jax.sharding.Mesh(np.asarray(devs[:m]), ("graph",))


@dataclasses.dataclass
class HaloProgram:
    """Static per-round device tables for mesh-sharded training.

    Leading axes are ``(rounds, m, ...)``: round ``r``'s slice is
    device-sharded over the ``graph`` axis at run time.  ``features``
    stays a host-side numpy array — the feature pager
    (:class:`repro.offload.pager.FeaturePager`) owns its movement.
    """

    n_parts: int
    group: int                 # m — partitions co-resident per round
    rounds: int
    n_pad: int                 # padded nodes per partition (static)
    e_pad: int                 # padded edges per partition (static)
    halo: int                  # H — padded halo rows per (sender, receiver)
    part: np.ndarray           # (N,) global partition assignment
    features: np.ndarray       # (rounds, m, n_pad, F) f32 — host-resident
    labels: np.ndarray         # (rounds, m, n_pad) i32
    train_mask: np.ndarray     # (rounds, m, n_pad) f32 — owned real rows
    node_mask: np.ndarray      # (rounds, m, n_pad) f32 — real rows
    edge_src: np.ndarray       # (rounds, m, e_pad) i32 — extended indices
    edge_dst: np.ndarray       # (rounds, m, e_pad) i32 — local indices
    gcn_weight: np.ndarray     # (rounds, m, e_pad) f32
    mean_weight: np.ndarray    # (rounds, m, e_pad) f32
    send_idx: np.ndarray       # (rounds, m, m, H) i32 — sender-local rows
    n_real_nodes: np.ndarray   # (rounds, m) i32
    n_real_edges: np.ndarray   # (rounds, m) i32
    dropped_edges: int         # cross-round edges (the mesh approximation)
    halo_edges: int            # kept edges with a remote (in-round) source


def build_halo_program(g: Graph, n_parts: int, group: int, *,
                       method: str = "bfs", seed: int = 0,
                       node_multiple: int = 64,
                       edge_multiple: int = 256) -> HaloProgram:
    """Precompute the static mesh layout for ``g``.

    Partitioning reuses the batched engine's partitioners with the same
    seed, owned-node order (ascending global id), edge order (global),
    and pad buckets — so ``group == 1`` reproduces
    :func:`repro.graph.sampling.make_subgraph_batches` layouts exactly
    (the m=1 ≡ batched parity gate in ``tests/test_parallel.py``).
    """
    if n_parts % group:
        raise ValueError(f"n_parts={n_parts} must be a multiple of the "
                         f"graph-mesh size {group}")
    src = np.asarray(g.edge_src)
    dst = np.asarray(g.edge_dst)
    n = g.n_nodes
    if n_parts == 1:
        part = np.zeros(n, np.int64)
    elif method == "random":
        part = random_partition(n, n_parts, seed)
    elif method == "bfs":
        part = bfs_partition(src, dst, n, n_parts, seed)
    else:
        raise ValueError(f"unknown partition method {method!r}")
    rounds = n_parts // group

    owned = [np.flatnonzero(part == p) for p in range(n_parts)]
    n_pad = _bucket(max(len(o) for o in owned), node_multiple)
    loc = np.full(n, -1, np.int64)
    for o in owned:
        loc[o] = np.arange(len(o))

    ps, pd = part[src], part[dst]
    same_round = (ps // group) == (pd // group)
    dropped = int(np.sum(~same_round))

    # kept edges per destination partition, in global edge order
    kept = [np.flatnonzero((pd == p) & same_round) for p in range(n_parts)]
    e_pad = _bucket(max(len(k) for k in kept), edge_multiple)

    # boundary sets: needed[(q, p)] = sorted unique global nodes owned by
    # q that p's kept edges read.  H is the single static halo width.
    needed: dict[tuple[int, int], np.ndarray] = {}
    halo_edges = 0
    H = 0
    for p in range(n_parts):
        r = p // group
        es, eps = src[kept[p]], ps[kept[p]]
        for q in range(r * group, (r + 1) * group):
            if q == p:
                continue
            u = np.unique(es[eps == q])
            needed[(q, p)] = u
            halo_edges += int(np.sum(eps == q))
            H = max(H, len(u))

    F = g.n_feats
    feats = np.asarray(g.features)
    labels = np.asarray(g.labels)
    gcn_w = np.asarray(g.gcn_weight)
    mean_w = np.asarray(g.mean_weight)
    tr = np.asarray(g.train_mask)

    o_feats = np.zeros((rounds, group, n_pad, F), np.float32)
    o_labels = np.zeros((rounds, group, n_pad), np.int32)
    o_train = np.zeros((rounds, group, n_pad), np.float32)
    o_nmask = np.zeros((rounds, group, n_pad), np.float32)
    o_esrc = np.zeros((rounds, group, e_pad), np.int32)
    o_edst = np.zeros((rounds, group, e_pad), np.int32)
    o_gw = np.zeros((rounds, group, e_pad), np.float32)
    o_mw = np.zeros((rounds, group, e_pad), np.float32)
    o_send = np.zeros((rounds, group, group, H), np.int32)
    o_nreal = np.zeros((rounds, group), np.int32)
    o_ereal = np.zeros((rounds, group), np.int32)

    for p in range(n_parts):
        r, j = divmod(p, group)
        nodes = owned[p]
        nl = len(nodes)
        o_feats[r, j, :nl] = feats[nodes]
        o_labels[r, j, :nl] = labels[nodes]
        o_train[r, j, :nl] = tr[nodes].astype(np.float32)
        o_nmask[r, j, :nl] = 1.0
        o_nreal[r, j] = nl

        e = kept[p]
        el = len(e)
        es, ed, eps = src[e], dst[e], ps[e]
        s_loc = np.empty(el, np.int64)
        local = eps == p
        s_loc[local] = loc[es[local]]
        for i in range(group):
            q = r * group + i
            if q == p:
                continue
            sel = eps == q
            if not np.any(sel):
                continue
            # remote source u slots into the halo strip at the position u
            # holds in the (sorted) boundary set q ships to p
            s_loc[sel] = (n_pad + i * H
                          + np.searchsorted(needed[(q, p)], es[sel]))
        o_esrc[r, j, :el] = s_loc
        o_edst[r, j, :el] = loc[ed]
        o_gw[r, j, :el] = gcn_w[e]
        o_mw[r, j, :el] = mean_w[e]
        o_ereal[r, j] = el

    # send maps: device i's rows for peer j are the boundary set of
    # (q = r*m + i → p = r*m + j), zero-padded to H (pad slots gather row
    # 0; the receiver's edges never index them)
    for (q, p), u in needed.items():
        r, i = divmod(q, group)
        j = p % group
        o_send[r, i, j, :len(u)] = loc[u]

    return HaloProgram(
        n_parts=n_parts, group=group, rounds=rounds, n_pad=n_pad,
        e_pad=e_pad, halo=H, part=part, features=o_feats, labels=o_labels,
        train_mask=o_train, node_mask=o_nmask, edge_src=o_esrc,
        edge_dst=o_edst, gcn_weight=o_gw, mean_weight=o_mw, send_idx=o_send,
        n_real_nodes=o_nreal, n_real_edges=o_ereal, dropped_edges=dropped,
        halo_edges=halo_edges)


def halo_exchange(h, send_idx, axis: str | None = "graph"):
    """Ship boundary rows between the round's co-resident partitions.

    ``h`` is this device's ``(n_pad, F)`` activation block inside a
    ``shard_map`` over ``axis``; ``send_idx`` is its ``(m, H)`` gather map
    (row ``i`` = the local rows peer ``i`` needs).  Returns the extended
    ``(n_pad + m*H, F)`` block whose halo strip holds, at
    ``n_pad + i*H + s``, row ``s`` of the boundary set partition ``i``
    ships here — exactly where :func:`build_halo_program` pointed the
    extended edge sources.

    ``all_to_all(split_axis=0, concat_axis=0, tiled=True)`` sends chunk
    ``i`` of the ``(m, H, F)`` send buffer to device ``i`` and concatenates
    what everyone sent *here*, so on device ``j`` the received chunk ``i``
    is ``h_i[send_idx_i[j]]``.  A pure permutation collective: its VJP is
    the inverse all_to_all, and the gather's VJP is a scatter-add, so the
    exchange is exactly differentiable.  ``H == 0`` (no cross-partition
    edges) and ``axis is None`` (single-device lowering) are identities.
    """
    m, H = send_idx.shape
    if H == 0 or axis is None or m == 1:
        return h
    f = h.shape[1]
    sb = h[send_idx.reshape(-1)].reshape(m, H, f)
    recv = jax.lax.all_to_all(sb, axis, split_axis=0, concat_axis=0,
                              tiled=True)
    return jnp.concatenate([h, recv.reshape(m * H, f)], axis=0)


def exchange_widths(arch: str, dims) -> tuple[int, ...]:
    """Per-layer halo-exchange row widths.

    GCN aggregates *after* the linear, so the exchanged tensor is the
    biased pre-aggregation output (``d_out`` wide); SAGE aggregates the
    layer *input*, so it exchanges ``h`` (``d_in`` wide).
    """
    dims = list(dims)
    return tuple(dims[1:]) if arch == "gcn" else tuple(dims[:-1])


def halo_bytes_per_round(prog: HaloProgram, widths) -> int:
    """f32 bytes crossing the mesh in ONE round (send side, all devices):
    each of the ``m`` devices ships an ``(m, H, width)`` buffer per
    layer.  This is the per-round granularity the obs metrics registry
    counts (``halo/bytes``); :func:`halo_bytes_per_epoch` is its
    ``rounds``-multiple."""
    if prog.halo == 0:
        return 0
    per_layer = prog.group * prog.group * prog.halo * 4
    return int(per_layer * sum(widths))


def halo_bytes_per_epoch(prog: HaloProgram, widths) -> int:
    """f32 bytes crossing the mesh per epoch (send side, all devices)."""
    return prog.rounds * halo_bytes_per_round(prog, widths)
