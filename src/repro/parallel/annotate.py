"""Logical-axis activation sharding annotations.

GSPMD's sharding propagation does not reliably survive ``lax.scan`` carries
(observed: fully replicated attention in the layer scan), so — as in
MaxText/Megatron-JAX practice — the model code annotates its major
intermediates with *logical* axes which are resolved against the active
mesh via rules installed by the launcher.  With no rules installed (unit
tests, single-device runs) ``shard()`` is a no-op.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

_RULES: dict = {}


def set_rules(**mapping):
    """e.g. set_rules(batch=("data",), heads="model", dff="model", ...)."""
    global _RULES
    _RULES = dict(mapping)


def rules_for(cfg, mesh, per_step_batch: int, *, is_train: bool = True):
    """Standard rule set for an ArchConfig on a mesh (DESIGN.md §6).

    ``is_train``: gradient accumulation divides the per-step batch into
    microbatches only on the training path; prefill/decode see the full
    batch."""
    msz = mesh.shape.get("model", 1)
    dp = ("pod", "data") if "pod" in mesh.shape else ("data",)
    dp_total = 1
    for a in dp:
        dp_total *= mesh.shape[a]
    micro = (per_step_batch // max(cfg.grad_accum, 1) if is_train
             else per_step_batch)
    d_inner = cfg.ssm_expand * cfg.d_model
    heads_ok = cfg.n_heads % msz == 0
    return dict(
        batch=dp if micro % dp_total == 0 else None,
        heads="model" if heads_ok else None,
        # context-parallel fallback: when heads don't divide the TP axis,
        # shard the QUERY sequence over `model` (k/v all-gathered) instead
        # of replicating attention 16x (beyond-paper sharding fix, §Perf)
        q_seq=None if heads_ok else "model",
        kv_heads="model" if cfg.n_kv_heads % msz == 0 else None,
        # flattened projection out-dims: shardable whenever divisible, even
        # when the head count itself is not (reshard happens at the reshape)
        attn_out="model" if (cfg.n_heads * cfg.d_head) % msz == 0 else None,
        kv_out="model" if (cfg.n_kv_heads * cfg.d_head) % msz == 0 else None,
        dff="model" if cfg.d_ff % msz == 0 and cfg.d_ff else None,
        experts="model" if cfg.n_experts % msz == 0 and cfg.n_experts else None,
        vocab="model" if cfg.vocab % msz == 0 else None,
        ssm_heads="model" if (d_inner // max(cfg.ssm_headdim, 1)) % msz == 0
        else None,
        cache_seq="model",
        embed=None,
    )


def shard(x, *axes):
    """Constrain ``x`` to the logical spec; no-op without installed rules."""
    if not _RULES:
        return x
    spec = []
    for a in axes:
        r = _RULES.get(a) if a is not None else None
        spec.append(r)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x  # outside a mesh context
