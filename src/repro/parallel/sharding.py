"""Sharding rules: TP (heads/d_ff/experts over ``model``) + FSDP (params
over ``data``) + DP (batch over ``pod``×``data``) + sequence-sharded KV for
long-context decode.  See DESIGN.md §6 for the full table.

Divisibility policy: a dim shards over an axis only if it divides evenly;
otherwise that dim stays replicated (e.g. 20-head or 56-head attention on a
16-way model axis falls back to replicated attention weights — FSDP still
shards them over ``data``).  Vocab dims likewise (92553, 256206, 50280 are
odd-sized and stay unsharded on ``model``).
"""
from __future__ import annotations

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


def _axis_size(mesh, name) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def dp_axes(mesh):
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def dp_size(mesh) -> int:
    """Total data-parallel degree (product of the DP axis sizes)."""
    total = 1
    for a in dp_axes(mesh):
        total *= _axis_size(mesh, a)
    return total


def graph_batch_pspecs(batch, mesh, axis: int = 0):
    """PartitionSpecs for a stacked ``SubgraphBatch`` pytree: shard the
    device-group axis ``axis`` over the DP mesh axes, replicate everything
    else (node/edge tables are per-batch local, params stay replicated —
    plain data parallelism over subgraph batches).

    Leaves whose ``axis`` dim doesn't divide the DP degree (or that have no
    such dim) stay replicated, mirroring the divisibility policy of
    :func:`batch_pspecs`.
    """
    total = dp_size(mesh)

    def rule(leaf):
        spec = [None] * leaf.ndim
        if leaf.ndim > axis and leaf.shape[axis] % total == 0:
            spec[axis] = dp_axes(mesh)
        return P(*spec)

    return jax.tree.map(rule, batch)


def _div(n: int, mesh, axis: str) -> bool:
    return n % _axis_size(mesh, axis) == 0


def param_pspecs(cfg, params_shape, mesh):
    """PartitionSpec pytree matching the params pytree (shape structs)."""
    msz = _axis_size(mesh, "model")
    dsz = _axis_size(mesh, "data")
    heads_ok = cfg.n_heads % msz == 0
    kv_ok = cfg.n_kv_heads % msz == 0

    def fsdp(dim: int):
        return "data" if dim % dsz == 0 else None

    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1]
        stacked = any(n in ("layers", "enc_layers") for n in names)
        shape = leaf.shape[1:] if stacked else leaf.shape
        shared = "shared_attn" in names

        def out(*spec):
            spec = list(spec) + [None] * (len(shape) - len(spec))
            if stacked:
                spec = [None] + spec
            return P(*spec)

        if name == "embed":
            return out("model" if _div(shape[0], mesh, "model") else None,
                       fsdp(shape[1]))
        if name == "lm_head":
            return out(fsdp(shape[0]),
                       "model" if _div(shape[1], mesh, "model") else None)
        if name in ("wq", "wk", "wv"):
            # flattened out-dim sharding (divisibility, not head count)
            return out(fsdp(shape[0]),
                       "model" if _div(shape[1], mesh, "model") else None)
        if name == "wo":
            return out("model" if _div(shape[0], mesh, "model") else None,
                       fsdp(shape[1]))
        if name in ("w_gate", "w_up"):
            if len(shape) == 3:                      # MoE experts (E, D, F)
                return out("model" if _div(shape[0], mesh, "model") else None,
                           fsdp(shape[1]), None)
            return out(fsdp(shape[0]),
                       "model" if _div(shape[1], mesh, "model") else None)
        if name == "w_down":
            if len(shape) == 3:                      # (E, F, D)
                return out("model" if _div(shape[0], mesh, "model") else None,
                           None, fsdp(shape[2]))
            return out("model" if _div(shape[0], mesh, "model") else None,
                       fsdp(shape[1]))
        if name == "router":
            return out(fsdp(shape[0]), None)
        if name in ("w_z", "w_x"):
            return out(fsdp(shape[0]),
                       "model" if _div(shape[1], mesh, "model") else None)
        if name == "w_dt":
            return out(fsdp(shape[0]),
                       "model" if _div(shape[1], mesh, "model") else None)
        if name in ("w_B", "w_C"):
            return out(fsdp(shape[0]), None)
        if name == "conv_x":
            return out(None, "model" if _div(shape[1], mesh, "model") else None)
        if name in ("out_proj", "down"):
            return out("model" if _div(shape[0], mesh, "model") else None,
                       fsdp(shape[1]))
        return out()  # norms, biases, scalars: replicated

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def batch_pspecs(cfg, shape_kind: str, mesh, batch: int):
    """Input-batch PartitionSpecs for train/prefill steps."""
    dp = dp_axes(mesh)
    dp_total = 1
    for a in dp:
        dp_total *= _axis_size(mesh, a)
    bspec = dp if batch % dp_total == 0 else None
    spec = {"tokens": P(bspec, None)}
    if cfg.family == "encdec":
        spec["enc_embeds"] = P(bspec, None, None)
    if cfg.frontend == "vision":
        spec["prefix_embeds"] = P(bspec, None, None)
    return spec


def cache_pspecs(cfg, cache_shape, mesh, batch: int, seq: int):
    """Decode-cache PartitionSpecs.

    batch >= dp → batch over (pod, data), cache seq over model.
    batch == 1 (long-context) → cache seq over (data, model); SSM state
    heads over model.
    """
    dp = dp_axes(mesh)
    msz = _axis_size(mesh, "model")
    dp_total = 1
    for a in dp:
        dp_total *= _axis_size(mesh, a)
    big_batch = batch % dp_total == 0
    bspec = dp if big_batch else None
    seq_axes = "model" if big_batch else (*dp, "model")

    def rule(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        name = names[-1]
        if name == "pos":
            return P(None)
        if name in ("k", "v", "shared_k", "shared_v"):
            # (L|ns, B, S, Hkv, Dh)
            s_ok = leaf.shape[2] % (msz * (1 if big_batch else dp_total)) == 0
            return P(None, bspec, seq_axes if s_ok else None, None, None)
        if name == "enc":
            return P(bspec, None, None)
        if name == "conv":
            return P(None, bspec, None, None)
        if name == "ssd":
            # (L, B, H, P, N)
            h_ok = leaf.shape[2] % msz == 0
            return P(None, bspec, "model" if h_ok else None, None, None)
        return P()

    return jax.tree_util.tree_map_with_path(rule, cache_shape)


def to_named(pspec_tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), pspec_tree,
        is_leaf=lambda x: isinstance(x, P))
