"""Block-wise-quantized paged KV cache for the continuous-batching engine.

The decode KV cache is carved into fixed-size **pages** of
``page_tokens`` tokens; each slot's logical sequence maps to physical
pages through a per-slot page table (``layout.null_page`` marks
unallocated entries — one past the pool end, so in-jit scatters drop and
gathers fill zeros instead of corrupting page 0).  Every token's
(Hkv·Dh)-element K and V rows are quantized per ``group_size`` block
through the paper's quantize/pack path (:mod:`repro.core.backend`) as
they are written, so the pool holds packed uint32 codes plus per-block
(zero, range) f32 stats — raw-f32 KV for inactive pages never resides in
device memory.  ``bits=16`` stores raw bf16 pages instead (the
uncompressed baseline; bit-identical to the legacy dense cache).

Page layout per (layer, physical page), one of the two K/V streams::

    quantized:  packed (page_tokens, blocks_per_token, words_per_block) u32
                zero/rng (page_tokens, blocks_per_token) f32
    raw bf16:   (page_tokens, n_kv_heads, d_head)

Block boundaries never straddle tokens: the effective group is
``min(group_size, Hkv*Dh)`` and must divide the token row exactly, so a
single-token decode write touches whole blocks only.

Placement reuses the offload policies (``device`` / ``host`` /
``pinned-paged``): where the platform exposes a distinct host memory
space the pools are ``device_put`` with that memory kind; on CPU the
default memory *is* host, so the pool stays put and the resolved
mechanism records the honest fallback.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.core import pack as packmod
from repro.engine import seeds as seedsmod

#: Supported KV cache widths: 2/4/8 quantized, 16 = raw bf16 pages.
KV_BITS = (2, 4, 8, 16)


@dataclasses.dataclass(frozen=True)
class KVCacheConfig:
    """User-facing knobs for the paged KV cache."""
    bits: int = 8
    group_size: int = 64
    policy: str = "device"
    page_tokens: int = 16
    n_pages: int = 64


@dataclasses.dataclass(frozen=True)
class KVPageLayout:
    """Resolved page-pool geometry (validated by :func:`plan_kv_layout`;
    constructing directly skips validation — what the staticcheck
    kv-geometry rule exists to catch)."""
    n_layers: int
    n_kv_heads: int
    d_head: int
    bits: int
    group_size: int      # effective per-token quant group
    page_tokens: int
    n_pages: int
    policy: str = "device"

    # ------------------------------------------------------------ geometry
    @property
    def quantized(self) -> bool:
        return self.bits < 16

    @property
    def elems_per_token(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def blocks_per_token(self) -> int:
        return self.elems_per_token // self.group_size

    @property
    def words_per_block(self) -> int:
        return packmod.packed_len(self.group_size, self.bits) \
            if self.quantized else 0

    @property
    def words_per_page(self) -> int:
        """uint32 words of one page's packed-code (or raw bf16) stream."""
        if self.quantized:
            return self.page_tokens * self.blocks_per_token \
                * self.words_per_block
        return self.page_tokens * self.elems_per_token * 2 // 4

    @property
    def null_page(self) -> int:
        """Sentinel page id for unallocated table entries: one past the
        pool end, so scatters ``mode="drop"`` and gathers ``mode="fill"``."""
        return self.n_pages

    # --------------------------------------------------------------- bytes
    @property
    def page_bytes(self) -> int:
        """Stored bytes of one page, both K and V streams."""
        per = self.words_per_page * 4
        if self.quantized:
            per += self.page_tokens * self.blocks_per_token * 8  # zero+rng
        return 2 * per

    @property
    def pool_bytes(self) -> int:
        return self.n_layers * self.n_pages * self.page_bytes

    @property
    def f32_page_bytes(self) -> int:
        """The same page capacity stored as uncompressed f32 K+V."""
        return 2 * self.page_tokens * self.elems_per_token * 4

    @property
    def f32_pool_bytes(self) -> int:
        return self.n_layers * self.n_pages * self.f32_page_bytes

    @property
    def total_words(self) -> int:
        return self.n_layers * self.n_pages * self.words_per_page

    def page_segments(self):
        """Flat-word-space segments of every (layer, page) in one packed
        stream — what the staticcheck kv-page rule proves in-bounds,
        non-overlapping, and geometry-consistent."""
        for li in range(self.n_layers):
            for p in range(self.n_pages):
                off = (li * self.n_pages + p) * self.words_per_page
                yield li, p, off, self.words_per_page


def plan_kv_layout(kv: KVCacheConfig, *, n_layers: int, n_kv_heads: int,
                   d_head: int) -> KVPageLayout:
    """Validate a :class:`KVCacheConfig` against the model's KV row and
    resolve the page geometry."""
    from repro.offload.engine import check_policy

    check_policy(kv.policy)
    if kv.bits not in KV_BITS:
        raise ValueError(f"kv bits={kv.bits} not in {KV_BITS}")
    if kv.page_tokens < 1:
        raise ValueError(f"page_tokens={kv.page_tokens} must be >= 1")
    if kv.n_pages < 1:
        raise ValueError(f"n_pages={kv.n_pages} must be >= 1")
    elems = n_kv_heads * d_head
    g = min(kv.group_size, elems)
    if g < 1 or elems % g:
        raise ValueError(
            f"group_size={kv.group_size} (effective {g}) must divide the "
            f"{elems}-element KV token row (Hkv={n_kv_heads} x Dh={d_head}) "
            "so quant blocks never straddle tokens")
    if kv.bits < 16:
        reason = backend.quant_kernel_unsupported(kv.bits, g, None)
        if reason is not None:
            raise ValueError(f"kv cache quantization infeasible: {reason}")
    return KVPageLayout(n_layers=n_layers, n_kv_heads=n_kv_heads,
                        d_head=d_head, bits=kv.bits, group_size=g,
                        page_tokens=kv.page_tokens, n_pages=kv.n_pages,
                        policy=kv.policy)


# ================================================================= pools
def init_kv_pool(layout: KVPageLayout) -> dict:
    """Zero-initialized page pool; arrays carry a leading layer axis so
    the decode step scans them alongside the stacked layer params."""
    L, P, T = layout.n_layers, layout.n_pages, layout.page_tokens
    if not layout.quantized:
        kv_shape = (L, P, T, layout.n_kv_heads, layout.d_head)
        return {"k": jnp.zeros(kv_shape, jnp.bfloat16),
                "v": jnp.zeros(kv_shape, jnp.bfloat16)}
    nbt, wpb = layout.blocks_per_token, layout.words_per_block
    pool = {}
    for name in ("k", "v"):
        pool[f"{name}_packed"] = jnp.zeros((L, P, T, nbt, wpb), jnp.uint32)
        pool[f"{name}_zero"] = jnp.zeros((L, P, T, nbt), jnp.float32)
        pool[f"{name}_rng"] = jnp.zeros((L, P, T, nbt), jnp.float32)
    return pool


def place_kv_pool(pool: dict, layout: KVPageLayout) -> tuple[dict, str]:
    """Place the pool per the layout's policy, returning the resolved
    mechanism.  Steady-state memkind residency across jitted decode steps
    needs out-sharding threading (accelerator follow-up); this records
    the initial placement honestly."""
    from repro.offload.engine import check_policy, host_memory_kind

    check_policy(layout.policy)
    if layout.policy == "device":
        return pool, "device"
    kind = host_memory_kind(layout.policy)
    if kind is None:
        return pool, "device-fallback"
    dev = jax.devices()[0]
    sh = jax.sharding.SingleDeviceSharding(dev, memory_kind=kind)
    return {k: jax.device_put(a, sh) for k, a in pool.items()}, \
        f"memkind:{kind}"


# ================================================================ writes
def write_token(pool_l: dict, layout: KVPageLayout, page_table, pos, active,
                k_tok, v_tok, seed_k, seed_v) -> dict:
    """Write one decode token's K/V rows into their page (one layer).

    k_tok/v_tok (B, Hkv, Dh); pos (B,) absolute positions; page_table
    (B, max_pages) physical ids; inactive slots scatter out of bounds
    (dropped).  Quantized pools stochastically round per block with the
    per-(pos, slot, layer, field) seeds the caller derived via
    :func:`repro.engine.seeds.kv_seed`.
    """
    T = layout.page_tokens
    off = pos % T
    phys = jnp.take_along_axis(page_table, (pos // T)[:, None], axis=1)[:, 0]
    phys = jnp.where(active, phys, layout.null_page)
    out = dict(pool_l)
    if not layout.quantized:
        for name, t in (("k", k_tok), ("v", v_tok)):
            out[name] = pool_l[name].at[phys, off].set(
                t.astype(pool_l[name].dtype), mode="drop")
        return out
    nbt, g = layout.blocks_per_token, layout.group_size
    for name, t, seed in (("k", k_tok, seed_k), ("v", v_tok, seed_v)):
        blocks = t.astype(jnp.float32).reshape(t.shape[0], nbt, g)
        packed, zero, rng = jax.vmap(
            lambda bl, sd: backend.quantize_blocks(
                bl, layout.bits, sd, impl="jnp"))(blocks, seed)
        for suffix, val in (("packed", packed), ("zero", zero), ("rng", rng)):
            key = f"{name}_{suffix}"
            out[key] = pool_l[key].at[phys, off].set(val, mode="drop")
    return out


def write_prompt(pool: dict, layout: KVPageLayout, k, v, phys_pages,
                 slots) -> dict:
    """Scatter a prefill's KV rows into freshly allocated pages.

    k/v (L, B, S, Hkv, Dh) from ``Model.prefill`` with ``max_seq`` padded
    to a page multiple (S % page_tokens == 0); phys_pages (B, S//T)
    physical page ids per slot; slots (B,) slot indices (seed stream).
    This IS the compressed prompt-context stash: the prompt's KV enters
    the arena-pooled pages through the same quantize/pack path decode
    writes use, seeded by position through the seeds module.
    """
    L, B, S = k.shape[0], k.shape[1], k.shape[2]
    T = layout.page_tokens
    assert S % T == 0, (S, T)
    npg = S // T
    positions = jnp.arange(S)
    nbt, g = layout.blocks_per_token, layout.group_size
    hkv, dh = layout.n_kv_heads, layout.d_head

    def body(carry, xs):
        pool_l, k_l, v_l, li = xs
        out = dict(pool_l)
        if not layout.quantized:
            for name, t in (("k", k_l), ("v", v_l)):
                paged = t.astype(out[name].dtype).reshape(B, npg, T, hkv, dh)
                out[name] = out[name].at[phys_pages].set(paged, mode="drop")
            return carry, out
        for field, (name, t) in enumerate((("k", k_l), ("v", v_l))):
            seeds = seedsmod.kv_seed(positions[None, :], slots[:, None],
                                     li, field)               # (B, S)
            blocks = t.astype(jnp.float32).reshape(B, S, nbt, g)
            packed, zero, rng = jax.vmap(jax.vmap(
                lambda bl, sd: backend.quantize_blocks(
                    bl, layout.bits, sd, impl="jnp")))(blocks, seeds)
            wpb = layout.words_per_block
            for suffix, val, tail in (("packed", packed, (nbt, wpb)),
                                      ("zero", zero, (nbt,)),
                                      ("rng", rng, (nbt,))):
                key = f"{name}_{suffix}"
                out[key] = out[key].at[phys_pages].set(
                    val.reshape(B, npg, T, *tail), mode="drop")
        return carry, out

    _, new_pool = jax.lax.scan(
        body, None, (pool, k, v, jnp.arange(L, dtype=jnp.uint32)))
    return new_pool


# ================================================================= reads
def gather_kv_raw(pool_l: dict, layout: KVPageLayout, page_table):
    """bits=16 read path: gather a slot's pages into the dense
    (B, max_pages*T, Hkv, Dh) f32 window the legacy decode attends over
    (unallocated pages fill zeros — identical to the dense cache's
    padding, which is what makes the raw engine bit-identical)."""
    B, maxp = page_table.shape
    outs = []
    for name in ("k", "v"):
        pages = jnp.take(pool_l[name], page_table, axis=0,
                         mode="fill", fill_value=0)
        outs.append(pages.reshape(B, maxp * layout.page_tokens,
                                  layout.n_kv_heads, layout.d_head
                                  ).astype(jnp.float32))
    return outs[0], outs[1]


def make_page_fetch(pool_l: dict, layout: KVPageLayout, page_table):
    """Quantized read path: a ``fetch(j)`` closure for
    :func:`repro.models.attention.decode_attend_paged` that gathers and
    dequantizes exactly one page per online-softmax iteration."""
    B = page_table.shape[0]
    T, nbt = layout.page_tokens, layout.blocks_per_token
    wpb, g = layout.words_per_block, layout.group_size

    def fetch(j):
        phys = jax.lax.dynamic_index_in_dim(page_table, j, axis=1,
                                            keepdims=False)    # (B,)
        outs = []
        for name in ("k", "v"):
            pk = jnp.take(pool_l[f"{name}_packed"], phys, axis=0,
                          mode="fill", fill_value=0)
            pz = jnp.take(pool_l[f"{name}_zero"], phys, axis=0,
                          mode="fill", fill_value=0.0)
            pr = jnp.take(pool_l[f"{name}_rng"], phys, axis=0,
                          mode="fill", fill_value=0.0)
            blocks = backend.dequantize_blocks(
                pk.reshape(B * T * nbt, wpb), pz.reshape(-1),
                pr.reshape(-1), layout.bits, g, impl="jnp")
            outs.append(blocks.reshape(B, T, layout.n_kv_heads,
                                       layout.d_head))
        kv_pos = j * T + jnp.arange(T)
        return outs[0], outs[1], kv_pos

    return fetch


# ============================================================= allocator
class PageAllocator:
    """Host-side free-list allocator over the physical page pool.

    Deterministic: pages hand out in ascending id order and freed pages
    return to the tail, so identical admission traces replay to identical
    page tables.  Bounds and double-free are hard errors — the geometry
    invariants the serving tests pin."""

    def __init__(self, n_pages: int):
        if n_pages < 1:
            raise ValueError(f"n_pages={n_pages} must be >= 1")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))
        self._used: set[int] = set()

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._used)

    def alloc(self, n: int) -> list[int] | None:
        """n physical pages, or None when the pool cannot satisfy them
        (the scheduler's signal to hold admission)."""
        if n < 0:
            raise ValueError(f"cannot allocate {n} pages")
        if n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        self._used.update(pages)
        return pages

    def free(self, pages) -> None:
        for p in pages:
            if not 0 <= p < self.n_pages:
                raise ValueError(
                    f"page id {p} outside the [0, {self.n_pages}) pool")
            if p not in self._used:
                raise ValueError(f"double free of page {p}")
            self._used.remove(p)
            self._free.append(p)
