"""Continuous-batching serving engine over a block-wise-quantized paged
KV cache (ISSUE 10): scheduler + paged pool + jitted decode/prefill.
"""
from repro.serving.engine import (KV_FAMILIES, RequestResult, ServeEngine,
                                  make_decode_fn, make_prefill_fn)
from repro.serving.kvcache import (KV_BITS, KVCacheConfig, KVPageLayout,
                                   PageAllocator, plan_kv_layout)
from repro.serving.scheduler import MODES, Request, Scheduler, SlotState

__all__ = [
    "KV_BITS", "KV_FAMILIES", "KVCacheConfig", "KVPageLayout", "MODES",
    "PageAllocator", "Request", "RequestResult", "Scheduler", "ServeEngine",
    "SlotState", "make_decode_fn", "make_prefill_fn", "plan_kv_layout",
]
