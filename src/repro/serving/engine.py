"""ServeEngine: continuous-batching greedy decode over the paged,
block-quantized KV cache.

One jitted decode step serves every occupied slot at once (static
``max_batch`` shapes, so it compiles exactly once per engine): embed the
slots' last tokens, scan the layer stack writing each new KV row into
its page — quantized through the paper's block-wise SR path for
``bits<16`` — and attend either through the chunked online-softmax paged
read (:func:`repro.models.attention.decode_attend_paged`, one page
dequantized per iteration) or the dense gather
(:func:`~repro.models.attention.decode_attend`, bits=16 raw pages,
bit-identical to the legacy cache).  Generated tokens accumulate in a
preallocated device-side ``(max_batch, gen_cap)`` buffer; the host
transfers a request's row **once**, on completion — no per-token
``np.asarray`` round trip in the timed loop.

Prefill runs per admission group (same-length prompts batch together),
writes the prompt's KV into the freshly reserved pages via
:func:`repro.serving.kvcache.write_prompt` (the compressed prompt-context
stash), and seats the slot state device-side.  Host-side bookkeeping
(scheduler mirrors, page tables) advances deterministically without
device syncs.

Observability: queue depth / batch occupancy / page residency and
per-request TTFT/TPOT histograms stream into a
:class:`repro.obs.session.ObsSession` built from the caller's
``ObsPolicy``; the run summary always carries the derived percentiles,
obs on or off.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.seeds import kv_seed
from repro.models import attention as attn
from repro.models import moe as moemod
from repro.models.layers import rmsnorm, swiglu
from repro.obs.session import ObsSession
from repro.serving import kvcache
from repro.serving.kvcache import KVCacheConfig, plan_kv_layout
from repro.serving.scheduler import MODES, Request, Scheduler

#: Families the paged KV cache serves (attention KV caches); SSM/hybrid
#: state caches decode through the legacy loop in ``launch.serve``.
KV_FAMILIES = ("dense", "vlm", "moe")


@dataclasses.dataclass
class RequestResult:
    rid: int
    status: str                      # "done" | "rejected"
    tokens: np.ndarray | None = None
    reason: str = ""
    ttft_s: float = 0.0
    tpot_s: float = 0.0
    latency_s: float = 0.0


def make_decode_fn(model, layout, *, gen_cap: int, collect_logits: bool):
    """Build the jitted one-token step for every slot: (params, pool,
    page_table, state) -> (pool, state).  Mirrors ``Model.decode_step``'s
    layer math exactly — only the KV storage differs."""
    cfg = model.cfg

    def step(params, pool, page_table, state):
        tokens, pos, active = state["tokens"], state["pos"], state["active"]
        B = tokens.shape[0]
        slot_ids = jnp.arange(B)
        h = jnp.take(params["embed"], tokens, axis=0)

        def body(carry, xs):
            hh = carry
            lp, pool_l, li = xs
            x = rmsnorm(hh, lp["ln1"])
            q, k, v = attn.qkv_project(x, lp["attn"], cfg, pos[:, None])
            seed_k = kv_seed(pos, slot_ids, li, 0)
            seed_v = kv_seed(pos, slot_ids, li, 1)
            pool_l = kvcache.write_token(pool_l, layout, page_table, pos,
                                         active, k[:, 0], v[:, 0],
                                         seed_k, seed_v)
            if layout.quantized:
                fetch = kvcache.make_page_fetch(pool_l, layout, page_table)
                a = attn.decode_attend_paged(
                    q, pos, page_table.shape[1], fetch,
                    n_kv_heads=cfg.n_kv_heads, out_dtype=x.dtype)
            else:
                kf, vf = kvcache.gather_kv_raw(pool_l, layout, page_table)
                a = attn.decode_attend(q, kf, vf, pos, out_dtype=x.dtype)
            hh = hh + a @ lp["attn"]["wo"]
            if cfg.family == "moe":
                if cfg.dense_residual:
                    m = lp["mlp"]
                    hh = hh + swiglu(rmsnorm(hh, lp["ln3"]), m["w_gate"],
                                     m["w_up"], m["w_down"])
                y, _ = moemod.moe_ffn(rmsnorm(hh, lp["ln2"]), lp["moe"],
                                      n_experts=cfg.n_experts,
                                      top_k=cfg.top_k,
                                      capacity_factor=cfg.moe_capacity_factor)
                hh = hh + y
            else:
                m = lp["mlp"]
                hh = hh + swiglu(rmsnorm(hh, lp["ln2"]), m["w_gate"],
                                 m["w_up"], m["w_down"])
            return hh, pool_l

        h, pool = jax.lax.scan(
            body, h, (params["layers"], pool,
                      jnp.arange(cfg.n_layers, dtype=jnp.uint32)))
        h = rmsnorm(h, params["final_norm"])
        logits = (h @ params["lm_head"]).astype(jnp.float32)[:, -1]  # (B,V)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = state["gen"]
        row = jnp.arange(B)
        col = jnp.where(active, gen, gen_cap)          # gen_cap → dropped
        new = dict(state)
        new["out"] = state["out"].at[row, col].set(next_tok, mode="drop")
        new["tokens"] = jnp.where(active[:, None], next_tok[:, None], tokens)
        new["pos"] = pos + active.astype(pos.dtype)
        new["gen"] = gen + active.astype(gen.dtype)
        new["active"] = active & (new["gen"] < state["target"])
        if collect_logits:
            new["logits"] = state["logits"].at[row, col].set(
                logits, mode="drop")
        return pool, new

    return step


def make_prefill_fn(model, layout, *, collect_logits: bool):
    """Build the jitted admission step: prefill a same-length prompt
    group, stash its KV into the reserved pages, seat the slots."""

    def prefill(params, pool, state, prompts, phys_pages, slots, targets):
        S = prompts.shape[1]
        T = layout.page_tokens
        pad = phys_pages.shape[1] * T           # prompt pages, page-aligned
        logits, cache = model.prefill(params, prompts, max_seq=pad)
        pool = kvcache.write_prompt(pool, layout, cache["k"], cache["v"],
                                    phys_pages, slots)
        tok0 = jnp.argmax(logits, -1).astype(jnp.int32)          # (n,)
        new = dict(state)
        new["tokens"] = state["tokens"].at[slots, 0].set(tok0)
        new["pos"] = state["pos"].at[slots].set(S)
        new["active"] = state["active"].at[slots].set(True)
        new["target"] = state["target"].at[slots].set(targets)
        new["out"] = state["out"].at[slots, 0].set(tok0)
        new["gen"] = state["gen"].at[slots].set(1)
        if collect_logits:
            new["logits"] = state["logits"].at[slots, 0].set(
                logits.astype(jnp.float32))
        return pool, new

    return prefill


class ServeEngine:
    """Continuous-batching serving engine over the paged KV cache.

    ``mode="fixed"`` turns the same machinery into the legacy sequential
    fixed-batch loop (admission barriers, see
    :class:`repro.serving.scheduler.Scheduler`).
    """

    def __init__(self, model, params, *, kv: KVCacheConfig | None = None,
                 max_batch: int = 4, max_queue: int = 64,
                 max_prompt: int = 64, gen_cap: int = 64,
                 mode: str = "continuous", obs=None,
                 collect_logits: bool = False):
        cfg = model.cfg
        if cfg.family not in KV_FAMILIES:
            raise ValueError(
                f"paged-KV serving covers the attention-cache families "
                f"{KV_FAMILIES}; family={cfg.family!r} decodes through the "
                "legacy loop in launch.serve")
        if mode not in MODES:
            raise ValueError(f"mode={mode!r} not in {MODES}")
        self.model, self.params, self.mode = model, params, mode
        kv = kv or KVCacheConfig()
        self.layout = plan_kv_layout(kv, n_layers=cfg.n_layers,
                                     n_kv_heads=cfg.n_kv_heads,
                                     d_head=cfg.d_head)
        T = self.layout.page_tokens
        self.max_prompt, self.gen_cap = max_prompt, gen_cap
        self.max_pages_per_slot = -(-(max_prompt + gen_cap - 1) // T)
        self.max_batch = max_batch
        self.collect_logits = collect_logits
        self.session = obs if isinstance(obs, ObsSession) \
            else ObsSession.from_policy(obs)
        pool = kvcache.init_kv_pool(self.layout)
        self.pool, self.mechanism = kvcache.place_kv_pool(pool, self.layout)
        self.alloc = kvcache.PageAllocator(kv.n_pages)
        self.sched = Scheduler(max_batch=max_batch, page_tokens=T,
                               allocator=self.alloc, mode=mode,
                               max_queue=max_queue, max_prompt=max_prompt,
                               max_new_cap=gen_cap)
        self._decode = jax.jit(
            make_decode_fn(model, self.layout, gen_cap=gen_cap,
                           collect_logits=collect_logits),
            donate_argnums=(1, 3))
        self._prefill = jax.jit(
            make_prefill_fn(model, self.layout,
                            collect_logits=collect_logits),
            donate_argnums=(1, 2))

    # ------------------------------------------------------------ plumbing
    def _init_state(self) -> dict:
        B, G = self.max_batch, self.gen_cap
        st = {"tokens": jnp.zeros((B, 1), jnp.int32),
              "pos": jnp.zeros((B,), jnp.int32),
              "active": jnp.zeros((B,), bool),
              "target": jnp.zeros((B,), jnp.int32),
              "out": jnp.zeros((B, G), jnp.int32),
              "gen": jnp.zeros((B,), jnp.int32)}
        if self.collect_logits:
            st["logits"] = jnp.zeros((B, G, self.model.cfg.vocab),
                                     jnp.float32)
        return st

    def _admit_group(self, group, state, page_table_np):
        """Prefill one same-prompt-length admission group and seat it."""
        m = self.session
        S = group[0][1].prompt.shape[0]
        npg_prompt = -(-S // self.layout.page_tokens)
        slots = np.asarray([si for si, _, _ in group], np.int32)
        prompts = np.stack([req.prompt for _, req, _ in group]).astype(
            np.int32)
        targets = np.asarray([req.max_new for _, req, _ in group], np.int32)
        phys = np.full((len(group), npg_prompt), self.layout.null_page,
                       np.int32)
        for gi, (si, _, pages) in enumerate(group):
            page_table_np[si, :] = self.layout.null_page
            page_table_np[si, :len(pages)] = pages
            phys[gi, :] = pages[:npg_prompt]
        with m.span("serve/prefill", batch=len(group), prompt_len=int(S)):
            self.pool, state = self._prefill(
                self.params, self.pool, state, jnp.asarray(prompts),
                jnp.asarray(phys), jnp.asarray(slots), jnp.asarray(targets))
            jax.block_until_ready(state["tokens"])
        now = time.perf_counter()
        for si, req, _ in group:
            slot = self.sched.slots[si]
            slot.gen = 1
            slot.t_first = now
        m.counter("serve/prefill_tokens").inc(int(prompts.size))
        return state

    # ------------------------------------------------------------ main run
    def run(self, requests) -> dict:
        """Drive a request list (with step-indexed arrivals) to completion;
        returns per-request results plus throughput/latency metrics."""
        with self.session.activate():
            return self._run(list(requests))

    def _run(self, requests) -> dict:
        m = self.session
        B, maxp = self.max_batch, self.max_pages_per_slot
        state = self._init_state()
        page_table_np = np.full((B, maxp), self.layout.null_page, np.int32)
        page_table = jnp.asarray(page_table_np)
        pending = deque(sorted(requests, key=lambda r: (r.arrival, r.rid)))
        results: dict[int, RequestResult] = {}
        arrival_t: dict[int, float] = {}
        step_idx, total_gen, decode_steps = 0, 0, 0
        logits_rows: dict[int, np.ndarray] = {}
        t0 = time.perf_counter()

        def completions(state):
            nonlocal total_gen, page_table
            dirty = False
            for si in range(B):
                slot = self.sched.slots[si]
                if slot is None or not slot.done:
                    continue
                toks = np.asarray(state["out"][si, :slot.max_new])
                if self.collect_logits:
                    logits_rows[slot.rid] = np.asarray(
                        state["logits"][si, :slot.max_new])
                t_done = time.perf_counter()
                ttft = slot.t_first - arrival_t[slot.rid]
                tpot = ((t_done - slot.t_first) / (slot.max_new - 1)
                        if slot.max_new > 1 else 0.0)
                results[slot.rid] = RequestResult(
                    rid=slot.rid, status="done", tokens=toks, ttft_s=ttft,
                    tpot_s=tpot, latency_s=t_done - arrival_t[slot.rid])
                total_gen += slot.max_new
                self.sched.complete(si)
                page_table_np[si, :] = self.layout.null_page
                dirty = True
                m.counter("serve/completed").inc()
                m.histogram("serve/ttft_ms").observe(ttft * 1e3)
                m.histogram("serve/tpot_ms").observe(tpot * 1e3)
            if dirty:
                page_table = jnp.asarray(page_table_np)

        while True:
            while pending and pending[0].arrival <= step_idx:
                req = pending.popleft()
                arrival_t[req.rid] = time.perf_counter()
                ok, reason = self.sched.submit(req)
                if not ok:
                    results[req.rid] = RequestResult(
                        rid=req.rid, status="rejected", reason=reason)
                    m.counter("serve/rejected").inc()
            m.histogram("serve/queue_depth").observe(len(self.sched.queue))
            admitted = self.sched.admit()
            if admitted:
                by_len: dict[int, list] = {}
                for entry in admitted:
                    by_len.setdefault(len(entry[1].prompt), []).append(entry)
                for group in by_len.values():
                    state = self._admit_group(group, state, page_table_np)
                page_table = jnp.asarray(page_table_np)
                m.counter("serve/admitted").inc(len(admitted))
                m.gauge("serve/pages_in_use").max(self.alloc.used_pages)
            completions(state)
            if self.sched.active_count == 0:
                if self.sched.queue:
                    raise RuntimeError(
                        "admission stalled with an empty batch — a queued "
                        "request's page reservation cannot ever be met")
                if pending:
                    step_idx = max(step_idx + 1, pending[0].arrival)
                    continue
                break
            with m.span("serve/decode_step", step=step_idx):
                self.pool, state = self._decode(self.params, self.pool,
                                                page_table, state)
            step_idx += 1
            decode_steps += 1
            self.sched.tick()
            m.counter("serve/decode_steps").inc()
            m.histogram("serve/occupancy").observe(
                self.sched.active_count / B)
            completions(state)

        wall = time.perf_counter() - t0
        ordered = [results[r.rid] for r in
                   sorted(requests, key=lambda r: r.rid)]
        done = [r for r in ordered if r.status == "done"]
        lat = np.asarray([r.latency_s for r in done]) if done else \
            np.zeros((1,))
        out = {
            "results": ordered,
            "wall_s": wall,
            "gen_tokens": total_gen,
            "decode_steps": decode_steps,
            "tokens_per_sec": total_gen / max(wall, 1e-9),
            "p50_latency_ms": float(np.percentile(lat, 50) * 1e3),
            "p99_latency_ms": float(np.percentile(lat, 99) * 1e3),
            "ttft_mean_ms": float(np.mean([r.ttft_s for r in done]) * 1e3)
            if done else 0.0,
            "tpot_mean_ms": float(np.mean([r.tpot_s for r in done]) * 1e3)
            if done else 0.0,
            "rejected": sum(r.status == "rejected" for r in ordered),
            "kv_pool_bytes": self.layout.pool_bytes,
            "kv_f32_pool_bytes": self.layout.f32_pool_bytes,
            "kv_bits": self.layout.bits,
            "kv_mechanism": self.mechanism,
            "mode": self.mode,
        }
        if self.collect_logits:
            out["logits"] = logits_rows
        return out
