"""Slot-based continuous-batching scheduler with admission control.

Requests queue FIFO; free decode slots refill from the queue head every
step (``mode="continuous"``), each admission allocating the request's
full page budget up front — admission control is "reserve pages or
wait", so an admitted request can never deadlock mid-decode.  Setting
``mode="fixed"`` recovers the legacy serving loop as a scheduler
configuration: admission waits until every slot is free, then seats a
whole batch, so slots idle until the batch's slowest request drains —
exactly the sequential fixed-batch behavior ``launch.serve`` used to
hard-code (and the baseline the continuous benchmark arm is gated
against).

Submission-time rejects (queue overflow, prompt/gen over the engine's
static caps, page demand exceeding the whole pool) are surfaced as
"rejected" results, never silently dropped.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.serving.kvcache import PageAllocator

MODES = ("continuous", "fixed")


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request: prompt token ids + a deterministic
    generation budget (``max_new`` counts the prefill's first token)."""
    rid: int
    prompt: np.ndarray
    max_new: int
    arrival: int = 0    # scheduler step at which the request becomes visible


@dataclasses.dataclass
class SlotState:
    """Host mirror of one occupied decode slot (no device syncs: pos/gen
    advance deterministically with every decode tick)."""
    rid: int
    prompt_len: int
    max_new: int
    pages: list
    t_admit: float = 0.0
    t_first: float = 0.0
    gen: int = 0

    @property
    def done(self) -> bool:
        return self.gen >= self.max_new


class Scheduler:
    def __init__(self, *, max_batch: int, page_tokens: int,
                 allocator: PageAllocator, mode: str = "continuous",
                 max_queue: int = 64, max_prompt: int, max_new_cap: int):
        if mode not in MODES:
            raise ValueError(f"mode={mode!r} not in {MODES}")
        if max_batch < 1:
            raise ValueError(f"max_batch={max_batch} must be >= 1")
        self.max_batch = max_batch
        self.page_tokens = page_tokens
        self.allocator = allocator
        self.mode = mode
        self.max_queue = max_queue
        self.max_prompt = max_prompt
        self.max_new_cap = max_new_cap
        self.slots: list[SlotState | None] = [None] * max_batch
        self.queue: deque[Request] = deque()

    # ------------------------------------------------------------- queries
    @property
    def active_count(self) -> int:
        return sum(s is not None for s in self.slots)

    def pages_needed(self, req: Request) -> int:
        """Whole-horizon page budget: the prompt's S tokens plus the
        max_new-1 decode writes (the first generated token comes out of
        prefill; its KV row is written by the first decode tick)."""
        tokens = len(req.prompt) + req.max_new - 1
        return -(-tokens // self.page_tokens)

    # ----------------------------------------------------------- admission
    def submit(self, req: Request) -> tuple[bool, str]:
        """Admission control at the door; (False, reason) = rejected."""
        if len(self.queue) >= self.max_queue:
            return False, f"queue full ({self.max_queue} waiting)"
        if len(req.prompt) < 1 or len(req.prompt) > self.max_prompt:
            return False, (f"prompt length {len(req.prompt)} outside "
                           f"[1, {self.max_prompt}]")
        if req.max_new < 1 or req.max_new > self.max_new_cap:
            return False, (f"max_new={req.max_new} outside "
                           f"[1, {self.max_new_cap}]")
        need = self.pages_needed(req)
        if need > self.allocator.n_pages:
            return False, (f"needs {need} KV pages; the pool has "
                           f"{self.allocator.n_pages}")
        self.queue.append(req)
        return True, ""

    def admit(self) -> list[tuple[int, Request, list[int]]]:
        """Seat queued requests into free slots, reserving their full
        page budget; stops at the first request the pool cannot yet
        satisfy (FIFO, no overtaking — deterministic replays)."""
        if self.mode == "fixed" and self.active_count:
            return []
        out = []
        for si in range(self.max_batch):
            if self.slots[si] is not None or not self.queue:
                continue
            req = self.queue[0]
            pages = self.allocator.alloc(self.pages_needed(req))
            if pages is None:
                break
            self.queue.popleft()
            self.slots[si] = SlotState(rid=req.rid,
                                       prompt_len=len(req.prompt),
                                       max_new=req.max_new, pages=pages)
            out.append((si, req, pages))
        return out

    def tick(self) -> None:
        """Advance the host mirrors after one decode step (every occupied
        slot generated one token; the jitted step deactivates finished
        slots device-side with the same arithmetic)."""
        for s in self.slots:
            if s is not None and s.gen < s.max_new:
                s.gen += 1

    def complete(self, si: int) -> SlotState:
        """Release a finished slot: pages back to the pool, slot free."""
        slot = self.slots[si]
        if slot is None:
            raise ValueError(f"slot {si} is not occupied")
        self.allocator.free(slot.pages)
        self.slots[si] = None
        return slot
