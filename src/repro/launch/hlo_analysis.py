"""Loop-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` and naive text grepping both count a ``while``
body ONCE, but ``lax.scan`` over layers / grad-accum / attention chunks puts
almost all compute inside whiles — so flops and collective bytes would be
undercounted by factors of 10-100x.  This module parses the HLO text into
computations, extracts each while's trip count (the s32 constant in its
condition computation), and recursively accumulates:

* ``dot_flops``      — 2 × result_elems × contracted_elems per dot, × trips
* ``collectives``    — wire bytes per device by kind (ring-model factors:
                       all-gather (g-1)/g · result, all-reduce 2(g-1)/g,
                       reduce-scatter (g-1) · result, all-to-all (g-1)/g,
                       collective-permute 1.0), × trips
* ``hbm_bytes``      — Σ (result + operand bytes) of top-level (non-fused)
                       instructions, × trips.  Fusion internals do not
                       materialize; this is a reads+writes HBM traffic model
                       (producer/consumer double count ≈ upper bound).

Known caveats (documented in EXPERIMENTS.md): CPU-backend lowering converts
some bf16 ops to f32 (inflates byte counts ~2x vs TPU); conditional branches
are counted at the max of their branches.
"""
from __future__ import annotations

import dataclasses
import re

_DT = {"f32": 4, "bf16": 2, "f16": 2, "f64": 8, "s32": 4, "u32": 4, "s64": 8,
       "u64": 8, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "pred": 1, "c64": 8,
       "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_ARR_RE = re.compile(r"(" + "|".join(_DT) + r")\[([0-9,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*?)\)\s+->")
_PARAM_RE = re.compile(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:[\w\[\]\{\},:\s]*?))(?:,\s|$)")
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _arrays(shape_str: str):
    """All (dtype, dims) arrays inside a shape string (handles tuples)."""
    return [(_DT[d], [int(x) for x in dims.split(",") if x])
            for d, dims in _ARR_RE.findall(shape_str)]


def _elems_first_array(shape_str: str):
    arrs = _arrays(shape_str)
    if not arrs:
        return None
    return arrs[0][1]


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    op: str
    rest: str              # everything after the opening paren


@dataclasses.dataclass
class Computation:
    name: str
    params: dict           # name -> shape str
    instrs: list
    is_entry: bool = False


def parse_computations(text: str) -> tuple[dict, str]:
    comps, cur, entry = {}, None, None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" "):
            m = _HEAD_RE.match(line)
            if m:
                params = {}
                for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]*(?:\([^)]*\))?[^,]*)",
                                      m.group(3)):
                    params[pm.group(1)] = pm.group(2)
                cur = Computation(m.group(2), params, [],
                                  is_entry=bool(m.group(1)))
                comps[cur.name] = cur
                if cur.is_entry:
                    entry = cur.name
                continue
        m = _INSTR_RE.match(line)
        if m and cur is not None:
            cur.instrs.append(Instr(m.group(1), m.group(2), m.group(3),
                                    m.group(4)))
    return comps, entry


def _shape_table(comp: Computation) -> dict:
    tab = dict(comp.params)
    for ins in comp.instrs:
        tab[ins.name] = ins.shape
    return tab


def _operand_names(rest: str) -> list[str]:
    depth, i, head = 0, 0, []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                break
            depth -= 1
        head.append(ch)
        i += 1
    return re.findall(r"%([\w\.\-]+)", "".join(head))


def _group_size(rest: str, n_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", rest)
    if m:
        return len(m.group(1).split(","))
    return n_devices


def _trip_count(comps: dict, cond_name: str) -> int:
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.shape.startswith("s32[]"):
            m = re.match(r"(\d+)", ins.rest)
            if m:
                best = max(best, int(m.group(1)))
    return best


_CALL_ATTRS = ("calls=", "to_apply=", "body=", "condition=",
               "branch_computations=", "true_computation=",
               "false_computation=", "comparator=")


def _called(rest: str) -> list[str]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(re.escape(attr) + r"\{?%?([\w\.\-]+)", rest):
            tok = m.group(1)
            out.append((attr.rstrip("="), tok))
        if attr == "branch_computations=":
            m = re.search(r"branch_computations=\{([^}]*)\}", rest)
            if m:
                out = [(a, t) for a, t in out if a != "branch_computations"]
                for tok in re.findall(r"%([\w\.\-]+)", m.group(1)):
                    out.append(("branch_computations", tok))
    return out


def analyze(text: str, n_devices: int = 256) -> dict:
    comps, entry = parse_computations(text)
    memo = {}

    def comp_cost(name: str, trip: int = 1) -> dict:
        key = (name, trip)
        if key in memo:
            return memo[key]
        memo[key] = {"flops": 0.0, "hbm": 0.0,
                     "coll": {k: 0.0 for k in _COLL}}
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        tab = _shape_table(comp)
        acc = {"flops": 0.0, "hbm": 0.0, "coll": {k: 0.0 for k in _COLL}}

        def nbytes(shape_str: str) -> int:
            """Byte size, charging loop-stacked buffers per-slice: inside a
            while body with trip count T, an array whose leading dim == T is
            scan xs/ys (or an in-place-updated stack) — each iteration only
            touches bytes/T of it."""
            total = 0
            for bsz, dims in _arrays(shape_str):
                n = 1
                for d in dims:
                    n *= d
                b = n * bsz
                if trip > 1 and dims and dims[0] == trip:
                    b //= trip
                total += b
            return total

        for ins in comp.instrs:
            op = ins.op
            if op == "dot":
                dims = _elems_first_array(ins.shape) or []
                out_elems = 1
                for d in dims:
                    out_elems *= d
                ops_ = _operand_names(ins.rest)
                lhs_shape = tab.get(ops_[0], "") if ops_ else ""
                ldims = _elems_first_array(lhs_shape) or []
                m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
                k_elems = 1
                if m and ldims:
                    for ci in m.group(1).split(","):
                        if ci and int(ci) < len(ldims):
                            k_elems *= ldims[int(ci)]
                acc["flops"] += 2.0 * out_elems * k_elems
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLL and not op.endswith("-done"):
                g = _group_size(ins.rest, n_devices)
                comps_bytes = [b * _prod(d) for b, d in _arrays(ins.shape)]
                if not comps_bytes:
                    continue
                big, small = max(comps_bytes), min(comps_bytes)
                if base == "all-gather":
                    wire = big * (g - 1) / g
                elif base == "all-reduce":
                    wire = big * 2 * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = small * (g - 1)
                elif base == "all-to-all":
                    wire = big * (g - 1) / g
                else:
                    wire = big
                acc["coll"][base] += wire
            # HBM traffic: top-level instr results + operands (fused bodies
            # don't materialize; 'fusion' result+operands counted here).
            # Slicing/indexing ops only touch their RESULT-sized window —
            # counting the full operand would charge each scan iteration for
            # the whole stacked weight array (quadratic in depth).
            if op in ("dynamic-slice", "gather", "slice"):
                acc["hbm"] += 2 * nbytes(ins.shape)
            elif op == "dynamic-update-slice":
                ops_ = _operand_names(ins.rest)
                upd = tab.get(ops_[1], "") if len(ops_) > 1 else ""
                acc["hbm"] += 2 * nbytes(upd)
            elif op == "scatter":
                ops_ = _operand_names(ins.rest)
                upd = tab.get(ops_[-1], "") if ops_ else ""
                acc["hbm"] += 2 * nbytes(upd)
            elif op not in ("parameter", "constant", "get-tuple-element",
                            "tuple", "bitcast", "while", "conditional"):
                b = nbytes(ins.shape)
                for on in _operand_names(ins.rest)[:8]:
                    b += nbytes(tab.get(on, ""))
                acc["hbm"] += b
            # recursion
            called = _called(ins.rest)
            if op == "while":
                body = next((t for a, t in called if a == "body"), None)
                cond = next((t for a, t in called if a == "condition"), None)
                trips = _trip_count(comps, cond) if cond else 1
                sub = comp_cost(body, trips) if body else None
                if sub:
                    acc["flops"] += sub["flops"] * trips
                    acc["hbm"] += sub["hbm"] * trips
                    for k in _COLL:
                        acc["coll"][k] += sub["coll"][k] * trips
            elif op == "conditional":
                branches = [t for a, t in called
                            if a in ("branch_computations", "true_computation",
                                     "false_computation")]
                if branches:
                    subs = [comp_cost(b) for b in branches]
                    best = max(subs, key=lambda s: s["flops"])
                    acc["flops"] += best["flops"]
                    acc["hbm"] += best["hbm"]
                    for k in _COLL:
                        acc["coll"][k] += best["coll"][k]
            else:
                for a, t in called:
                    if a in ("calls", "to_apply"):
                        # fusion/call body: flops + collectives flow up;
                        # internal tensors do NOT materialize to HBM (the
                        # fusion's own operands/result were counted above)
                        sub = comp_cost(t)
                        acc["flops"] += sub["flops"]
                        for k in _COLL:
                            acc["coll"][k] += sub["coll"][k]
        memo[name] = acc
        return acc

    total = comp_cost(entry) if entry else {"flops": 0, "hbm": 0,
                                            "coll": {k: 0 for k in _COLL}}
    total = dict(total)
    total["coll_total"] = sum(total["coll"].values())
    total["n_computations"] = len(comps)
    return total


def _prod(dims):
    n = 1
    for d in dims:
        n *= d
    return n
