import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
single-pod (16,16) and multi-pod (2,16,16) production meshes, record
memory_analysis / cost_analysis / collective bytes (parsed from optimized
HLO) into results/dryrun/*.json.

Usage:
  python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # subprocess per cell
"""
import argparse
import json
import re
import subprocess
import sys
import time
from pathlib import Path

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train (N = active params), 2·N·D fwd."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.batch * shape.seq
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.batch * shape.seq
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.batch  # decode: one token per sequence


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               act_mode: str | None = None):
    """Construct (step_fn, args shape structs, in_shardings) for a cell.

    ``act_mode`` overrides the config's activation policy (e.g. "act" lowers
    the paper's INT2 compressed-stash variant for before/after comparison).
    """
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.configs import SHAPES, cell_applicable, get, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import (make_prefill_step, make_serve_step,
                                    make_train_step)
    from repro.models import Model
    from repro.optim import AdamWConfig, adamw_init
    from repro.parallel import annotate
    from repro.parallel.sharding import (batch_pspecs, cache_pspecs,
                                         param_pspecs, to_named)

    cfg = get(arch)
    if act_mode:
        from repro.core.compressor import CompressionConfig

        cfg = dataclasses.replace(
            cfg, act_mode=act_mode,
            act_compression=CompressionConfig(bits=2, group_size=256))
    shape = SHAPES[shape_name]
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        return None, why

    mesh = make_production_mesh(multi_pod=multi_pod)
    annotate.set_rules(**annotate.rules_for(
        cfg, mesh, shape.batch, is_train=shape.kind == "train"))
    model = Model(cfg)
    params_shape = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    p_specs = to_named(param_pspecs(cfg, params_shape, mesh), mesh)
    specs = input_specs(cfg, shape)

    big = cfg.param_count() > 6e10  # bf16 optimizer moments for the giants
    opt = AdamWConfig(lr=1e-4, weight_decay=0.1, grad_clip=1.0,
                      state_dtype="bfloat16" if big else "float32")

    if shape.kind == "train":
        step = make_train_step(
            model, opt,
            accum_dtype=jnp.bfloat16 if big else jnp.float32)
        opt_shape = jax.eval_shape(
            lambda p: adamw_init(p, opt), params_shape)
        o_specs = {"step": to_named(jax.sharding.PartitionSpec(), mesh),
                   "m": jax.tree.map(lambda s: s, p_specs),
                   "v": jax.tree.map(lambda s: s, p_specs)}
        b_specs = to_named(
            batch_pspecs(cfg, shape.kind, mesh, shape.batch), mesh)
        args = (params_shape, opt_shape, specs)
        shardings = (p_specs, o_specs, b_specs)
        fn = step
    elif shape.kind == "prefill":
        # cache sized to the prompt (+ any stub-frontend prefix)
        fn = make_prefill_step(model, max_seq=None)
        b_specs = to_named(
            batch_pspecs(cfg, shape.kind, mesh, shape.batch), mesh)
        args = (params_shape, specs)
        shardings = (p_specs, b_specs)
    else:  # decode
        fn = make_serve_step(model)
        cache_shape = specs["cache"]
        c_specs = to_named(cache_pspecs(cfg, cache_shape, mesh, shape.batch,
                                        shape.seq), mesh)
        dp_total = 32 if multi_pod else 16
        dp_ax = ("pod", "data") if multi_pod else ("data",)
        tok_spec = to_named(jax.sharding.PartitionSpec(
            dp_ax if shape.batch % dp_total == 0 else None, None), mesh)
        args = (params_shape, cache_shape,
                jax.ShapeDtypeStruct((shape.batch, 1), jnp.int32))
        shardings = (p_specs, c_specs, tok_spec)
    return (fn, args, shardings, mesh), ""


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             act_mode: str | None = None) -> dict:
    import jax

    t0 = time.time()
    multi_pod = mesh_kind == "multi"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "act_mode": act_mode, "status": "?", "ts": time.strftime("%F %T")}
    built, why = build_cell(arch, shape_name, multi_pod, act_mode)
    if built is None:
        rec.update(status="skipped", reason=why)
        return rec
    from repro.configs import SHAPES, get
    from repro.launch.hlo_analysis import analyze

    fn, args, shardings, mesh = built
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*args)
        t_lower = time.time()
        compiled = lowered.compile()
        t_compile = time.time()
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
    n_dev = len(jax.devices())
    loop_aware = analyze(hlo, n_devices=n_dev)
    cfg = get(arch)
    shape = SHAPES[shape_name]
    rec.update(
        status="ok",
        lower_s=round(t_lower - t0, 1),
        compile_s=round(t_compile - t_lower, 1),
        memory={
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
        },
        cost_raw={"flops_body_once": cost.get("flops"),
                  "bytes_accessed_body_once": cost.get("bytes accessed")},
        hlo={"dot_flops_per_device": loop_aware["flops"],
             "hbm_bytes_per_device": loop_aware["hbm"],
             "collective_wire_bytes_per_device": loop_aware["coll"],
             "collective_total_bytes": loop_aware["coll_total"],
             "n_computations": loop_aware["n_computations"]},
        model_flops_global=model_flops(cfg, shape),
        param_count=cfg.param_count(),
        active_param_count=cfg.active_param_count(),
        n_devices=n_dev,
    )
    return rec


ALL_ARCHS = [
    "seamless-m4t-large-v2", "qwen3-moe-235b-a22b", "arctic-480b",
    "qwen1.5-4b", "qwen1.5-32b", "mistral-nemo-12b", "qwen3-32b",
    "internvl2-2b", "mamba2-780m", "zamba2-1.2b",
]
ALL_SHAPES = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--act-mode", default=None,
                    choices=[None, "none", "remat", "act"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--timeout", type=int, default=2400)
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    RESULTS.mkdir(parents=True, exist_ok=True)

    if not args.all:
        rec = run_cell(args.arch, args.shape, args.mesh, args.act_mode)
        suffix = f"__{args.act_mode}" if args.act_mode else ""
        out = RESULTS / f"{args.arch}__{args.shape}__{args.mesh}{suffix}.json"
        out.write_text(json.dumps(rec, indent=1))
        print(json.dumps({k: rec[k] for k in
                          ("arch", "shape", "mesh", "status")}, indent=None))
        if rec["status"] == "ok":
            h = rec["hlo"]
            ratio = rec["model_flops_global"] / max(
                h["dot_flops_per_device"] * rec["n_devices"], 1)
            print(f"  lower {rec['lower_s']}s compile {rec['compile_s']}s "
                  f"dot_flops/dev={h['dot_flops_per_device']:.3e} "
                  f"model/hlo={ratio:.3f} "
                  f"coll/dev={h['collective_total_bytes']:.3e}B "
                  f"hbm/dev={h['hbm_bytes_per_device']:.3e}B")
        return 0 if rec["status"] in ("ok", "skipped") else 1

    # driver: one subprocess per cell (isolates compile memory, survives
    # single-cell crashes)
    failures = []
    for mesh_kind in ("single", "multi"):
        for arch in ALL_ARCHS:
            for shape in ALL_SHAPES:
                out = RESULTS / f"{arch}__{shape}__{mesh_kind}.json"
                if args.skip_done and out.exists():
                    st = json.loads(out.read_text()).get("status")
                    if st in ("ok", "skipped"):
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind]
                print(f"=== {arch} × {shape} × {mesh_kind}", flush=True)
                try:
                    r = subprocess.run(cmd, timeout=args.timeout)
                    if r.returncode != 0:
                        failures.append((arch, shape, mesh_kind, r.returncode))
                        out.write_text(json.dumps(
                            {"arch": arch, "shape": shape, "mesh": mesh_kind,
                             "status": "error", "rc": r.returncode}))
                except subprocess.TimeoutExpired:
                    failures.append((arch, shape, mesh_kind, "timeout"))
                    out.write_text(json.dumps(
                        {"arch": arch, "shape": shape, "mesh": mesh_kind,
                         "status": "timeout"}))
    print(f"done; {len(failures)} failures: {failures}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
