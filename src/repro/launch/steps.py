"""jit-able step functions: train (with gradient accumulation), prefill,
decode.  These are what the dry-run lowers and what launch/train.py runs."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import backend
from repro.engine.seeds import step_seed
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update


def make_train_step(model: Model, opt: AdamWConfig,
                    accum_dtype=jnp.float32, act_impl: str | None = None):
    """``act_impl`` pins the activation-compression kernel backend for the
    whole step ("jnp" | "interp" | "pallas" | "auto"); None defers to the
    config's ``act_compression.impl``.  Applied at trace time via
    :func:`repro.core.backend.use_impl`."""
    cfg = model.cfg

    def loss_fn(params, mb, step):
        with backend.use_impl(act_impl):
            return model.loss(
                params, mb["tokens"],
                prefix_embeds=mb.get("prefix_embeds"),
                enc_embeds=mb.get("enc_embeds"),
                act_seed=step_seed(step),
                vocab_chunk=cfg.vocab_chunk)

    def train_step(params, opt_state, batch):
        step = opt_state["step"]
        if cfg.grad_accum > 1:
            a = cfg.grad_accum

            def split(x):
                return x.reshape(a, x.shape[0] // a, *x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, accum_dtype), params)

            def body(gsum, mb):
                l, g = jax.value_and_grad(loss_fn)(params, mb, step)
                return jax.tree.map(
                    lambda s, x: s + x.astype(accum_dtype), gsum, g), l

            grads, losses = jax.lax.scan(body, zeros, mbs)
            grads = jax.tree.map(lambda g: g / a, grads)
            loss = losses.mean()
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, step)
        params, opt_state = adamw_update(grads, opt_state, params, opt)
        return params, opt_state, {"loss": loss}

    return train_step


def make_prefill_step(model: Model, max_seq: int | None = None):
    def prefill_step(params, batch):
        return model.prefill(
            params, batch["tokens"],
            prefix_embeds=batch.get("prefix_embeds"),
            enc_embeds=batch.get("enc_embeds"),
            max_seq=max_seq)

    return prefill_step


def make_serve_step(model: Model):
    """One decode step: greedy next token + updated cache."""

    def serve_step(params, cache, tokens):
        logits, cache = model.decode_step(params, cache, tokens)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, cache

    return serve_step
