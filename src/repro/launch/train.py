"""End-to-end training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --smoke \\
      --steps 50 --act-mode act --ckpt-dir /tmp/run1

Full configs need the production mesh (TPU pod); ``--smoke`` runs the
reduced same-family config on local devices.  Auto-resumes from the last
checkpoint in --ckpt-dir.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get, reduce_for_smoke
from repro.core.compressor import CompressionConfig
from repro.data import batch_for_step
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init
from repro.obs import ObsPolicy
from repro.obs.trace import stopwatch
from repro.parallel import annotate
from repro.parallel.sharding import batch_pspecs, param_pspecs, to_named
from repro.runtime import StragglerMonitor, TrainRunner


def _graph_main(args):
    """--graph-batches path: the partition-sampled GNN engine instead of an
    LM arch (same launcher, same compression flags, same mesh plumbing).

    Flags lower onto one :class:`~repro.engine.plan.ExecutionPlan`; the
    engine run and the memory report read the *same* plan object, so the
    byte/bit accounting describes exactly what this invocation stashed."""
    from repro.engine import run as engine_run
    from repro.engine.plan import (ExecutionPlan, KernelPolicy,
                                   SamplingPolicy)
    from repro.graph import (GNNConfig, activation_memory_report, arxiv_like,
                             flickr_like, papers100m_like)

    maker = {"arxiv": arxiv_like, "flickr": flickr_like,
             "papers100m": papers100m_like}[args.graph_dataset]
    g = maker(scale=args.graph_scale)
    comp = None
    if args.act_mode == "act":
        comp = CompressionConfig(bits=args.act_bits, group_size=args.act_group,
                                 rp_ratio=8, impl=args.act_impl)
    cfg = GNNConfig(arch=args.graph_arch, hidden=(256, 256),
                    n_classes=g.num_classes, compression=comp)
    lr = args.lr if args.lr is not None else 5e-3   # GNN engines' default
    offload = None if args.offload == "none" else args.offload
    obs_policy = ObsPolicy()
    if args.obs:
        obs_policy = ObsPolicy(enabled=True,
                               quant_stats=comp is not None,
                               quant_stats_every=args.obs_quant_every)
    if args.mesh_parts:
        # mesh-sharded partition-parallel engine: the graph mesh is built
        # by the compiler (largest divisor of n_parts the host allows);
        # stash/precision knobs belong to the other engines and raise
        plan = ExecutionPlan(
            sampling=SamplingPolicy(kind="mesh", n_parts=args.mesh_parts,
                                    shuffle=False),
            kernel=KernelPolicy(fused=args.act_fused),
            obs=obs_policy)
        mesh = None
    else:
        mesh = (make_production_mesh() if args.production_mesh
                else make_local_mesh())
        plan = ExecutionPlan.from_legacy(
            n_parts=args.graph_batches, fused=args.act_fused,
            offload=offload, bit_budget=args.bit_budget,
            autoprec_refresh=args.autoprec_refresh, halo=args.graph_halo,
            obs=obs_policy)
    print(f"plan: {plan.describe()}")
    r = engine_run(g, cfg, plan, AdamWConfig(lr=lr, weight_decay=0.0),
                   n_epochs=args.steps, seed=0, verbose=True, mesh=mesh)
    if args.mesh_parts:
        pg = r["pager"]
        print(f"mesh: {r['mesh_devices']} devices x "
              f"{r['updates_per_epoch']} rounds, halo width "
              f"{r['halo_width']} rows, {r['dropped_edges']} cross-round "
              f"edges dropped, {r['halo_bytes_per_epoch'] / 1e6:.2f} MB "
              f"halo traffic/epoch")
        print(f"feature pager: {pg['host_bytes'] / 1e6:.2f} MB host-resident "
              f"in {pg['n_pages']} pages/round, overlap "
              f"{pg['overlap_frac']:.2f} (last {pg['overlap_window_size']} "
              f"fetches: {pg['overlap_frac_window']:.2f})")
    quant_rows = []
    obs = r.get("obs")
    if obs is not None:
        quant_rows = obs.quant_rows()
        if quant_rows:
            ep = quant_rows[0]["epoch"]
            print(f"quant health (epoch {ep}): layer bits measured "
                  "predicted ratio sat%")
            for row in quant_rows:
                print(f"  L{row['layer']} {row['bits']}b "
                      f"{row['measured_var']:.3e} "
                      f"{row['predicted_var']:.3e} "
                      f"{row['ratio']:.2f} {100 * row['sat_rate']:.1f}%")
        if args.trace_out:
            paths = obs.export(args.trace_out)
            print(f"obs trace: {paths['jsonl']} (spans) + "
                  f"{paths['chrome']} (load at ui.perfetto.dev)")
    cfg = r.get("cfg", cfg)   # autoprec may have re-allocated per-layer bits
    rep = activation_memory_report(g, cfg, batch_nodes=r["batch_nodes"],
                                   plan=plan,
                                   quant_health=quant_rows or None)
    if "arena" in rep:
        a = rep["arena"]
        print(f"stash arena[{a['policy']}]: {a['planned_bytes'] / 1e6:.2f} MB "
              f"pooled ({a['u32_bytes'] / 1e6:.2f} u32 + "
              f"{a['f32_bytes'] / 1e6:.2f} f32), "
              f"device-resident {a['device_resident_bytes'] / 1e6:.2f} MB")
    if "bits_per_layer" in r:
        print(f"autoprec: budget={args.bit_budget} avg bits "
              f"({r['bit_budget_bytes']} stash bytes) -> per-layer bits "
              f"{r['bits_per_layer']}")
    print(f"{g.name}: {g.n_nodes} nodes -> {r['n_parts']} batches of "
          f"{r['batch_nodes']} padded nodes, "
          f"{r['updates_per_epoch']} updates/epoch")
    print(f"epochs={args.steps} val_acc={r['val_acc']:.4f} "
          f"test_acc={r['test_acc']:.4f} S={r['epochs_per_sec']:.2f} e/s")
    if "mesh" in rep:
        print(f"per-device peak saved-activation bytes: "
              f"{rep['mesh']['per_device_saved_bytes'] / 1e6:.2f} MB "
              f"({rep['mesh']['peak_reduction_vs_full']:.1f}x below "
              f"full-graph)")
    elif "batched" in rep:
        print(f"peak saved-activation bytes/batch: "
              f"{rep['batched']['peak_saved_bytes'] / 1e6:.2f} MB "
              f"({rep['batched']['peak_reduction_vs_full']:.1f}x below "
              f"full-graph)")
    else:
        full = rep.get("compressed_bytes", rep["fp32_bytes"])
        print(f"full-graph saved-activation bytes: {full / 1e6:.2f} MB")
    return r["history"]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="LM config name (required unless --graph-batches)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=None,
                    help="defaults to 3e-4 (LM) / 5e-3 (--graph-batches)")
    ap.add_argument("--act-mode", default=None,
                    choices=[None, "none", "remat", "act"])
    ap.add_argument("--act-bits", type=int, default=2)
    ap.add_argument("--act-group", type=int, default=256)
    ap.add_argument("--act-impl", default="auto",
                    choices=["auto", "jnp", "interp", "pallas"],
                    help="kernel backend for the compression stack "
                         "(core.backend dispatch; 'auto' = pallas on TPU)")
    ap.add_argument("--act-fused", default="auto",
                    choices=["auto", "on", "off"],
                    help="fused quantize-in-epilogue matmul pair for the "
                         "GNN engine (KernelPolicy.fused): 'auto' fuses "
                         "eligible layers on the real Pallas backend, "
                         "'on' forces it, 'off' keeps the two-pass path")
    ap.add_argument("--offload", default="none",
                    choices=["none", "device", "host", "pinned-paged"],
                    help="where saved-for-backward stashes live "
                         "(repro.offload): 'device' pools them in one "
                         "arena (--graph-batches path), 'host'/'pinned-"
                         "paged' additionally park segments in host "
                         "memory between forward and backward (LM path: "
                         "per-layer host stash under the scan)")
    ap.add_argument("--opt-bits", type=int, default=0, choices=[0, 8])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure (fault-tolerance demo/tests)")
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--graph-batches", type=int, default=0, metavar="N_PARTS",
                    help="train the GNN stack with the partition-sampled "
                         "mini-batch engine (N_PARTS subgraph batches; "
                         "--steps counts epochs) instead of an LM arch")
    ap.add_argument("--mesh-parts", type=int, default=0, metavar="N_PARTS",
                    help="train the GNN stack with the mesh-sharded "
                         "partition-parallel engine: N_PARTS partitions "
                         "sharded over a 'graph' device mesh axis with "
                         "per-layer halo exchange and host-resident "
                         "feature paging (--steps counts epochs)")
    ap.add_argument("--graph-dataset", default="arxiv",
                    choices=["arxiv", "flickr", "papers100m"])
    ap.add_argument("--graph-scale", type=float, default=0.02)
    ap.add_argument("--graph-arch", default="sage", choices=["sage", "gcn"])
    ap.add_argument("--graph-halo", type=int, default=0,
                    help="hops of in-neighborhood halo around each partition")
    ap.add_argument("--bit-budget", type=float, default=None,
                    help="variance-guided adaptive precision: average stash "
                         "bits per element (2.0 = the fixed-INT2 footprint); "
                         "per-layer widths are solved by core.autoprec "
                         "(--graph-batches path)")
    ap.add_argument("--autoprec-refresh", type=int, default=0,
                    help="re-collect sensitivity stats and re-solve the "
                         "allocation every N epochs (0 = allocate once)")
    ap.add_argument("--obs", action="store_true",
                    help="enable the runtime observability layer "
                         "(repro.obs): engine spans, metrics, and — when "
                         "compression is on — the per-layer quant-health "
                         "probe (graph engines; bit-identical to obs-off)")
    ap.add_argument("--trace-out", default=None, metavar="BASE",
                    help="with --obs: export the span trace to BASE.jsonl "
                         "and BASE.trace.json (Chrome trace_event — load "
                         "at ui.perfetto.dev)")
    ap.add_argument("--obs-quant-every", type=int, default=10, metavar="N",
                    help="with --obs: run the quant-health probe every N "
                         "epochs")
    args = ap.parse_args(argv)

    if args.graph_batches and args.mesh_parts:
        ap.error("--graph-batches and --mesh-parts are different engines; "
                 "pick one")
    if args.graph_batches or args.mesh_parts:
        return _graph_main(args)
    if args.arch is None:
        ap.error("--arch is required unless --graph-batches or "
                 "--mesh-parts is set")

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    if args.act_mode:
        comp = CompressionConfig(bits=args.act_bits, group_size=args.act_group,
                                 impl=args.act_impl)
        cfg = dataclasses.replace(cfg, act_mode=args.act_mode,
                                  act_compression=comp)
    if args.offload in ("host", "pinned-paged"):
        # "device" is a no-op for the LM path: without a multi-layer arena
        # the per-layer residual already is the device placement
        cfg = dataclasses.replace(cfg, act_offload=args.offload)

    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    annotate.set_rules(**annotate.rules_for(cfg, mesh, args.batch))

    model = Model(cfg)
    lr = args.lr if args.lr is not None else 3e-4
    opt = AdamWConfig(lr=lr, weight_decay=0.01, grad_clip=1.0,
                      warmup_steps=min(20, args.steps // 5),
                      state_bits=args.opt_bits)
    act_impl = None if args.act_impl == "auto" else args.act_impl
    train_step = make_train_step(model, opt, act_impl=act_impl)

    with mesh:
        params = model.init(jax.random.PRNGKey(0))
        opt_state = adamw_init(params, opt)
        jit_step = jax.jit(train_step, donate_argnums=(0, 1))

        def step_fn(state, batch):
            params, opt_state = state
            params, opt_state, metrics = jit_step(params, opt_state, batch)
            return (params, opt_state), metrics

        def make_batch(step):
            toks = batch_for_step(cfg.vocab, args.batch, args.seq, step)
            b = {"tokens": jnp.asarray(toks)}
            if cfg.family == "encdec":
                b["enc_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(step), (args.batch, args.seq,
                                               cfg.d_model), jnp.bfloat16)
            if cfg.frontend == "vision":
                b["prefix_embeds"] = jax.random.normal(
                    jax.random.PRNGKey(step), (args.batch, cfg.frontend_len,
                                               cfg.d_model), jnp.bfloat16)
            return b

        if args.ckpt_dir:
            runner = TrainRunner(step_fn, make_batch, args.ckpt_dir,
                                 ckpt_every=args.ckpt_every,
                                 fail_at_step=args.fail_at,
                                 monitor=StragglerMonitor())
            state, hist = runner.run((params, opt_state), args.steps)
            print(f"straggler events: {len(runner.monitor.events)}")
        else:
            state = (params, opt_state)
            hist = []
            for step in range(args.steps):
                with stopwatch("lm/step", step=step) as sw:
                    state, m = step_fn(state, make_batch(step))
                hist.append({"step": step, "loss": float(m["loss"]),
                             "dt": sw.elapsed_s})
        first, last = hist[0]["loss"], hist[-1]["loss"]
        print(f"steps={len(hist)} loss {first:.4f} -> {last:.4f}")
        return hist


if __name__ == "__main__":
    main()
