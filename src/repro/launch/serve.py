"""Batched serving launcher: prefill queue + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \\
      --requests 8 --prompt-len 32 --gen-len 64

Production notes: on a TPU mesh the same step functions lower with the
decode cache shardings from ``parallel.sharding.cache_pspecs`` (what the
dry-run exercises at 32k/500k context); this launcher runs the identical
code path on local devices with reduced configs.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduce_for_smoke
from repro.data import batch_for_step
from repro.launch.steps import make_serve_step
from repro.models import Model
from repro.obs.trace import stopwatch


def _stash_prompt_context(params, prompts, policy: str) -> dict:
    """Serving-side arena exercise: park the batch's prompt embeddings in
    a compressed stash arena under ``policy`` and read them back.

    This is the read path a compressed prompt-context cache would use
    (stash at prefill, decompress on a later turn); it drives
    ``stash_write`` → offload → prefetch → ``stash_read`` → decompress
    end-to-end outside the training engines.
    """
    from repro.core.compressor import CompressionConfig, compress, decompress
    from repro.engine.seeds import sr_seed
    from repro.offload import arena, engine

    h0 = jnp.take(params["embed"], jnp.asarray(prompts),
                  axis=0).astype(jnp.float32)
    comp = CompressionConfig(bits=2, group_size=256)
    plan = arena.plan_stashes((tuple(h0.shape),), (comp,))
    writer = engine.make_writer(plan, policy, jnp.uint32(0x5E12))
    writer.put_ct(0, compress(h0, comp, sr_seed(0)))
    reader = engine.make_reader(plan, policy, writer.residual())
    reader.prefetch(0)
    h_rec = decompress(reader.get_ct(0))
    err = float(jnp.mean((h_rec - h0) ** 2) / jnp.maximum(
        jnp.mean(h0 ** 2), 1e-12))
    return {"policy": policy, "arena_bytes": plan.total_bytes,
            "full_bytes": int(h0.nbytes), "rel_mse": err,
            "shape_ok": h_rec.shape == h0.shape}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--offload", default=None,
                    choices=["device", "host", "pinned-paged"],
                    help="also stash each batch's prompt embeddings in a "
                         "compressed arena under this policy and read "
                         "them back (exercises the serving-side arena "
                         "read path)")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, act_mode="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model))
    max_seq = args.prompt_len + args.gen_len

    done, t_prefill, t_decode, n_decoded = 0, 0.0, 0.0, 0
    outputs = []
    stash_report = None
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        prompts = batch_for_step(cfg.vocab, n, args.prompt_len,
                                 step=done, seed=11)
        if args.offload and stash_report is None:
            stash_report = _stash_prompt_context(params, prompts,
                                                 args.offload)
            assert stash_report["shape_ok"], stash_report
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(done),
                (n, args.prompt_len, cfg.d_model), jnp.bfloat16)
        with stopwatch("serve/prefill", batch=n) as sw:
            logits, cache = model.prefill(params, jnp.asarray(prompts),
                                          max_seq=max_seq, **kwargs)
            jax.block_until_ready(logits)
        t_prefill += sw.elapsed_s
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen = [np.asarray(tok)]
        with stopwatch("serve/decode", batch=n,
                       gen_len=args.gen_len) as sw:
            for _ in range(args.gen_len - 1):
                tok, _, cache = serve(params, cache, tok)
                gen.append(np.asarray(tok))
            jax.block_until_ready(tok)
        t_decode += sw.elapsed_s
        n_decoded += (args.gen_len - 1) * n
        outputs.append(np.concatenate(gen, axis=1))
        done += n
    print(f"served {done} requests: prefill {t_prefill:.2f}s total, "
          f"decode {n_decoded / max(t_decode, 1e-9):.1f} tok/s")
    if stash_report is not None:
        print(f"prompt-context stash[{stash_report['policy']}]: "
              f"{stash_report['arena_bytes']} B arena vs "
              f"{stash_report['full_bytes']} B raw, "
              f"rel_mse={stash_report['rel_mse']:.4f}")
    return outputs


if __name__ == "__main__":
    main()
