"""Batched serving launcher: prefill queue + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m --smoke \\
      --requests 8 --prompt-len 32 --gen-len 64

Production notes: on a TPU mesh the same step functions lower with the
decode cache shardings from ``parallel.sharding.cache_pspecs`` (what the
dry-run exercises at 32k/500k context); this launcher runs the identical
code path on local devices with reduced configs.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduce_for_smoke
from repro.data import batch_for_step
from repro.launch.steps import make_serve_step
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, act_mode="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    serve = jax.jit(make_serve_step(model))
    max_seq = args.prompt_len + args.gen_len

    done, t_prefill, t_decode, n_decoded = 0, 0.0, 0.0, 0
    outputs = []
    while done < args.requests:
        n = min(args.batch, args.requests - done)
        prompts = batch_for_step(cfg.vocab, n, args.prompt_len,
                                 step=done, seed=11)
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(done),
                (n, args.prompt_len, cfg.d_model), jnp.bfloat16)
        t0 = time.perf_counter()
        logits, cache = model.prefill(params, jnp.asarray(prompts),
                                      max_seq=max_seq, **kwargs)
        jax.block_until_ready(logits)
        t_prefill += time.perf_counter() - t0
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        gen = [np.asarray(tok)]
        t0 = time.perf_counter()
        for _ in range(args.gen_len - 1):
            tok, _, cache = serve(params, cache, tok)
            gen.append(np.asarray(tok))
        jax.block_until_ready(tok)
        t_decode += time.perf_counter() - t0
        n_decoded += (args.gen_len - 1) * n
        outputs.append(np.concatenate(gen, axis=1))
        done += n
    print(f"served {done} requests: prefill {t_prefill:.2f}s total, "
          f"decode {n_decoded / max(t_decode, 1e-9):.1f} tok/s")
    return outputs


if __name__ == "__main__":
    main()
