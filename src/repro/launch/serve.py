"""Serving launcher: continuous-batching engine over the block-quantized
paged KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-4b --smoke \\
      --requests 8 --prompt-len 32 --gen-len 64 --kv-bits 4

Attention-cache families (dense / vlm / moe) serve through
:class:`repro.serving.ServeEngine`: slot-based continuous batching with
page-level admission control, KV written block-quantized
(``--kv-bits {2,4,8}``; 16 = raw bf16) under an offload placement policy
(``--kv-policy``).  ``--mode fixed`` recovers the legacy sequential
fixed-batch loop as a scheduler configuration — the baseline
``benchmarks/serve.py`` gates the continuous engine against.

SSM / hybrid / enc-dec state caches are not paged-KV shaped; they decode
through the legacy fixed-batch loop below (which accumulates tokens
device-side and transfers once per batch — no per-token host round trip).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get, reduce_for_smoke
from repro.data import batch_for_step
from repro.launch.steps import make_serve_step
from repro.models import Model
from repro.obs import ObsPolicy
from repro.obs.trace import stopwatch
from repro.serving import KV_FAMILIES, KVCacheConfig, Request, ServeEngine


def _legacy_loop(model, params, args):
    """Fixed-batch greedy decode for the non-attention families: tokens
    accumulate in a device-side buffer updated in-place each step and
    transfer to the host once per batch."""
    cfg = model.cfg
    serve = jax.jit(make_serve_step(model), donate_argnums=(1,))
    max_seq = args.prompt_len + args.gen_len

    @jax.jit
    def append(buf, tok, i):
        return buf.at[:, i].set(tok[:, 0])

    done, t_prefill, t_decode, n_decoded = 0, 0.0, 0.0, 0
    outputs = []
    while done < args.requests:
        n = min(args.max_batch, args.requests - done)
        prompts = batch_for_step(cfg.vocab, n, args.prompt_len,
                                 step=done, seed=11)
        kwargs = {}
        if cfg.family == "encdec":
            kwargs["enc_embeds"] = jax.random.normal(
                jax.random.PRNGKey(done),
                (n, args.prompt_len, cfg.d_model), jnp.bfloat16)
        with stopwatch("serve/prefill", batch=n) as sw:
            logits, cache = model.prefill(params, jnp.asarray(prompts),
                                          max_seq=max_seq, **kwargs)
            jax.block_until_ready(logits)
        t_prefill += sw.elapsed_s
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        buf = jnp.zeros((n, args.gen_len), jnp.int32).at[:, 0].set(tok[:, 0])
        with stopwatch("serve/decode", batch=n, gen_len=args.gen_len) as sw:
            for i in range(1, args.gen_len):
                tok, _, cache = serve(params, cache, tok)
                buf = append(buf, tok, i)
            jax.block_until_ready(buf)
        t_decode += sw.elapsed_s
        n_decoded += (args.gen_len - 1) * n
        outputs.append(np.asarray(buf))          # one transfer per batch
        done += n
    print(f"served {done} requests (legacy {cfg.family} loop): prefill "
          f"{t_prefill:.2f}s total, decode "
          f"{n_decoded / max(t_decode, 1e-9):.1f} tok/s")
    return [row for batch in outputs for row in batch]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4,
                    help="decode slots (continuous) / batch size (fixed)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--kv-bits", type=int, default=8, choices=[2, 4, 8, 16],
                    help="KV cache width: 2/4/8 block-quantized, 16 raw bf16")
    ap.add_argument("--kv-policy", default="device",
                    choices=["device", "host", "pinned-paged"],
                    help="page-pool placement (offload memory policies)")
    ap.add_argument("--kv-group", type=int, default=64,
                    help="quantization block size along the KV token row")
    ap.add_argument("--page-tokens", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--kv-pages", type=int, default=None,
                    help="physical pages in the pool (default: sized so "
                         "max_batch full-horizon requests fit)")
    ap.add_argument("--mode", default="continuous",
                    choices=["continuous", "fixed"],
                    help="fixed = legacy sequential batch loop, as a "
                         "scheduler configuration")
    ap.add_argument("--obs", action="store_true",
                    help="enable scheduler/engine metrics "
                         "(queue depth, occupancy, TTFT/TPOT, page residency)")
    args = ap.parse_args(argv)

    cfg = get(args.arch)
    if args.smoke:
        cfg = reduce_for_smoke(cfg)
    cfg = dataclasses.replace(cfg, act_mode="none")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    if cfg.family not in KV_FAMILIES:
        return _legacy_loop(model, params, args)

    pages_per_req = -(-(args.prompt_len + args.gen_len - 1)
                      // args.page_tokens)
    n_pages = args.kv_pages or args.max_batch * pages_per_req
    kv = KVCacheConfig(bits=args.kv_bits, group_size=args.kv_group,
                       policy=args.kv_policy, page_tokens=args.page_tokens,
                       n_pages=n_pages)
    engine = ServeEngine(model, params, kv=kv, max_batch=args.max_batch,
                         max_prompt=args.prompt_len, gen_cap=args.gen_len,
                         mode=args.mode,
                         obs=ObsPolicy(enabled=True) if args.obs else None)
    requests = [
        Request(rid=i,
                prompt=batch_for_step(cfg.vocab, 1, args.prompt_len,
                                      step=i, seed=11)[0],
                max_new=args.gen_len)
        for i in range(args.requests)]
    out = engine.run(requests)
    print(f"served {args.requests - out['rejected']}/{args.requests} "
          f"requests [{args.mode}, kv-bits={args.kv_bits}, "
          f"{engine.mechanism}]: {out['tokens_per_sec']:.1f} tok/s, "
          f"p50 {out['p50_latency_ms']:.0f} ms / "
          f"p99 {out['p99_latency_ms']:.0f} ms, "
          f"ttft {out['ttft_mean_ms']:.0f} ms, "
          f"tpot {out['tpot_mean_ms']:.1f} ms")
    print(f"kv pool: {out['kv_pool_bytes']} B "
          f"({out['kv_f32_pool_bytes']} B as f32, "
          f"{out['kv_f32_pool_bytes'] / max(out['kv_pool_bytes'], 1):.1f}x)")
    if args.obs:
        snap = engine.session.summary().get("metrics", {})
        for key in ("serve/admitted", "serve/completed", "serve/rejected",
                    "serve/decode_steps", "serve/pages_in_use"):
            if key in snap:
                print(f"  {key}: {snap[key]}")
    return [r.tokens for r in out["results"] if r.status == "done"]


if __name__ == "__main__":
    main()
