"""``python -m repro.staticcheck`` — run every pass, gate on new findings.

Exit codes: 0 — clean or fully baselined; 1 — at least one finding not in
the baseline; 2 — a pass crashed (an analyzer bug, not a repo finding).

The jaxpr audit traces the whole plan matrix, which costs a few seconds
of JAX tracing; its results are cached in
``results/staticcheck/audit_cache.json`` keyed by a digest of every
source file the traced programs could depend on, so repeated CI runs on
an unchanged tree skip straight to the verdict.
"""
from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys
import traceback

from repro.staticcheck import (deadcode, findings as fmod, jaxpr_audit,
                               kernel_contracts, plan_verify, seed_lint)
from repro.staticcheck.matrix import audit_matrix

REPO_ROOT = pathlib.Path(__file__).resolve().parents[3]
DEFAULT_BASELINE = REPO_ROOT / "results" / "staticcheck" / "baseline.json"
DEFAULT_CACHE = REPO_ROOT / "results" / "staticcheck" / "audit_cache.json"

PASSES = ("seed-lint", "plan-verify", "kernel-contracts", "jaxpr-audit")


def tree_digest(root: pathlib.Path = REPO_ROOT) -> str:
    """Digest of everything the traced plan matrix depends on: the whole
    ``src/repro`` tree plus the persisted autotune tiles."""
    h = hashlib.sha256()
    paths = sorted((root / "src" / "repro").rglob("*.py"))
    tiles = root / "results" / "autotune" / "fused_tiles.json"
    if tiles.exists():
        paths.append(tiles)
    for p in paths:
        h.update(str(p.relative_to(root)).encode())
        h.update(p.read_bytes())
    return h.hexdigest()


def run_jaxpr_audit(cache: pathlib.Path | None) -> list[fmod.Finding]:
    digest = tree_digest()
    if cache is not None and cache.exists():
        try:
            data = json.loads(cache.read_text())
        except ValueError:
            data = {}
        if data.get("digest") == digest:
            results = [jaxpr_audit.AuditResult.from_json(r)
                       for r in data["results"]]
            return [f for r in results for f in r.findings]
    results = jaxpr_audit.run()
    if cache is not None:
        cache.parent.mkdir(parents=True, exist_ok=True)
        cache.write_text(json.dumps(
            {"digest": digest, "results": [r.to_json() for r in results]},
            indent=2) + "\n")
    return [f for r in results for f in r.findings]


def run_pass(name: str, cache: pathlib.Path | None) -> list[fmod.Finding]:
    if name == "seed-lint":
        return seed_lint.run()
    if name == "plan-verify":
        out = []
        for case in audit_matrix():
            out.extend(plan_verify.verify_plan(
                case.plan, case.cfg, case.in_dim, case.n_nodes,
                where=case.key))
        out.extend(plan_verify.verify_kv_matrix())
        return out
    if name == "kernel-contracts":
        return kernel_contracts.run()
    if name == "jaxpr-audit":
        return run_jaxpr_audit(cache)
    if name == "dead-code":
        return deadcode.sweep()
    raise ValueError(f"unknown pass {name!r}")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.staticcheck",
        description="compression-invariant static analysis over the repo")
    ap.add_argument("--ci", action="store_true",
                    help="CI mode: plain output, all gating passes")
    ap.add_argument("--baseline", type=pathlib.Path,
                    default=DEFAULT_BASELINE,
                    help="suppression file (default: %(default)s)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept the current findings into --baseline")
    ap.add_argument("--dead-code", action="store_true",
                    help="also run the opt-in unused-symbol sweep")
    ap.add_argument("--passes", default=None, metavar="CSV",
                    help=f"subset of passes to run (default: all of "
                         f"{','.join(PASSES)})")
    ap.add_argument("--cache", type=pathlib.Path, default=DEFAULT_CACHE,
                    help="jaxpr-audit result cache (default: %(default)s)")
    ap.add_argument("--no-cache", action="store_true",
                    help="re-trace the plan matrix unconditionally")
    args = ap.parse_args(argv)

    names = (args.passes.split(",") if args.passes
             else list(PASSES) + (["dead-code"] if args.dead_code else []))
    cache = None if args.no_cache else args.cache

    all_findings: list[fmod.Finding] = []
    for name in names:
        try:
            got = run_pass(name.strip(), cache)
        except Exception:
            print(f"[{name}] pass crashed:", file=sys.stderr)
            traceback.print_exc()
            return 2
        print(f"[{name}] {len(got)} finding(s)")
        for f in got:
            print(f"  {f.render()}")
        all_findings.extend(got)

    if args.write_baseline:
        fmod.save_baseline(args.baseline, all_findings)
        print(f"wrote {len(all_findings)} finding(s) to {args.baseline}")
        return 0

    fresh = fmod.new_findings(all_findings, fmod.load_baseline(args.baseline))
    n_old = len(all_findings) - len(fresh)
    if fresh:
        print(f"FAIL: {len(fresh)} new finding(s) "
              f"({n_old} baselined)", file=sys.stderr)
        return 1
    print(f"OK: no new findings ({n_old} baselined)")
    return 0
