"""Finding model + baseline suppression file shared by every pass.

A :class:`Finding` is one violation of a compression invariant:
``pass_name`` names the analysis pass ("jaxpr-audit", "plan-verify",
"kernel-contracts", "seed-lint", "dead-code"), ``rule`` the specific
invariant, ``where`` the locator (``file:line`` for source passes, a
plan-matrix key or cache-entry key for the symbolic passes), and
``message`` the human sentence.

Baselines are how pre-existing findings get grandfathered without
silencing the gate for *new* ones: a baseline JSON stores each accepted
finding's :meth:`Finding.fingerprint` (a stable hash of pass/rule/where —
deliberately not the message, so rewording a diagnostic doesn't
un-suppress it) plus the human text for review.  The CLI exits nonzero
exactly when a run produces a finding whose fingerprint is not in the
baseline.  The committed baseline (``results/staticcheck/baseline.json``)
is empty: the repo holds no known violations.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import pathlib


@dataclasses.dataclass(frozen=True)
class Finding:
    pass_name: str
    rule: str
    where: str
    message: str

    def fingerprint(self) -> str:
        h = hashlib.sha256(
            f"{self.pass_name}|{self.rule}|{self.where}".encode())
        return h.hexdigest()[:16]

    def render(self) -> str:
        return f"[{self.pass_name}/{self.rule}] {self.where}: {self.message}"

    def to_json(self) -> dict:
        return {"fingerprint": self.fingerprint(), "pass": self.pass_name,
                "rule": self.rule, "where": self.where,
                "message": self.message}


def load_baseline(path: pathlib.Path) -> set[str]:
    """Fingerprints accepted by the baseline file (empty set if absent)."""
    if not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {f["fingerprint"] for f in data.get("findings", [])}


def save_baseline(path: pathlib.Path, findings: list[Finding]) -> None:
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(
        {"findings": [f.to_json() for f in findings]},
        indent=2, sort_keys=True) + "\n")


def new_findings(findings: list[Finding],
                 baseline: set[str]) -> list[Finding]:
    """Findings not suppressed by the baseline, input order preserved."""
    return [f for f in findings if f.fingerprint() not in baseline]
