"""Seed/RNG discipline lint: an AST pass over ``src/repro``.

The engine's replay guarantees rest on one seed scheme
(:mod:`repro.engine.seeds`, constants shared through
:mod:`repro.core.prng`).  Any module that re-derives a stream from the
raw constants can silently desynchronize from the scheme when a constant
changes — the counter-wraparound bug class.  Rules:

* ``seed-constant`` — a numeric literal equal to one of the scheme's
  constants (7919, 1013, the order salt, the Knuth hash multiplier)
  anywhere outside the two modules that *define* them.  Call the
  ``engine.seeds`` helpers instead;
* ``prng-key-arith`` — ``PRNGKey(...)`` whose argument does arithmetic
  (``PRNGKey(seed + 3)``-style ad-hoc stream derivation); derived streams
  belong in ``engine/seeds.py`` where the scheme is pinned by tests;
* ``jit-host-nondeterminism`` — calls into Python ``random`` / ``time`` /
  ``datetime`` inside jit-reachable functions (decorated with
  ``jax.jit``/``pmap``, passed to ``jax.jit(...)``, or nested in either):
  host-side nondeterminism baked into a traced program is frozen at trace
  time on one host and breaks bit-replay on the next;
* ``sr-seed-reuse`` — two ``sr_seed``/``layer_seed``/``step_seed`` calls
  with identical literal arguments in one function: two stashes drawing
  the same SR stream correlate their rounding noise (the variance model
  assumes independence across layers);
* ``host-callback-tap`` — raw ``jax.debug.callback`` / ``pure_callback``
  / ``io_callback`` calls inside jit-reachable functions anywhere except
  the two sanctioned homes: the obs telemetry tap
  (``obs/quantstats.py``) and the offload callback host store
  (``offload/engine.py``).  An untracked host callback is invisible to
  the jaxpr byte audit and a bit-replay hazard — route through
  :func:`repro.obs.quantstats.tap`;
* ``obs-tap-dataflow`` — any ``tap(...)`` call inside the
  residual/stash dataflow modules (``engine/forward.py``,
  ``offload/engine.py``, ``offload/arena.py``): obs taps must observe
  training from a *separate* probe pass, never from inside the stash
  path, or obs-on jaxprs diverge from obs-off and the bit-identity gate
  is forfeit.
"""
from __future__ import annotations

import ast
import pathlib

from repro.engine import seeds as seedsmod
from repro.staticcheck.findings import Finding

PASS = "seed-lint"

#: The scheme's constants; literals equal to these are flagged elsewhere.
SEED_CONSTANTS = {
    seedsmod.SR_SEED_PRIME,
    seedsmod.LAYER_SEED_STRIDE,
    seedsmod.ORDER_SALT,
    int(seedsmod._PROBE_MULT),
}

#: Modules allowed to spell the constants: the scheme's definition sites.
ALLOWED_FILES = ("engine/seeds.py", "core/prng.py")

_HOST_MODULES = ("random", "time", "datetime")
_SEED_HELPERS = ("sr_seed", "layer_seed", "step_seed")

#: Host-callback spellings; jit-reachable calls outside the sanctioned
#: homes are findings.
_CALLBACK_NAMES = ("callback", "pure_callback", "io_callback")

#: The two modules allowed to spell a host callback in traced code: the
#: obs telemetry tap and the offload callback host store.
_CALLBACK_FILES = ("obs/quantstats.py", "offload/engine.py")

#: The residual/stash dataflow path: obs taps are banned here outright
#: (the offload store's callbacks are its transport, not obs taps).
_DATAFLOW_FILES = ("engine/forward.py", "offload/engine.py",
                   "offload/arena.py")


def _expr_names(node: ast.AST) -> set[str]:
    out = set()
    for n in ast.walk(node):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def _is_jit_wrapper(node: ast.AST) -> bool:
    """Does this decorator / call target express jax.jit or jax.pmap?"""
    return bool(_expr_names(node) & {"jit", "pmap", "shard_map"})


def _call_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _jitted_defs(tree: ast.Module) -> set[ast.AST]:
    """Function defs that are jit-reachable: jit/pmap-decorated, passed by
    name to a jit/pmap wrapper in this module, or nested inside either."""
    by_name = {}
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            by_name.setdefault(n.name, n)
    roots = set()
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_wrapper(d) for d in n.decorator_list):
                roots.add(n)
        elif isinstance(n, ast.Call) and _is_jit_wrapper(n.func):
            for arg in n.args:
                if isinstance(arg, ast.Name) and arg.id in by_name:
                    roots.add(by_name[arg.id])
    jitted = set()
    for root in roots:
        for n in ast.walk(root):
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                jitted.add(n)
    return jitted


def _literal_key(call: ast.Call) -> tuple | None:
    """Hashable identity of an all-literal argument list, else None."""
    vals = []
    for a in list(call.args) + [kw.value for kw in call.keywords]:
        if not isinstance(a, ast.Constant):
            return None
        vals.append(a.value)
    return (_call_name(call), tuple(vals))


def lint_source(src: str, filename: str) -> list[Finding]:
    try:
        tree = ast.parse(src, filename=filename)
    except SyntaxError as e:
        return [Finding(PASS, "syntax", f"{filename}:{e.lineno}", str(e))]
    out = []
    allowed = filename.endswith(ALLOWED_FILES)

    # seed-constant: raw numeric literals of the scheme outside its home
    if not allowed:
        for n in ast.walk(tree):
            if (isinstance(n, ast.Constant) and isinstance(n.value, int)
                    and not isinstance(n.value, bool)
                    and n.value in SEED_CONSTANTS):
                out.append(Finding(
                    PASS, "seed-constant", f"{filename}:{n.lineno}",
                    f"raw seed constant {n.value} re-derived outside "
                    "engine/seeds.py — use the seeds helpers so the "
                    "scheme stays single-sourced"))

    jitted = _jitted_defs(tree)
    for n in ast.walk(tree):
        if not isinstance(n, ast.Call):
            continue
        name = _call_name(n)
        # prng-key-arith: ad-hoc stream derivation at the PRNGKey call
        if name == "PRNGKey" and not allowed:
            for a in list(n.args) + [kw.value for kw in n.keywords]:
                if any(isinstance(sub, ast.BinOp) for sub in ast.walk(a)):
                    out.append(Finding(
                        PASS, "prng-key-arith", f"{filename}:{n.lineno}",
                        "PRNGKey argument does seed arithmetic inline; "
                        "derived streams belong in engine/seeds.py"))
                    break

    # jit-host-nondeterminism: host clock/PRNG calls inside traced code
    for fn in jitted:
        for n in ast.walk(fn):
            if (isinstance(n, ast.Call)
                    and isinstance(n.func, ast.Attribute)
                    and isinstance(n.func.value, ast.Name)
                    and n.func.value.id in _HOST_MODULES):
                out.append(Finding(
                    PASS, "jit-host-nondeterminism",
                    f"{filename}:{n.lineno}",
                    f"{n.func.value.id}.{n.func.attr}() inside "
                    f"jit-reachable '{fn.name}': host nondeterminism is "
                    "frozen at trace time and breaks bit-replay"))

    # host-callback-tap: raw host callbacks in traced code outside the
    # sanctioned homes
    if not filename.endswith(_CALLBACK_FILES):
        for fn in jitted:
            for n in ast.walk(fn):
                if (isinstance(n, ast.Call)
                        and _call_name(n) in _CALLBACK_NAMES):
                    out.append(Finding(
                        PASS, "host-callback-tap",
                        f"{filename}:{n.lineno}",
                        f"{_call_name(n)}() inside jit-reachable "
                        f"'{fn.name}': host callbacks in traced code "
                        "belong to repro.obs.quantstats.tap (telemetry) "
                        "or the offload callback store — an untracked "
                        "callback evades the jaxpr byte audit"))

    # obs-tap-dataflow: no obs taps on the residual/stash dataflow path
    if filename.endswith(_DATAFLOW_FILES):
        for n in ast.walk(tree):
            if isinstance(n, ast.Call) and _call_name(n) == "tap":
                out.append(Finding(
                    PASS, "obs-tap-dataflow", f"{filename}:{n.lineno}",
                    "obs tap() on the residual/stash dataflow path: "
                    "telemetry must run as a separate probe pass so "
                    "obs-on training jaxprs stay bit-identical to "
                    "obs-off"))

    # sr-seed-reuse: identical literal seed-helper calls in one function
    for n in ast.walk(tree):
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        seen: dict[tuple, int] = {}
        for c in ast.walk(n):
            if (isinstance(c, ast.Call)
                    and _call_name(c) in _SEED_HELPERS):
                key = _literal_key(c)
                if key is None:
                    continue
                if key in seen:
                    out.append(Finding(
                        PASS, "sr-seed-reuse", f"{filename}:{c.lineno}",
                        f"{key[0]}{key[1]} already drawn at line "
                        f"{seen[key]} of '{n.name}': reusing one SR "
                        "stream across stashes correlates their "
                        "rounding noise"))
                else:
                    seen[key] = c.lineno
    return out


def lint_file(path: pathlib.Path, root: pathlib.Path) -> list[Finding]:
    rel = path.relative_to(root).as_posix()
    return lint_source(path.read_text(), rel)


def run(root: pathlib.Path | None = None) -> list[Finding]:
    """Lint every module under ``src/repro`` (or an explicit tree)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parents[1]
    out = []
    for p in sorted(root.rglob("*.py")):
        out.extend(lint_file(p, root.parent))
    return out
