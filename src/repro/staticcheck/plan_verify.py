"""Symbolic plan/arena verifier: ExecutionPlan × graph × arch checks that
need no compilation.

Four layers of checks, all pure arithmetic over static plan state:

* **policy fields** — re-raise the engine dataclasses' own validation
  (:mod:`repro.engine.plan` names the offending ``policy.field=value`` in
  every message; :func:`verify_legacy_kwargs` surfaces them as findings);
* **cross-policy combinations** — the constraints
  :mod:`repro.engine.compile` enforces at compile time (mesh × arena,
  mesh × autoprec, mesh × fused='on', host offload under data
  parallelism, whole update groups, mesh divisors, obs-sourced autoprec
  calibration needing the telemetry channel enabled), checked here
  without building a single batch;
* **per-layer feasibility** — bit-width/word-alignment of every layer's
  quantization config (autoprec mixed-bit tuples included), RP
  divisibility, and ``fused='on'`` eligibility via the same
  :mod:`repro.core.backend` predicates the dispatch layer routes on;
* **arena layout** — every :class:`~repro.offload.arena.StashPlan`
  segment proven in-bounds and non-overlapping, its geometry re-derived
  and compared (ragged 1-bit mask tails must be word-aligned *ceil* — the
  historical ``// 8`` floor bug class).
"""
from __future__ import annotations

import math

from repro.core import backend
from repro.core import pack as packmod
from repro.engine.plan import ExecutionPlan
from repro.offload.arena import StashPlan, _stash_geometry
from repro.staticcheck.findings import Finding

PASS = "plan-verify"


def verify_legacy_kwargs(where: str = "kwargs", **kwargs) -> list[Finding]:
    """Validate a legacy kwarg spelling by building its plan; the policy
    dataclasses' field-named messages become the findings verbatim."""
    try:
        ExecutionPlan.from_legacy(**kwargs)
    except (ValueError, TypeError) as e:
        return [Finding(PASS, "policy-field", where, str(e))]
    return []


def _largest_mesh_divisor(n_parts: int, devices: int) -> int:
    """Mirror of :func:`repro.parallel.halo.graph_mesh`'s axis sizing:
    the largest divisor of ``n_parts`` not exceeding the device count."""
    return max(d for d in range(1, max(devices, 1) + 1) if n_parts % d == 0)


def verify_combination(plan: ExecutionPlan, *, devices: int = 1,
                       where: str = "plan") -> list[Finding]:
    """The cross-policy rules ``compile_plan`` would reject at runtime."""
    out = []
    sp = plan.sampling

    def bad(rule, msg):
        out.append(Finding(PASS, rule, where, msg))

    if sp.kind == "mesh":
        if plan.stash.kind != "tensor":
            bad("mesh-stash",
                f"stash.kind={plan.stash.kind!r} is incompatible with "
                "sampling.kind='mesh' (mesh devices stash per-tensor "
                "residuals; the features are what is host-resident)")
        if plan.precision.kind != "fixed":
            bad("mesh-precision",
                f"precision.kind={plan.precision.kind!r} is incompatible "
                "with sampling.kind='mesh' (calibrate autoprec on a "
                "partition plan and pass the allocated cfg)")
        if plan.kernel.fused == "on":
            bad("mesh-fused",
                "kernel.fused='on' is incompatible with "
                "sampling.kind='mesh' (the mesh forward composes the "
                "per-op stack; use 'auto'/'off')")
        m = _largest_mesh_divisor(sp.n_parts, devices)
        if devices > 1 and sp.n_parts > 1 and m == 1:
            bad("mesh-divisor",
                f"sampling.n_parts={sp.n_parts} shares no divisor with "
                f"the {devices}-device mesh: the graph axis degenerates "
                "to m=1 (sequential rounds, no mesh parallelism)")
    pp = plan.precision
    if (pp.kind == "autoprec" and pp.calibration == "obs"
            and not (plan.obs.enabled and plan.obs.quant_stats)):
        bad("obs-calibration",
            "precision.calibration='obs' sources sensitivities from the "
            "quant-health telemetry channel; the plan needs "
            "obs=ObsPolicy(enabled=True, quant_stats=True)")
    if sp.kind == "partition":
        group = max(devices, 1) * sp.grad_accum
        if sp.n_parts % group:
            bad("update-group",
                f"sampling.n_parts={sp.n_parts} must be a multiple of "
                f"dp*grad_accum={devices}*{sp.grad_accum}={group} "
                "(whole update groups per epoch)")
        if plan.stash.offload in ("host", "pinned-paged") and devices > 1:
            bad("offload-dp",
                f"stash.placement={plan.stash.placement!r} needs an "
                f"unsharded run (dp_size==1); got dp={devices}")
    return out


def verify_layers(plan: ExecutionPlan, cfg, in_dim: int, live_nodes: int,
                  where: str = "plan") -> list[Finding]:
    """Bit-width / alignment / fused-eligibility feasibility per layer."""
    from repro.graph.models import _dims

    out = []
    try:
        per = cfg.layer_compression()
    except ValueError as e:
        return [Finding(PASS, "layer-widths", where, str(e))]
    dims = _dims(cfg, in_dim)
    for li, (d_in, comp) in enumerate(zip(dims[:-1], per)):
        if comp is None:
            continue
        lin_in = d_in * (2 if cfg.arch == "sage" else 1)
        lwhere = f"{where}/layer{li}"
        reason = backend.quant_kernel_unsupported(comp.bits, comp.group_size,
                                                 comp.levels())
        if reason is not None:
            out.append(Finding(PASS, "bit-alignment", lwhere, reason))
        if comp.rp_ratio > 1 and lin_in % comp.rp_ratio:
            out.append(Finding(
                PASS, "rp-divisibility", lwhere,
                f"stash width {lin_in} is not divisible by "
                f"rp_ratio={comp.rp_ratio} (compress would assert)"))
        if plan.kernel.fused == "on":
            reason = backend.fused_unsupported((live_nodes, lin_in),
                                               comp.bits, comp.group_size,
                                               comp.levels())
            if reason is None and comp.rp_ratio > 1:
                reason = (f"rp_ratio={comp.rp_ratio} projects before "
                          "quantization; the fused epilogue quantizes the "
                          "matmul operand itself")
            if reason is not None:
                out.append(Finding(
                    PASS, "fused-eligibility", lwhere,
                    f"kernel.fused='on' cannot run this layer: {reason}"))
    return out


def verify_stash_plan(splan: StashPlan,
                      where: str = "stash-plan") -> list[Finding]:
    """Prove every arena segment in-bounds, non-overlapping, and sized to
    its re-derived geometry."""
    out = []
    spans: dict[str, list[tuple[int, int, str]]] = {"u32": [], "f32": []}
    limits = {"u32": splan.u32_words, "f32": splan.f32_elems}
    for lp in splan.layers:
        lwhere = f"{where}/layer{lp.index}"
        for name, seg in (("packed", lp.packed), ("rp_seed", lp.rp_seed),
                          ("zero", lp.zero), ("rng", lp.rng),
                          ("raw", lp.raw), ("mask", lp.mask)):
            if seg is None:
                continue
            swhere = f"{lwhere}/{name}"
            if seg.arena not in spans:
                out.append(Finding(PASS, "arena-bounds", swhere,
                                   f"unknown arena {seg.arena!r}"))
                continue
            if seg.offset < 0 or seg.offset + seg.size > limits[seg.arena]:
                out.append(Finding(
                    PASS, "arena-bounds", swhere,
                    f"[{seg.offset}, {seg.offset + seg.size}) lies outside "
                    f"the {limits[seg.arena]}-word {seg.arena} arena"))
            spans[seg.arena].append(
                (seg.offset, seg.offset + seg.size, swhere))
        if lp.cfg is not None:
            try:
                proj_shape, n_blocks, wpb = _stash_geometry(lp.shape, lp.cfg)
            except AssertionError as e:
                out.append(Finding(PASS, "rp-divisibility", lwhere, str(e)))
                continue
            if (lp.proj_shape, lp.n_blocks, lp.words_per_block) != \
                    (proj_shape, n_blocks, wpb):
                out.append(Finding(
                    PASS, "arena-geometry", lwhere,
                    f"planned geometry (proj={lp.proj_shape}, "
                    f"blocks={lp.n_blocks}x{lp.words_per_block}w) does not "
                    f"match the config's (proj={proj_shape}, "
                    f"blocks={n_blocks}x{wpb}w)"))
            for name, seg, want in (("packed", lp.packed, n_blocks * wpb),
                                    ("rp_seed", lp.rp_seed, 1),
                                    ("zero", lp.zero, n_blocks),
                                    ("rng", lp.rng, n_blocks)):
                if seg is None or seg.size != want:
                    got = "absent" if seg is None else f"{seg.size} words"
                    out.append(Finding(
                        PASS, "arena-geometry", f"{lwhere}/{name}",
                        f"segment must span {want} words, got {got}"))
        else:
            numel = math.prod(lp.shape)
            if lp.raw is None or lp.raw.size != numel:
                got = "absent" if lp.raw is None else f"{lp.raw.size} elems"
                out.append(Finding(
                    PASS, "arena-geometry", f"{lwhere}/raw",
                    f"raw f32 stash of shape {lp.shape} must span {numel} "
                    f"elements, got {got}"))
        if lp.mask_elems:
            want = packmod.packed_len(lp.mask_elems, 1)
            if lp.mask is None or lp.mask.size != want:
                got = "absent" if lp.mask is None else f"{lp.mask.size}"
                out.append(Finding(
                    PASS, "mask-alignment", f"{lwhere}/mask",
                    f"1-bit ReLU mask over {lp.mask_elems} elements needs "
                    f"{want} word-aligned uint32 words (ceil), got {got} — "
                    "a floor-divided ragged tail drops the partial word"))
        elif lp.mask is not None:
            out.append(Finding(PASS, "arena-geometry", f"{lwhere}/mask",
                               "mask segment present but mask_elems == 0"))
    for arena, sp in spans.items():
        sp.sort()
        for (a0, a1, wa), (b0, b1, wb) in zip(sp[:-1], sp[1:]):
            if b0 < a1:
                out.append(Finding(
                    PASS, "arena-overlap", wb,
                    f"[{b0}, {b1}) overlaps {wa} [{a0}, {a1}) in the "
                    f"{arena} arena"))
    return out


def verify_kv_layout(layout, where: str = "kv-layout",
                     segments=None) -> list[Finding]:
    """Prove a serving :class:`~repro.serving.kvcache.KVPageLayout`'s
    page map sound: every (layer, page) segment word-aligned to the quant
    packing, sized to the re-derived geometry, in-bounds of the pool's
    flat word space, and non-overlapping.  ``segments`` defaults to the
    layout's own map; tests inject crafted maps to pin each rule."""
    out = []
    if layout.quantized:
        vals_per_word = 32 // layout.bits
        if layout.group_size % vals_per_word:
            out.append(Finding(
                PASS, "kv-page-alignment", where,
                f"group_size={layout.group_size} does not pack whole uint32 "
                f"words at bits={layout.bits} ({vals_per_word} values/word); "
                "a token's trailing block would straddle a word"))
        want_wpb = packmod.packed_len(layout.group_size, layout.bits)
        want_wpp = layout.page_tokens * layout.blocks_per_token * want_wpb
    else:
        want_wpp = layout.page_tokens * layout.elems_per_token * 2 // 4
    if layout.words_per_page != want_wpp:
        out.append(Finding(
            PASS, "kv-page-geometry", where,
            f"words_per_page={layout.words_per_page} does not match the "
            f"re-derived {want_wpp} (page_tokens={layout.page_tokens} x "
            f"{layout.blocks_per_token} blocks/token at bits={layout.bits})"))
    if layout.elems_per_token % max(layout.group_size, 1):
        out.append(Finding(
            PASS, "kv-page-geometry", where,
            f"group_size={layout.group_size} does not divide the "
            f"{layout.elems_per_token}-element token row; a quant block "
            "would straddle tokens"))
    total = layout.total_words
    spans = []
    segs = list(layout.page_segments()) if segments is None else segments
    for li, p, off, size in segs:
        swhere = f"{where}/layer{li}/page{p}"
        if size != layout.words_per_page:
            out.append(Finding(
                PASS, "kv-page-geometry", swhere,
                f"segment spans {size} words, layout says "
                f"{layout.words_per_page} per page"))
        if off < 0 or off + size > total:
            out.append(Finding(
                PASS, "kv-page-bounds", swhere,
                f"[{off}, {off + size}) lies outside the {total}-word pool"))
        spans.append((off, off + size, swhere))
    spans.sort()
    for (a0, a1, wa), (b0, b1, wb) in zip(spans[:-1], spans[1:]):
        if b0 < a1:
            out.append(Finding(
                PASS, "kv-page-overlap", wb,
                f"[{b0}, {b1}) overlaps {wa} [{a0}, {a1})"))
    return out


def verify_kv_matrix() -> list[Finding]:
    """KV-page soundness across every supported serving cache width, over
    the canonical smoke decode geometry."""
    from repro.serving.kvcache import KV_BITS, KVCacheConfig, plan_kv_layout

    out = []
    for bits in KV_BITS:
        layout = plan_kv_layout(
            KVCacheConfig(bits=bits, group_size=64, page_tokens=16,
                          n_pages=64),
            n_layers=2, n_kv_heads=4, d_head=16)
        out.extend(verify_kv_layout(layout, where=f"kv-layout/bits{bits}"))
    return out


def verify_plan(plan: ExecutionPlan, cfg, in_dim: int, n_nodes: int, *,
                devices: int = 1, where: str | None = None) -> list[Finding]:
    """All symbolic checks for one (plan, model, graph-size) triple."""
    from repro.graph.sampling import _bucket
    from repro.offload.gnn import plan_gnn_stashes

    where = where or plan.describe()
    out = verify_combination(plan, devices=devices, where=where)
    sp = plan.sampling
    if sp.kind == "full":
        live = n_nodes
    else:
        if sp.n_parts > n_nodes:
            out.append(Finding(
                PASS, "partition-count", where,
                f"sampling.n_parts={sp.n_parts} exceeds the graph's "
                f"{n_nodes} nodes"))
            return out
        live = _bucket(-(-n_nodes // sp.n_parts), sp.node_multiple)
    out += verify_layers(plan, cfg, in_dim, live, where)
    if not any(x.rule in ("rp-divisibility", "layer-widths") for x in out):
        out += verify_stash_plan(plan_gnn_stashes(cfg, in_dim, live),
                                 where=where)
    return out
