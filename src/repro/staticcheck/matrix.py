"""The representative plan matrix every symbolic pass runs over.

One small, fixed GNN geometry (SAGE, 256 nodes, one hidden layer) crossed
with the engine's policy axes:

* sampling — ``full`` / ``partition`` ("batched") / ``mesh``;
* precision — ``fixed`` (one INT2 config broadcast to every layer) or
  ``autoprec`` (a representative *solved* mixed-bit tuple — the audit
  checks the widths an allocation would stash, not the allocator);
* stash — per-tensor, or a pooled arena at ``device`` / ``host`` /
  ``pinned-paged``;
* fused — ``on`` / ``off``;

plus one random-projection arm (``rp_ratio=8``, the paper's D/R).
Combinations the compiler rejects (mesh × arena, mesh × autoprec,
mesh × fused='on' — see :mod:`repro.engine.compile`) are skipped, so the
matrix enumerates exactly the plans a training run could execute.

Every config here is **all-layers-compressed**: the jaxpr audit
cross-checks its byte ledger against ``activation_memory_report``'s
``compressed_bytes`` model, and an uncompressed hidden layer is the one
case where the two models legitimately diverge (the report charges the
f32 ReLU context, the stash plan a 1-bit mask — the engine never saves
the f32 context).  Uncompressed-layer stashes are still audited
structurally through the raw-f32 arena segments of the layer plans.
"""
from __future__ import annotations

import dataclasses
import itertools

from repro.core.compressor import CompressionConfig
from repro.engine.plan import (ExecutionPlan, KernelPolicy, PrecisionPolicy,
                               SamplingPolicy, StashPolicy)
from repro.graph.models import GNNConfig

#: Canonical audit geometry.  Dimensions are chosen fused-eligible
#: (every layer's linear input width is a multiple of the group size) so
#: the ``fused='on'`` arms trace the epilogue-quantized path for real.
N_NODES = 256
IN_DIM = 32
N_PARTS = 4
NODE_MULTIPLE = 64
HIDDEN = (64,)
N_CLASSES = 8

_FIXED = CompressionConfig(bits=2, group_size=64)
_MIXED = (CompressionConfig(bits=1, group_size=64),
          CompressionConfig(bits=4, group_size=64))
_RP = CompressionConfig(bits=2, group_size=64, rp_ratio=8)


def gnn_cfg(compression) -> GNNConfig:
    return GNNConfig(arch="sage", hidden=HIDDEN, n_classes=N_CLASSES,
                     compression=compression)


@dataclasses.dataclass(frozen=True)
class AuditCase:
    """One plan-matrix cell: the plan plus the concrete layer widths the
    forward would stash under it."""

    key: str
    plan: ExecutionPlan
    cfg: GNNConfig
    n_nodes: int = N_NODES
    in_dim: int = IN_DIM

    @property
    def live_nodes(self) -> int:
        """Rows live at once: the full graph, or one padded batch (the
        same ceil-then-bucket model ``activation_memory_report`` uses)."""
        from repro.graph.sampling import _bucket

        sp = self.plan.sampling
        if sp.kind == "full":
            return self.n_nodes
        return _bucket(-(-self.n_nodes // sp.n_parts), sp.node_multiple)


def audit_matrix() -> list[AuditCase]:
    """Every valid cell of the plan matrix, stable key order."""
    samplings = [
        ("full", SamplingPolicy()),
        ("batched", SamplingPolicy(kind="partition", n_parts=N_PARTS,
                                   node_multiple=NODE_MULTIPLE)),
        ("mesh", SamplingPolicy(kind="mesh", n_parts=N_PARTS,
                                node_multiple=NODE_MULTIPLE)),
    ]
    precisions = [
        ("fixed", PrecisionPolicy(), _FIXED),
        ("autoprec", PrecisionPolicy(kind="autoprec", bit_budget=2.5),
         _MIXED),
    ]
    stashes = [
        ("tensor", StashPolicy()),
        ("device", StashPolicy(kind="arena", placement="device")),
        ("host", StashPolicy(kind="arena", placement="host")),
        ("paged", StashPolicy(kind="arena", placement="pinned-paged")),
    ]
    cases = []
    for (sk, samp), (pk, prec, comp), (tk, stash), fz in itertools.product(
            samplings, precisions, stashes, ("on", "off")):
        if sk == "mesh" and (tk != "tensor" or pk != "fixed" or fz == "on"):
            continue  # combinations _CompiledMesh rejects
        plan = ExecutionPlan(sampling=samp, precision=prec, stash=stash,
                             kernel=KernelPolicy(fused=fz))
        cases.append(AuditCase(key=f"{sk}/{pk}/{tk}/fused-{fz}", plan=plan,
                               cfg=gnn_cfg(comp)))
    cases.append(AuditCase(
        key="full/fixed-rp8/tensor/fused-off",
        plan=ExecutionPlan(kernel=KernelPolicy(fused="off")),
        cfg=gnn_cfg(_RP)))
    return cases
