"""jaxpr residual-leak audit: prove, per plan, that every saved-for-backward
byte is planned.

The engine's whole-network ``custom_vjp``
(:func:`repro.engine.forward._build`) keeps its forward rule reachable
after ``defvjp`` (``f.fwd``), so the exact residual set a compiled step
saves to HBM can be read off statically: trace ``f.fwd`` to a closed
jaxpr over :class:`jax.ShapeDtypeStruct` arguments and walk the output
vars after the primal.  Each residual leaf is classified:

* **pass-through** — the outvar is an invar (params, edge lists,
  aggregation weights, the node mask): no new HBM, and the donation
  contract holds (donated buffers reappear only as pass-throughs the
  backward consumes within the step);
* **planned** — its aval matches one entry of the
  :class:`~repro.offload.arena.StashPlan`-derived expectation multiset
  (per-tensor fields, the pooled arena pair, or the callback store's
  ticket+key under the host mechanisms);
* **leak** — an unmatched float residual reaching HBM (rule
  ``residual-leak``): an activation escaping the quantizer, the exact
  failure mode EXACT/GACT-style compressed training must exclude.
  Unmatched non-scalar integer residuals are ``unplanned-residual``.

Host-offloaded plans route bytes through ``jax.pure_callback`` instead of
residuals; the audit sums every callback's array operands (the
``host_put`` payload) as the ledger.  The per-plan byte ledger is then
cross-checked against ``activation_memory_report`` — the model the
benchmarks and the paper's Table-1 columns read — and any divergence
beyond 1% is a ``ledger-mismatch`` finding.

Mesh plans are audited at per-device geometry through the same unified
forward: :func:`repro.engine.forward.mesh_stash_plan` *is*
``plan_gnn_stashes`` at the partition's padded node count (halo rows
stash nothing), and the mesh per-op stack is gated bit-identical to the
engine forward, so the per-device residual set coincides.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace

import jax
import jax.numpy as jnp

from repro.offload.arena import StashPlan
from repro.staticcheck.findings import Finding
from repro.staticcheck.matrix import AuditCase, audit_matrix

PASS = "jaxpr-audit"

#: Relative tolerance of the ledger ↔ memory-report cross-check.  The two
#: models agree byte-for-byte by construction; 1% is headroom, not slack.
LEDGER_RTOL = 0.01

_EDGES = 512  # residual geometry is edge-count independent


@dataclasses.dataclass
class AuditResult:
    key: str
    findings: list[Finding]
    ledger_bytes: int
    report_bytes: int

    def to_json(self) -> dict:
        return {"key": self.key, "ledger_bytes": self.ledger_bytes,
                "report_bytes": self.report_bytes,
                "findings": [f.to_json() for f in self.findings]}

    @classmethod
    def from_json(cls, d: dict) -> "AuditResult":
        return cls(key=d["key"],
                   findings=[Finding(f["pass"], f["rule"], f["where"],
                                     f["message"])
                             for f in d["findings"]],
                   ledger_bytes=d["ledger_bytes"],
                   report_bytes=d["report_bytes"])


def expected_residuals(splan: StashPlan,
                       mechanism: str) -> list[tuple[str, tuple, str]]:
    """(dtype, shape, label) multiset the plan says the residual holds."""
    if mechanism == "device":
        return [("uint32", (splan.u32_words,), "u32-arena"),
                ("float32", (splan.f32_elems,), "f32-arena")]
    if mechanism == "callback":
        # bytes live in the host store; the residual is the chained ticket
        # (the forward key rides along as a pass-through of the seed invar)
        return [("uint32", (), "ticket")]
    # "tensor" (and "memkind", whose residual is the same fields as
    # host-kind arrays — unreachable on CPU hosts)
    exp = []
    for lp in splan.layers:
        tag = f"layer{lp.index}"
        if lp.cfg is not None:
            exp += [("uint32", (lp.n_blocks, lp.words_per_block),
                     f"{tag}/packed"),
                    ("float32", (lp.n_blocks,), f"{tag}/zero"),
                    ("float32", (lp.n_blocks,), f"{tag}/rng"),
                    ("uint32", (), f"{tag}/rp_seed")]
        else:
            exp.append(("float32", tuple(lp.shape), f"{tag}/raw"))
        if lp.mask is not None:
            exp.append(("uint32", (1, lp.mask.size), f"{tag}/mask"))
    return exp


def _iter_eqns(jaxpr):
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in (v if isinstance(v, (list, tuple)) else (v,)):
                inner = getattr(sub, "jaxpr", None)
                if inner is not None and hasattr(inner, "eqns"):
                    yield from _iter_eqns(inner)
                elif hasattr(sub, "eqns"):
                    yield from _iter_eqns(sub)


def _nbytes(aval) -> int:
    return int(aval.size) * jnp.dtype(aval.dtype).itemsize


def audit_forward(fwd, example_args, splan: StashPlan, mechanism: str,
                  where: str) -> tuple[list[Finding], int]:
    """Audit one forward rule; returns (findings, ledger_bytes)."""
    closed, out_shape = jax.make_jaxpr(fwd, return_shape=True)(*example_args)
    jx = closed.jaxpr
    n_primal = len(jax.tree.leaves(out_shape[0]))
    passthrough = set(jx.invars) | set(jx.constvars)

    findings: list[Finding] = []
    expected = expected_residuals(splan, mechanism)
    remaining = list(expected)
    ledger = 0
    for leaf in jx.outvars[n_primal:]:
        if isinstance(leaf, jax.core.Literal) or leaf in passthrough:
            continue  # pass-through residual: no new HBM
        aval = leaf.aval
        sig = (str(jnp.dtype(aval.dtype)), tuple(aval.shape))
        hit = next((e for e in remaining if (e[0], e[1]) == sig), None)
        if hit is not None:
            remaining.remove(hit)
            if mechanism != "callback":  # the ticket is bookkeeping, not
                ledger += _nbytes(aval)  # saved activation bytes
            continue
        if jnp.issubdtype(aval.dtype, jnp.floating):
            ledger += _nbytes(aval)
            findings.append(Finding(
                PASS, "residual-leak", where,
                f"{sig[0]}{list(sig[1])} residual ({_nbytes(aval)} bytes) "
                "reaches HBM but is not accounted for in the StashPlan — "
                "an activation escaped the quantizer"))
        elif int(aval.size) > 1:
            ledger += _nbytes(aval)
            findings.append(Finding(
                PASS, "unplanned-residual", where,
                f"{sig[0]}{list(sig[1])} residual ({_nbytes(aval)} bytes) "
                "is not in the StashPlan"))
        # unmatched integer scalars (stray seeds) are byte-negligible
    for dtype, shape, label in remaining:
        findings.append(Finding(
            PASS, "missing-stash", f"{where}/{label}",
            f"planned {dtype}{list(shape)} stash never appears in the "
            "residual — the backward would read unwritten state"))
    if mechanism == "callback":
        # planned bytes crossed to the host store through pure_callback;
        # each host_put's operands after (key, ticket) are the payload
        for eqn in _iter_eqns(jx):
            if eqn.primitive.name == "pure_callback":
                ledger += sum(_nbytes(v.aval) for v in eqn.invars[2:]
                              if not isinstance(v, jax.core.Literal))
    return findings, ledger


def _example_args(cfg, in_dim: int, n_nodes: int):
    from repro.graph.models import _dims

    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    sds = jax.ShapeDtypeStruct
    mult = 2 if cfg.arch == "sage" else 1
    dims = _dims(cfg, in_dim)
    params = [{"w": sds((d_in * mult, d_out), f32), "b": sds((d_out,), f32)}
              for d_in, d_out in zip(dims[:-1], dims[1:])]
    return (params, sds((n_nodes, in_dim), f32), sds((_EDGES,), i32),
            sds((_EDGES,), i32), sds((_EDGES,), f32), sds((_EDGES,), f32),
            sds((), u32), sds((n_nodes,), f32))


def _report_bytes(case: AuditCase) -> int:
    from repro.graph.train import activation_memory_report

    g = SimpleNamespace(n_feats=case.in_dim, n_nodes=case.n_nodes)
    rep = activation_memory_report(g, case.cfg, plan=case.plan)
    sp = case.plan.sampling
    if sp.kind == "full":
        return rep.get("compressed_bytes", rep["fp32_bytes"])
    sub = rep["mesh" if sp.kind == "mesh" else "batched"]
    return sub["peak_saved_bytes"]


def audit_case(case: AuditCase) -> AuditResult:
    from repro.engine.forward import TENSOR_STASH, _build
    from repro.offload.engine import resolve_stash
    from repro.offload.gnn import plan_gnn_stashes

    # the mesh forward stashes per-device local rows only: audit the
    # unified forward at per-partition geometry (see module docstring)
    stash = (TENSOR_STASH if case.plan.sampling.kind == "mesh"
             else case.plan.stash)
    live = case.live_nodes
    splan = plan_gnn_stashes(case.cfg, case.in_dim, live)
    mechanism = resolve_stash(stash.kind, stash.placement)
    fwd = _build(case.cfg, splan, stash, case.plan.kernel.fused).fwd
    findings, ledger = audit_forward(
        fwd, _example_args(case.cfg, case.in_dim, live), splan, mechanism,
        where=case.key)
    report = _report_bytes(case)
    if report and abs(ledger - report) > LEDGER_RTOL * report:
        findings.append(Finding(
            PASS, "ledger-mismatch", case.key,
            f"jaxpr residual ledger ({ledger} bytes) diverges from "
            f"activation_memory_report ({report} bytes) by more than "
            f"{LEDGER_RTOL:.0%}"))
    return AuditResult(key=case.key, findings=findings,
                       ledger_bytes=ledger, report_bytes=report)


def run(cases: list[AuditCase] | None = None) -> list[AuditResult]:
    return [audit_case(c) for c in (audit_matrix() if cases is None
                                    else cases)]
