"""Unused-symbol sweep over ``src/repro`` (the CLI's opt-in ``--dead-code``).

A module-level function or class in ``src/repro`` is *dead* when nothing
anywhere in the repo — source, tests, benchmarks, scripts, examples —
references it: not by name inside its own module (helpers a module still
calls are alive), not through an import (resolved per defining module, so
two modules exporting the same name are tracked separately), not through
a module-alias attribute access (``from repro.offload import engine as
eng; eng.make_writer``), and not through the engine's lazy-export pattern
(a dict literal mapping ``"symbol" -> "module.path"`` strings, PEP 562
``__getattr__`` dispatch).

``__init__.py`` re-export imports are deliberately *transparent*: a shim
kept importable only by its package's ``__init__`` is exactly the dead
code this sweep exists to surface, so a re-export counts as a use only
when the package-level name is itself referenced somewhere.

The sweep is a reviewer aid, not a gate — it runs only under
``--dead-code`` and reports findings for a human to delete (or baseline,
for symbols kept intentionally as public API).
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from repro.staticcheck.findings import Finding

PASS = "dead-code"

#: Names never reported: entry points and protocol methods looked up
#: implicitly (by python itself, pytest, or console runners).
IMPLICIT_USES = {"main", "__getattr__", "__dir__"}

#: Reference-scan roots relative to the repo root.
SCAN_DIRS = ("src", "tests", "benchmarks", "scripts", "examples")


@dataclasses.dataclass
class Symbol:
    module: str            # dotted module defining it
    name: str
    lineno: int
    rel: str               # file path relative to the repo root


def _module_of(path: pathlib.Path, src_root: pathlib.Path) -> str:
    rel = path.relative_to(src_root).with_suffix("")
    parts = list(rel.parts)
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _resolve_from(node: ast.ImportFrom, module: str) -> str | None:
    """Absolute module an ``ImportFrom`` names (relative imports resolved
    against the importing module)."""
    if node.level == 0:
        return node.module
    base = module.split(".")
    # level=1 from a module file strips the module leaf; each extra level
    # strips one package
    base = base[:len(base) - node.level]
    if node.module:
        base.append(node.module)
    return ".".join(base) if base else None


def collect_symbols(src_root: pathlib.Path,
                    repo_root: pathlib.Path) -> list[Symbol]:
    syms = []
    for path in sorted(src_root.rglob("*.py")):
        module = _module_of(path, src_root.parent)
        tree = ast.parse(path.read_text(), filename=str(path))
        for n in tree.body:
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.ClassDef)):
                if n.name.startswith("__") or n.name in IMPLICIT_USES:
                    continue
                syms.append(Symbol(module=module, name=n.name,
                                   lineno=n.lineno,
                                   rel=str(path.relative_to(repo_root))))
    return syms


def _scan_file(path: pathlib.Path, module: str | None, is_init: bool,
               uses: set[tuple[str | None, str]],
               reexports: list[tuple[str, str, str, str]]) -> None:
    """Record (module, name) uses from one file.

    ``uses`` entries with ``module=None`` are *unresolved* name uses (bare
    ``Name`` loads and attribute accesses through non-module values) —
    they match a symbol of that name in any module.  ``reexports`` rows
    are ``(pkg, name, src_module, src_name)`` aliases recorded by
    ``__init__`` re-export imports.
    """
    try:
        tree = ast.parse(path.read_text(), filename=str(path))
    except SyntaxError:
        return
    alias_to_module: dict[str, str] = {}
    imported_syms: dict[str, tuple[str, str]] = {}
    for n in ast.walk(tree):
        if isinstance(n, ast.Import):
            for a in n.names:
                alias_to_module[a.asname or a.name.split(".")[0]] = \
                    a.name if a.asname else a.name.split(".")[0]
        elif isinstance(n, ast.ImportFrom):
            src = _resolve_from(n, module) if module else n.module
            if src is None:
                continue
            for a in n.names:
                if a.name == "*":
                    continue
                local = a.asname or a.name
                if is_init and module:
                    # __init__ re-export: transparent — alias, not a use
                    reexports.append((module, local, src, a.name))
                else:
                    uses.add((src, a.name))
                    # `from pkg import mod` also binds a module alias
                    alias_to_module[local] = f"{src}.{a.name}"
                imported_syms[local] = (src, a.name)
    for n in ast.walk(tree):
        if isinstance(n, ast.Attribute) and isinstance(n.value, ast.Name):
            mod = alias_to_module.get(n.value.id)
            if mod is not None:
                uses.add((mod, n.attr))
            else:
                uses.add((None, n.attr))
        elif isinstance(n, ast.Name) and not isinstance(n.ctx, ast.Store):
            # bare name load: a use of whatever it was imported as, or an
            # unresolved use inside the defining module itself
            if n.id in imported_syms and not is_init:
                uses.add(imported_syms[n.id])
            else:
                uses.add((None, n.id))
        elif isinstance(n, ast.Dict):
            # lazy-export pattern: {"symbol": "module.path", ...}
            for k, v in zip(n.keys, n.values):
                if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                        and isinstance(v, ast.Constant)
                        and isinstance(v.value, str) and "." in v.value):
                    uses.add((v.value, k.value))
        elif (isinstance(n, ast.Call) and isinstance(n.func, ast.Name)
                and n.func.id == "getattr" and len(n.args) >= 2
                and isinstance(n.args[1], ast.Constant)
                and isinstance(n.args[1].value, str)):
            uses.add((None, n.args[1].value))


def sweep(repo_root: pathlib.Path | None = None) -> list[Finding]:
    if repo_root is None:
        repo_root = pathlib.Path(__file__).resolve().parents[3]
    src_root = repo_root / "src" / "repro"
    symbols = collect_symbols(src_root, repo_root)
    uses: set[tuple[str | None, str]] = set()
    reexports: list[tuple[str, str, str, str]] = []
    for d in SCAN_DIRS:
        base = repo_root / d
        if not base.exists():
            continue
        for path in sorted(base.rglob("*.py")):
            module = None
            if d == "src":
                module = _module_of(path, src_root.parent)
            _scan_file(path, module, path.name == "__init__.py",
                       uses, reexports)

    # close re-export aliases: a *resolved* use of the package-level name
    # is a use of the re-exported source symbol (one hop is enough for
    # this tree; unresolved bare-name uses already match by name below)
    closed = set(uses)
    for pkg, local, src, name in reexports:
        if (pkg, local) in uses:
            closed.add((src, name))
    # a bare-name use only counts within non-init files; re-exported
    # names still need a package-level reference
    resolved_names = {(m, n) for (m, n) in closed if m is not None}
    unresolved = {n for (m, n) in closed if m is None}

    out = []
    for s in symbols:
        if (s.module, s.name) in resolved_names:
            continue
        if s.name in unresolved:
            continue
        out.append(Finding(
            PASS, "unused-symbol", f"{s.rel}:{s.lineno}",
            f"{s.module}.{s.name} is referenced nowhere in "
            f"{'/'.join(SCAN_DIRS)} (re-export imports are transparent); "
            "delete it or baseline it as intentional API"))
    return out
