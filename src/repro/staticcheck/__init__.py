"""repro.staticcheck — compression-invariant static analysis.

Four gating passes (``python -m repro.staticcheck``) plus an opt-in
dead-code sweep:

* :mod:`~repro.staticcheck.jaxpr_audit` — trace every plan in the
  representative matrix to a closed jaxpr and prove no activation bytes
  reach HBM outside the :class:`~repro.offload.arena.StashPlan`, with a
  byte ledger cross-checked against ``activation_memory_report``;
* :mod:`~repro.staticcheck.plan_verify` — symbolic ExecutionPlan × graph
  × arch feasibility (policy fields, cross-policy combinations, layer
  bit-alignment, arena segment bounds/overlap) without compiling;
* :mod:`~repro.staticcheck.kernel_contracts` — declarative pre/post
  conditions for ``fused_matmul`` / ``quant_blockwise`` / ``rp_matmul``
  over every persisted autotune-cache entry;
* :mod:`~repro.staticcheck.seed_lint` — AST lint for seed/RNG discipline
  (raw scheme constants, ad-hoc PRNGKey arithmetic, host nondeterminism
  in jitted code, SR-stream reuse);
* :mod:`~repro.staticcheck.deadcode` — unused-symbol sweep
  (``--dead-code``).
"""
from repro.staticcheck.findings import Finding, load_baseline, new_findings

__all__ = ["Finding", "load_baseline", "new_findings"]
