"""Declarative pre/post-conditions for the compression kernels, evaluated
against every persisted autotune entry.

Each :class:`Contract` states one invariant the Pallas kernels assume and
checks it over a parsed ``results/autotune/fused_tiles.json`` entry
(``{kind}/{m}x{d}x{n}/b{bits}/g{group}/{backend}`` → ``(t0, t1)`` tiles):

* ``fused_matmul`` forward — the row tile owns whole quantization blocks
  (``tm % row_tile_step(d, G) == 0``, the same legality
  :func:`repro.kernels.autotune.fwd_candidates` enumerates), tiles stay
  inside the (step-padded) operand, and the tile working set fits the
  per-core VMEM budget;
* ``fused_matmul`` backward — the row tile divides ``m`` exactly (the
  fixed-order tree reduction needs equal splits) and owns whole blocks;
  the single-tile ``tile_rows == m`` configuration is VMEM-exempt by
  design (it is the bit-exact fallback, never auto-picked over budget);
* ``quant_blockwise`` — the base kernel preconditions (bits divides 32,
  the pack width divides the group, VM level tables fit the unrolled
  compare/select chain), via the one predicate the dispatch layer routes
  on (:func:`repro.core.backend.quant_kernel_unsupported`);
* ``rp_matmul`` — the projection ratio divides the stash width
  (``compress`` asserts this at trace time; off-grid tile shapes are an
  allowed jnp fallback, not a violation).

A cache entry violating a contract means the autotuner persisted tiles a
kernel launch would miscompute or OOM on — exactly the class of bug that
only surfaces on real TPU hardware otherwise.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
from typing import Callable

from repro.core import backend
from repro.kernels.autotune import VMEM_BUDGET, cache_path, row_tile_step
from repro.staticcheck.findings import Finding

PASS = "kernel-contracts"

_KEY_RE = re.compile(
    r"^(?P<kind>fwd|bwd)/(?P<m>\d+)x(?P<d>\d+)x(?P<n>\d+)"
    r"/b(?P<bits>\d+)/g(?P<group>\d+)/(?P<backend>[\w-]+)$")


@dataclasses.dataclass(frozen=True)
class Entry:
    """One parsed autotune-cache row."""

    key: str
    kind: str
    m: int
    d: int
    n: int
    bits: int
    group_size: int
    backend: str
    t0: int  # tm (fwd) | tile_rows (bwd)
    t1: int  # tn


@dataclasses.dataclass(frozen=True)
class Contract:
    rule: str
    description: str
    applies: str                       # "fwd" | "bwd" | "any"
    check: Callable[[Entry], str | None]  # violation message, or None


def _fwd_vmem(e: Entry) -> int:
    return 4 * (e.t0 * e.d + e.d * e.t1 + e.t0 * e.t1 + e.t0 * e.d // 8)


def _bwd_vmem(e: Entry) -> int:
    return 4 * (e.t0 * e.d + e.t0 * e.t1 + e.d * e.t1 + e.t0 * e.d // 8)


def _tile_positive(e: Entry) -> str | None:
    if e.t0 < 1 or e.t1 < 1:
        return f"non-positive tile ({e.t0}, {e.t1})"
    return None


def _quant_precondition(e: Entry) -> str | None:
    return backend.quant_kernel_unsupported(e.bits, e.group_size, None)


def _fwd_block_alignment(e: Entry) -> str | None:
    step = row_tile_step(e.d, e.group_size)
    if e.t0 % step:
        return (f"row tile tm={e.t0} does not own whole quantization "
                f"blocks: need a multiple of step={step} "
                f"(G={e.group_size}, D={e.d})")
    return None


def _fwd_index_bounds(e: Entry) -> str | None:
    step = row_tile_step(e.d, e.group_size)
    m_pad = -(-e.m // step) * step
    if e.t0 > m_pad:
        return (f"row tile tm={e.t0} exceeds the step-padded operand "
                f"height {m_pad} (m={e.m}, step={step})")
    if e.t1 > e.n:
        return f"column tile tn={e.t1} exceeds the output width n={e.n}"
    return None


def _fwd_vmem_budget(e: Entry) -> str | None:
    vmem = _fwd_vmem(e)
    if vmem > VMEM_BUDGET:
        return (f"tile ({e.t0}, {e.t1}) needs {vmem} bytes of VMEM "
                f"({e.t0}x{e.d} operand + {e.d}x{e.t1} weights + "
                f"{e.t0}x{e.t1} output + packed epilogue) over the "
                f"{VMEM_BUDGET}-byte per-core budget")
    return None


def _bwd_block_alignment(e: Entry) -> str | None:
    step = row_tile_step(e.d, e.group_size)
    if e.t0 % step:
        return (f"row tile tile_rows={e.t0} does not own whole "
                f"quantization blocks: need a multiple of step={step}")
    if e.m % e.t0:
        return (f"tile_rows={e.t0} does not divide m={e.m}: the M-split "
                "tree reduction needs equal row splits")
    return None


def _bwd_index_bounds(e: Entry) -> str | None:
    if e.t0 > e.m:
        return f"tile_rows={e.t0} exceeds the operand height m={e.m}"
    if e.t1 > e.n:
        return f"column tile tn={e.t1} exceeds the output width n={e.n}"
    return None


def _bwd_vmem_budget(e: Entry) -> str | None:
    if e.t0 == e.m:
        return None  # the bit-exact single-tile config is budget-exempt
    vmem = _bwd_vmem(e)
    if vmem > VMEM_BUDGET:
        return (f"row-split tile ({e.t0}, {e.t1}) needs {vmem} bytes of "
                f"VMEM over the {VMEM_BUDGET}-byte per-core budget")
    return None


CONTRACTS: tuple[Contract, ...] = (
    Contract("tile-bounds", "tiles are positive", "any", _tile_positive),
    Contract("quant-precondition",
             "base quant kernel can run (bits | 32, pack width | G)",
             "any", _quant_precondition),
    Contract("tile-block-alignment",
             "fwd row tile owns whole quantization blocks",
             "fwd", _fwd_block_alignment),
    Contract("tile-bounds", "fwd tiles stay inside the padded operand",
             "fwd", _fwd_index_bounds),
    Contract("vmem-budget", "fwd tile working set fits VMEM",
             "fwd", _fwd_vmem_budget),
    Contract("tile-block-alignment",
             "bwd row tile owns whole blocks and divides m exactly",
             "bwd", _bwd_block_alignment),
    Contract("tile-bounds", "bwd tiles stay inside the operand",
             "bwd", _bwd_index_bounds),
    Contract("vmem-budget",
             "bwd row-split tile fits VMEM (tile_rows == m exempt)",
             "bwd", _bwd_vmem_budget),
)


def parse_entry(key: str, tiles) -> Entry | None:
    m = _KEY_RE.match(key)
    if m is None or not (isinstance(tiles, (list, tuple))
                         and len(tiles) == 2):
        return None
    return Entry(key=key, kind=m["kind"], m=int(m["m"]), d=int(m["d"]),
                 n=int(m["n"]), bits=int(m["bits"]),
                 group_size=int(m["group"]), backend=m["backend"],
                 t0=int(tiles[0]), t1=int(tiles[1]))


def check_entry(key: str, tiles) -> list[Finding]:
    e = parse_entry(key, tiles)
    if e is None:
        return [Finding(PASS, "cache-key", key,
                        f"unparseable autotune entry (tiles={tiles!r}); "
                        "expected kind/MxDxN/bBITS/gGROUP/backend -> "
                        "[t0, t1]")]
    out = []
    for c in CONTRACTS:
        if c.applies not in ("any", e.kind):
            continue
        msg = c.check(e)
        if msg is not None:
            out.append(Finding(PASS, c.rule, key, msg))
    return out


def check_autotune_cache(path: pathlib.Path | None = None) -> list[Finding]:
    """Evaluate every contract against every persisted cache entry."""
    p = pathlib.Path(path) if path is not None else cache_path()
    if not p.exists():
        return []
    try:
        cache = json.loads(p.read_text())
    except (ValueError, OSError) as e:
        return [Finding(PASS, "cache-key", str(p),
                        f"autotune cache is not valid JSON: {e}")]
    out = []
    for key in sorted(cache):
        out.extend(check_entry(key, cache[key]))
    return out


def check_compression_config(cfg, stash_width: int,
                             where: str) -> list[Finding]:
    """quant_blockwise / rp_matmul preconditions for one layer config."""
    out = []
    reason = backend.quant_kernel_unsupported(cfg.bits, cfg.group_size,
                                              cfg.levels())
    if reason is not None:
        out.append(Finding(PASS, "quant-precondition", where, reason))
    if cfg.rp_ratio > 1 and stash_width % cfg.rp_ratio:
        out.append(Finding(
            PASS, "rp-precondition", where,
            f"rp_matmul projects the last dim {stash_width} by "
            f"rp_ratio={cfg.rp_ratio}, which does not divide it"))
    return out


def check_matrix_configs() -> list[Finding]:
    """Every (layer config × stash width) the plan matrix would launch."""
    from repro.graph.models import _dims
    from repro.staticcheck.matrix import audit_matrix

    out, seen = [], set()
    for case in audit_matrix():
        dims = _dims(case.cfg, case.in_dim)
        for li, (d_in, comp) in enumerate(
                zip(dims[:-1], case.cfg.layer_compression())):
            if comp is None:
                continue
            lin_in = d_in * (2 if case.cfg.arch == "sage" else 1)
            sig = (comp, lin_in)
            if sig in seen:
                continue
            seen.add(sig)
            out.extend(check_compression_config(
                comp, lin_in, f"{case.key}/layer{li}"))
    return out


def run(path: pathlib.Path | None = None) -> list[Finding]:
    return check_autotune_cache(path) + check_matrix_configs()
