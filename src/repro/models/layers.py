"""Shared neural layers (pure functions over param pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rmsnorm(x, w, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv * w).astype(x.dtype)


def rope_freqs(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(x, positions, theta: float):
    """x (..., S, H, Dh), positions (..., S) int32."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(d_head, theta), jnp.float32)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    from repro.parallel.annotate import shard
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    if h.ndim == 3:
        h = shard(h, "batch", None, "dff")
    return h @ w_down


def dense_init(key, d_in, d_out, dtype=jnp.bfloat16, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab, d_model, dtype=jnp.bfloat16):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) * 0.02).astype(dtype)


def stack_layer_params(per_layer: list):
    """[pytree_l0, pytree_l1, ...] -> pytree with leading layer axis (scan)."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
