"""Model zoo assembly: dense / MoE / SSM / hybrid / enc-dec / stub-frontend
architectures from a single config, built for ``lax.scan`` over stacked
layer weights (compile time O(1) in depth) and GSPMD sharding.

Activation-compressed training (the paper's technique) plugs in per layer:
``act_mode``:
  * "none"  — autodiff saves everything
  * "remat" — jax.checkpoint per layer
  * "act"   — compressed_block: the layer input is stored RP+block-quantized
              (INT2 by default) and the backward recomputes from the
              reconstruction.  remat recomputes, ACT stores-compressed.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.act_compress import compressed_block
from repro.core.compressor import CompressionConfig
from repro.models import attention as attn
from repro.models import moe as moemod
from repro.models import ssm as ssmmod
from repro.models.layers import (dense_init, embed_init, rmsnorm,
                                 stack_layer_params, swiglu)
from repro.parallel.annotate import shard


# ============================================================ param init
def _attn_params(key, cfg, d_in=None):
    d = d_in or cfg.d_model
    ks = jax.random.split(key, 8)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * cfg.d_head),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * cfg.d_head),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * cfg.d_head),
        "wo": dense_init(ks[3], cfg.n_heads * cfg.d_head, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads * cfg.d_head,), jnp.float32)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * cfg.d_head,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.d_head,), jnp.float32)
    return p


def _mlp_params(key, d_model, d_ff):
    ks = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(ks[0], d_model, d_ff),
        "w_up": dense_init(ks[1], d_model, d_ff),
        "w_down": dense_init(ks[2], d_ff, d_model),
    }


def _moe_params(key, cfg):
    ks = jax.random.split(key, 4)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    s = 1.0 / np.sqrt(d)
    return {
        "router": (jax.random.normal(ks[0], (d, e), jnp.float32) * s
                   ).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d, f), jnp.float32) * s
                   ).astype(jnp.bfloat16),
        "w_up": (jax.random.normal(ks[2], (e, d, f), jnp.float32) * s
                 ).astype(jnp.bfloat16),
        "w_down": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                   / np.sqrt(f)).astype(jnp.bfloat16),
    }


def _ssm_params(key, cfg):
    d_inner, n_heads = ssmmod.ssm_dims(cfg)
    n = cfg.ssm_state
    ks = jax.random.split(key, 10)
    conv = lambda k, c: (jax.random.normal(k, (cfg.ssm_conv, c), jnp.float32)
                         * 0.2).astype(jnp.bfloat16)
    return {
        "w_z": dense_init(ks[0], cfg.d_model, d_inner),
        "w_x": dense_init(ks[1], cfg.d_model, d_inner),
        "w_B": dense_init(ks[2], cfg.d_model, n),
        "w_C": dense_init(ks[3], cfg.d_model, n),
        "w_dt": dense_init(ks[4], cfg.d_model, n_heads),
        "conv_x": conv(ks[5], d_inner),
        "conv_B": conv(ks[6], n),
        "conv_C": conv(ks[7], n),
        "conv_bx": jnp.zeros((d_inner,), jnp.float32),
        "conv_bB": jnp.zeros((n,), jnp.float32),
        "conv_bC": jnp.zeros((n,), jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "a_log": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_w": jnp.ones((d_inner,), jnp.float32),
        "out_proj": dense_init(ks[8], d_inner, cfg.d_model),
    }


def _dense_layer_params(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": _attn_params(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "mlp": _mlp_params(k2, cfg.d_model, cfg.d_ff),
    }


def _moe_layer_params(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": _attn_params(k1, cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "moe": _moe_params(k2, cfg),
    }
    if cfg.dense_residual:
        p["ln3"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = _mlp_params(k3, cfg.d_model, cfg.d_ff)
    return p


def _ssm_layer_params(key, cfg):
    return {
        "ln": jnp.ones((cfg.d_model,), jnp.float32),
        "mixer": _ssm_params(key, cfg),
    }


@dataclasses.dataclass
class Model:
    cfg: object

    # ------------------------------------------------------------ init
    def init(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        act_dtype = jnp.dtype(getattr(cfg, "act_dtype", "bfloat16"))
        params = {"embed": embed_init(keys[0], cfg.vocab, cfg.d_model,
                                      dtype=act_dtype),
                  "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
                  "lm_head": dense_init(keys[1], cfg.d_model, cfg.vocab)}
        fam = cfg.family
        lk = jax.random.split(keys[2], max(cfg.n_layers, 1))
        if fam in ("dense", "vlm"):
            params["layers"] = stack_layer_params(
                [_dense_layer_params(k, cfg) for k in lk])
        elif fam == "moe":
            params["layers"] = stack_layer_params(
                [_moe_layer_params(k, cfg) for k in lk])
        elif fam == "ssm":
            params["layers"] = stack_layer_params(
                [_ssm_layer_params(k, cfg) for k in lk])
        elif fam == "hybrid":
            params["layers"] = stack_layer_params(
                [_ssm_layer_params(k, cfg) for k in lk])
            shared_cfg = dataclasses.replace(
                cfg, d_model=2 * cfg.d_model,
                d_head=2 * cfg.d_model // cfg.n_heads)
            params["shared_attn"] = {
                "ln": jnp.ones((2 * cfg.d_model,), jnp.float32),
                "attn": _attn_params(keys[3], shared_cfg),
                "ln2": jnp.ones((2 * cfg.d_model,), jnp.float32),
                "mlp": _mlp_params(keys[4], 2 * cfg.d_model, cfg.d_ff),
                "down": dense_init(keys[5], 2 * cfg.d_model, cfg.d_model),
            }
        elif fam == "encdec":
            ek = jax.random.split(keys[3], cfg.encoder_layers)
            params["enc_layers"] = stack_layer_params(
                [_dense_layer_params(k, cfg) for k in ek])
            params["enc_norm"] = jnp.ones((cfg.d_model,), jnp.float32)
            dk = jax.random.split(keys[4], cfg.n_layers)

            def dec_layer(k):
                k1, k2 = jax.random.split(k)
                p = _dense_layer_params(k1, cfg)
                p["ln_x"] = jnp.ones((cfg.d_model,), jnp.float32)
                p["xattn"] = _attn_params(k2, cfg)
                return p

            params["layers"] = stack_layer_params([dec_layer(k) for k in dk])
        else:
            raise ValueError(fam)
        return params

    # ---------------------------------------------------- layer wrapping
    def _wrap(self, layer_fn):
        """Apply act_mode around a layer fn f(x, (params, seed)) -> x."""
        cfg = self.cfg
        if cfg.act_mode == "act":
            comp = cfg.act_compression or CompressionConfig(
                bits=2, group_size=256, rp_ratio=0)

            def f(x, ps):
                p, seed = ps
                return layer_fn(x, p)

            offload = getattr(cfg, "act_offload", None)
            wrapped = compressed_block(
                f, comp, offload=None if offload == "device" else offload)
            return lambda x, p, seed: wrapped(x, (p, seed), seed)
        if cfg.act_mode == "remat":
            ck = jax.checkpoint(layer_fn)
            return lambda x, p, seed: ck(x, p)
        return lambda x, p, seed: layer_fn(x, p)

    # ------------------------------------------------------------ blocks
    def _dense_layer(self, h, p, causal=True):
        cfg = self.cfg
        h = shard(h, "batch", None, None)
        h = h + attn.attention_block(rmsnorm(h, p["ln1"]), p["attn"], cfg,
                                     causal=causal, k_chunk=cfg.k_chunk)
        m = p["mlp"]
        h = h + swiglu(rmsnorm(h, p["ln2"]), m["w_gate"], m["w_up"],
                       m["w_down"])
        return shard(h, "batch", None, None)

    def _moe_layer(self, h, p):
        cfg = self.cfg
        h = shard(h, "batch", None, None)
        h = h + attn.attention_block(rmsnorm(h, p["ln1"]), p["attn"], cfg,
                                     causal=True, k_chunk=cfg.k_chunk)
        if cfg.dense_residual:
            m = p["mlp"]
            h = h + swiglu(rmsnorm(h, p["ln3"]), m["w_gate"], m["w_up"],
                           m["w_down"])
        y, aux = moemod.moe_ffn(rmsnorm(h, p["ln2"]), p["moe"],
                                n_experts=cfg.n_experts, top_k=cfg.top_k,
                                capacity_factor=cfg.moe_capacity_factor)
        return shard(h + y, "batch", None, None), aux

    def _ssm_layer(self, h, p):
        h = shard(h, "batch", None, None)
        return h + ssmmod.mamba2_block(rmsnorm(h, p["ln"]), p["mixer"],
                                       self.cfg, chunk=self.cfg.ssm_chunk)

    def _shared_attn_block(self, h, h0, p):
        cfg = self.cfg
        shared_cfg = dataclasses.replace(
            cfg, d_model=2 * cfg.d_model,
            d_head=2 * cfg.d_model // cfg.n_heads)
        x = jnp.concatenate([h, h0], axis=-1)
        x = x + attn.attention_block(rmsnorm(x, p["ln"]), p["attn"],
                                     shared_cfg, causal=True,
                                     k_chunk=cfg.k_chunk)
        m = p["mlp"]
        x = x + swiglu(rmsnorm(x, p["ln2"]), m["w_gate"], m["w_up"],
                       m["w_down"])
        return h + x @ p["down"]

    # ------------------------------------------------------------ forward
    def hidden_states(self, params, tokens, *, prefix_embeds=None,
                      enc_embeds=None, act_seed=0):
        """Token ids (+ optional stub-frontend prefix) -> final hidden (B,S,D).

        Returns (h, aux_loss).  For encdec, ``enc_embeds`` (B,Se,D) is the
        audio-frontend stub output and tokens are decoder ids.
        """
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0)
        if prefix_embeds is not None:
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        aux = jnp.zeros((), jnp.float32)
        seed0 = jnp.asarray(act_seed, jnp.uint32)

        if cfg.family in ("dense", "vlm"):
            step = self._wrap(self._dense_layer)

            def body(carry, xs):
                lp, li = xs
                return step(carry, lp, seed0 + li), None

            h, _ = jax.lax.scan(body, h, (params["layers"],
                                          jnp.arange(cfg.n_layers, dtype=jnp.uint32)))
        elif cfg.family == "moe":
            def moe_fn(x, p):
                return self._moe_layer(x, p)

            if cfg.act_mode == "remat":
                moe_fn = jax.checkpoint(moe_fn)

            def body(carry, xs):
                hh, aa = carry
                lp, li = xs
                hh, a = moe_fn(hh, lp)
                return (hh, aa + a), None

            (h, aux), _ = jax.lax.scan(
                body, (h, aux), (params["layers"],
                                 jnp.arange(cfg.n_layers, dtype=jnp.uint32)))
        elif cfg.family == "ssm":
            step = self._wrap(self._ssm_layer)

            def body(carry, xs):
                lp, li = xs
                return step(carry, lp, seed0 + li), None

            h, _ = jax.lax.scan(body, h, (params["layers"],
                                          jnp.arange(cfg.n_layers, dtype=jnp.uint32)))
        elif cfg.family == "hybrid":
            h0 = h
            step = self._wrap(self._ssm_layer)
            sites = cfg.shared_attn_sites()
            start = 0
            for si, site in enumerate(sites + [cfg.n_layers]):
                seg = jax.tree.map(lambda a: a[start:site], params["layers"])
                if site > start:
                    def body(carry, xs):
                        lp, li = xs
                        return step(carry, lp, seed0 + li), None

                    h, _ = jax.lax.scan(
                        body, h,
                        (seg, jnp.arange(start, site, dtype=jnp.uint32)))
                if site < cfg.n_layers:
                    h = self._shared_attn_block(h, h0, params["shared_attn"])
                start = site
        elif cfg.family == "encdec":
            enc = enc_embeds.astype(h.dtype)

            def enc_body(carry, lp):
                return self._dense_layer(carry, lp, causal=False), None

            enc_fn = enc_body
            if cfg.act_mode in ("remat", "act"):
                enc_fn = jax.checkpoint(enc_body)
            enc, _ = jax.lax.scan(enc_fn, enc, params["enc_layers"])
            enc = rmsnorm(enc, params["enc_norm"])

            def dec_layer(x, p):
                x = x + attn.attention_block(rmsnorm(x, p["ln1"]), p["attn"],
                                             cfg, causal=True,
                                             k_chunk=cfg.k_chunk)
                x = x + attn.cross_attention_block(rmsnorm(x, p["ln_x"]),
                                                   p["xattn"], cfg, enc)
                m = p["mlp"]
                return x + swiglu(rmsnorm(x, p["ln2"]), m["w_gate"],
                                  m["w_up"], m["w_down"])

            dfn = dec_layer
            if cfg.act_mode in ("remat", "act"):
                dfn = jax.checkpoint(dec_layer)

            def dec_body(carry, lp):
                return dfn(carry, lp), None

            h, _ = jax.lax.scan(dec_body, h, params["layers"])
        else:
            raise ValueError(cfg.family)
        return rmsnorm(h, params["final_norm"]), aux

    def loss(self, params, tokens, *, prefix_embeds=None, enc_embeds=None,
             act_seed=0, vocab_chunk: int = 4096):
        """Next-token CE, vocab projection chunked over the sequence so the
        (B, S, V) logits never materialize (beyond-paper memory saving)."""
        cfg = self.cfg
        h, aux = self.hidden_states(params, tokens,
                                    prefix_embeds=prefix_embeds,
                                    enc_embeds=enc_embeds, act_seed=act_seed)
        npfx = 0 if prefix_embeds is None else prefix_embeds.shape[1]
        h_pred = h[:, npfx:npfx + tokens.shape[1] - 1]
        targets = tokens[:, 1:]
        s = h_pred.shape[1]
        n_chunks = max(1, (s + vocab_chunk - 1) // vocab_chunk)
        pad = n_chunks * vocab_chunk - s
        if pad:
            h_pred = jnp.pad(h_pred, ((0, 0), (0, pad), (0, 0)))
            targets = jnp.pad(targets, ((0, 0), (0, pad)))
        hc = h_pred.reshape(h_pred.shape[0], n_chunks, vocab_chunk, -1)
        tc = targets.reshape(targets.shape[0], n_chunks, vocab_chunk)
        valid = (jnp.arange(n_chunks * vocab_chunk).reshape(n_chunks, vocab_chunk)
                 < s)

        @jax.checkpoint
        def chunk_nll(hx, tx, vx):
            logits = shard((hx @ params["lm_head"]).astype(jnp.float32),
                           "batch", None, "vocab")
            lse = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tx[..., None], axis=-1)[..., 0]
            return jnp.sum((lse - gold) * vx)

        def body(acc, xs):
            hx, tx, vx = xs
            return acc + chunk_nll(hx, tx, vx), None

        total, _ = jax.lax.scan(
            body, jnp.zeros((), jnp.float32),
            (hc.transpose(1, 0, 2, 3), tc.transpose(1, 0, 2), valid))
        nll = total / jnp.maximum(valid.sum() * h_pred.shape[0], 1)
        return nll + cfg.aux_loss_weight * aux

    # ------------------------------------------------------------ decode
    def init_cache(self, batch: int, max_seq: int, enc_len: int = 0,
                   dtype=jnp.bfloat16):
        cfg = self.cfg
        L = cfg.n_layers
        kv = lambda: jnp.zeros((L, batch, max_seq, cfg.n_kv_heads,
                                cfg.d_head), dtype)
        cache = {"pos": jnp.zeros((batch,), jnp.int32)}
        if cfg.family in ("dense", "vlm", "moe"):
            cache["k"], cache["v"] = kv(), kv()
        elif cfg.family == "ssm":
            d_inner, n_heads = ssmmod.ssm_dims(cfg)
            conv_ch = d_inner + 2 * cfg.ssm_state
            cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch),
                                      dtype)
            cache["ssd"] = jnp.zeros((L, batch, n_heads, cfg.ssm_headdim,
                                      cfg.ssm_state), jnp.float32)
        elif cfg.family == "hybrid":
            d_inner, n_heads = ssmmod.ssm_dims(cfg)
            conv_ch = d_inner + 2 * cfg.ssm_state
            cache["conv"] = jnp.zeros((L, batch, cfg.ssm_conv - 1, conv_ch),
                                      dtype)
            cache["ssd"] = jnp.zeros((L, batch, n_heads, cfg.ssm_headdim,
                                      cfg.ssm_state), jnp.float32)
            ns = len(cfg.shared_attn_sites())
            dh = 2 * cfg.d_model // cfg.n_heads
            cache["shared_k"] = jnp.zeros(
                (ns, batch, max_seq, cfg.n_kv_heads, dh), dtype)
            cache["shared_v"] = jnp.zeros(
                (ns, batch, max_seq, cfg.n_kv_heads, dh), dtype)
        elif cfg.family == "encdec":
            cache["k"], cache["v"] = kv(), kv()
            cache["enc"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
        return cache

    # ----------------------------------------------------------- prefill
    def prefill(self, params, tokens, *, prefix_embeds=None, enc_embeds=None,
                max_seq: int | None = None):
        """Process a prompt, returning (last_logits (B,V), cache).

        The compute profile of inference-prefill: full forward + KV/state
        cache population.  ``max_seq`` sizes the cache (>= prompt length).
        """
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0)
        if prefix_embeds is not None:
            h = jnp.concatenate([prefix_embeds.astype(h.dtype), h], axis=1)
        b, s, _ = h.shape
        max_seq = max_seq or s
        pad_s = max_seq - s

        def attn_collect(x, p, acfg):
            positions = jnp.broadcast_to(jnp.arange(s), (b, s))
            q, k, v = attn.qkv_project(x, p, acfg, positions)
            n_rep = acfg.n_heads // acfg.n_kv_heads
            out = attn.online_attention(
                q, attn._repeat_kv(k, n_rep), attn._repeat_kv(v, n_rep),
                causal=True, k_chunk=acfg.k_chunk)
            out = shard(out.reshape(b, s, acfg.n_heads * acfg.d_head),
                        "batch", None, "attn_out")
            out = out @ p["wo"]
            kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
            return out, shard(kp, "batch", None, "kv_heads", None), \
                shard(vp, "batch", None, "kv_heads", None)

        cache = {"pos": jnp.full((b,), s, jnp.int32)}
        if cfg.family in ("dense", "vlm", "moe"):
            def body(carry, lp):
                hh = shard(carry, "batch", None, None)
                a, kp, vp = attn_collect(rmsnorm(hh, lp["ln1"]), lp["attn"],
                                         cfg)
                hh = hh + a
                if cfg.family == "moe":
                    if cfg.dense_residual:
                        m = lp["mlp"]
                        hh = hh + swiglu(rmsnorm(hh, lp["ln3"]), m["w_gate"],
                                         m["w_up"], m["w_down"])
                    y, _ = moemod.moe_ffn(rmsnorm(hh, lp["ln2"]), lp["moe"],
                                          n_experts=cfg.n_experts,
                                          top_k=cfg.top_k,
                                          capacity_factor=cfg.moe_capacity_factor)
                    hh = hh + y
                else:
                    m = lp["mlp"]
                    hh = hh + swiglu(rmsnorm(hh, lp["ln2"]), m["w_gate"],
                                     m["w_up"], m["w_down"])
                return shard(hh, "batch", None, None), (kp, vp)

            h, (cache["k"], cache["v"]) = jax.lax.scan(body, h,
                                                       params["layers"])
        elif cfg.family == "encdec":
            enc = enc_embeds.astype(h.dtype)

            def enc_body(carry, lp):
                return self._dense_layer(carry, lp, causal=False), None

            enc, _ = jax.lax.scan(enc_body, enc, params["enc_layers"])
            cache["enc"] = rmsnorm(enc, params["enc_norm"])
            L = cfg.n_layers
            cache["k"] = jnp.zeros((L, b, max_seq, cfg.n_kv_heads,
                                    cfg.d_head), h.dtype)
            cache["v"] = jnp.zeros_like(cache["k"])
            cache["pos"] = jnp.zeros((b,), jnp.int32)
        elif cfg.family in ("ssm", "hybrid"):
            K = cfg.ssm_conv

            def ssm_body(carry, lp):
                hh = shard(carry, "batch", None, None)
                x = rmsnorm(hh, lp["ln"])
                y, state = ssmmod.mamba2_block(x, lp["mixer"], cfg,
                                               chunk=cfg.ssm_chunk,
                                               return_state=True)
                tail = jnp.concatenate(
                    [x @ lp["mixer"]["w_x"], x @ lp["mixer"]["w_B"],
                     x @ lp["mixer"]["w_C"]], axis=-1)[:, s - (K - 1):]
                return shard(hh + y, "batch", None, None), (tail, state)

            if cfg.family == "ssm":
                h, (cache["conv"], cache["ssd"]) = jax.lax.scan(
                    ssm_body, h, params["layers"])
            else:
                h0 = h
                sites = cfg.shared_attn_sites()
                sp = params["shared_attn"]
                shared_cfg = dataclasses.replace(
                    cfg, d_model=2 * cfg.d_model,
                    d_head=2 * cfg.d_model // cfg.n_heads)
                convs, ssds, sks, svs = [], [], [], []
                start = 0
                for site in sites + [cfg.n_layers]:
                    if site > start:
                        seg = jax.tree.map(lambda a: a[start:site],
                                           params["layers"])
                        h, (cc, cs) = jax.lax.scan(ssm_body, h, seg)
                        convs.append(cc)
                        ssds.append(cs)
                    if site < cfg.n_layers:
                        x = jnp.concatenate([h, h0], axis=-1)
                        a, kp, vp = attn_collect(rmsnorm(x, sp["ln"]),
                                                 sp["attn"], shared_cfg)
                        x = x + a
                        m = sp["mlp"]
                        x = x + swiglu(rmsnorm(x, sp["ln2"]), m["w_gate"],
                                       m["w_up"], m["w_down"])
                        h = h + x @ sp["down"]
                        sks.append(kp)
                        svs.append(vp)
                    start = site
                cache["conv"] = jnp.concatenate(convs, axis=0)
                cache["ssd"] = jnp.concatenate(ssds, axis=0)
                cache["shared_k"] = jnp.stack(sks)
                cache["shared_v"] = jnp.stack(svs)
        h = rmsnorm(h, params["final_norm"])
        logits = (h[:, -1] @ params["lm_head"]).astype(jnp.float32)
        return logits, cache

    def decode_step(self, params, cache, tokens):
        """tokens (B, 1) -> (logits (B, 1, V), cache)."""
        cfg = self.cfg
        h = jnp.take(params["embed"], tokens, axis=0)
        pos = cache["pos"]

        if cfg.family in ("dense", "vlm", "moe", "encdec"):
            def body(carry, xs):
                hh = carry
                lp, ck, cv = xs
                x = rmsnorm(hh, lp["ln1"])
                a, ck, cv = attn.attention_decode(x, lp["attn"], cfg, ck, cv,
                                                  pos)
                hh = hh + a
                if cfg.family == "encdec":
                    hh = hh + attn.cross_attention_block(
                        rmsnorm(hh, lp["ln_x"]), lp["xattn"], cfg,
                        cache["enc"])
                if cfg.family == "moe":
                    if cfg.dense_residual:
                        m = lp["mlp"]
                        hh = hh + swiglu(rmsnorm(hh, lp["ln3"]), m["w_gate"],
                                         m["w_up"], m["w_down"])
                    y, _ = moemod.moe_ffn(rmsnorm(hh, lp["ln2"]), lp["moe"],
                                          n_experts=cfg.n_experts,
                                          top_k=cfg.top_k,
                                          capacity_factor=cfg.moe_capacity_factor)
                    hh = hh + y
                else:
                    m = lp["mlp"]
                    hh = hh + swiglu(rmsnorm(hh, lp["ln2"]), m["w_gate"],
                                     m["w_up"], m["w_down"])
                return hh, (ck, cv)

            h, (cache["k"], cache["v"]) = jax.lax.scan(
                body, h, (params["layers"], cache["k"], cache["v"]))
        elif cfg.family == "ssm":
            def body(carry, xs):
                hh = carry
                lp, cc, cs = xs
                y, cc, cs = ssmmod.mamba2_decode(rmsnorm(hh, lp["ln"]),
                                                 lp["mixer"], cfg, cc, cs)
                return hh + y, (cc, cs)

            h, (cache["conv"], cache["ssd"]) = jax.lax.scan(
                body, h, (params["layers"], cache["conv"], cache["ssd"]))
        elif cfg.family == "hybrid":
            h0 = h  # shared-block input concatenates the CURRENT token's
            # embedding (matches the training path where h0 is the full
            # embedding sequence)
            sites = cfg.shared_attn_sites()
            start = 0
            sp = params["shared_attn"]
            shared_cfg = dataclasses.replace(
                cfg, d_model=2 * cfg.d_model,
                d_head=2 * cfg.d_model // cfg.n_heads)
            new_conv, new_ssd = [], []
            for si, site in enumerate(sites + [cfg.n_layers]):
                if site > start:
                    seg = jax.tree.map(lambda a: a[start:site],
                                       params["layers"])
                    cc = cache["conv"][start:site]
                    cs = cache["ssd"][start:site]

                    def body(carry, xs):
                        hh = carry
                        lp, c1, c2 = xs
                        y, c1, c2 = ssmmod.mamba2_decode(
                            rmsnorm(hh, lp["ln"]), lp["mixer"], cfg, c1, c2)
                        return hh + y, (c1, c2)

                    h, (cc, cs) = jax.lax.scan(body, h, (seg, cc, cs))
                    new_conv.append(cc)
                    new_ssd.append(cs)
                if site < cfg.n_layers:
                    x = jnp.concatenate([h, h0], axis=-1)
                    xl = rmsnorm(x, sp["ln"])
                    a, ck, cv = attn.attention_decode(
                        xl, sp["attn"], shared_cfg,
                        cache["shared_k"][si], cache["shared_v"][si], pos)
                    cache["shared_k"] = cache["shared_k"].at[si].set(ck)
                    cache["shared_v"] = cache["shared_v"].at[si].set(cv)
                    x = x + a
                    m = sp["mlp"]
                    x = x + swiglu(rmsnorm(x, sp["ln2"]), m["w_gate"],
                                   m["w_up"], m["w_down"])
                    h = h + x @ sp["down"]
                start = site
            cache["conv"] = jnp.concatenate(new_conv, axis=0)
            cache["ssd"] = jnp.concatenate(new_ssd, axis=0)
        cache["pos"] = pos + 1
        h = rmsnorm(h, params["final_norm"])
        return (h @ params["lm_head"]).astype(jnp.float32), cache

