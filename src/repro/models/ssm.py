"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) block.

Training path: chunked SSD — intra-chunk quadratic attention-like term +
inter-chunk recurrent state carried with ``lax.scan`` (linear in sequence
length; this is why the ssm/hybrid archs run the ``long_500k`` shape that
full attention skips).  Decode path: O(1) per-token state update.

TPU/TP notes: projections are UNFUSED (w_z/w_x/w_B/w_C/w_dt) so the head
dimension shards over the ``model`` mesh axis without slice/tile mismatch
(a fused in_proj would put split boundaries mid-tile).  Heads (H, P) keep
P on lanes; the state (B,H,P,N) einsums are MXU batched matmuls;
n_groups = 1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import rmsnorm
from repro.parallel.annotate import shard


def ssm_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    n_heads = d_inner // cfg.ssm_headdim
    return d_inner, n_heads


def causal_conv1d(x, w, b):
    """Depthwise causal conv. x (B,S,C), w (K,C), b (C)."""
    k = w.shape[0]
    out = jnp.zeros_like(x)
    for i in range(k):
        shift = k - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[i]
    return out + b


def ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk: int = 128,
                initial_state=None, return_state: bool = False):
    """Chunked SSD scan.

    xh (B,S,H,P) head inputs; dt (B,S,H) post-softplus; a_neg (H,) negative;
    bmat/cmat (B,S,N) (n_groups=1, broadcast over heads).
    Returns (y (B,S,H,P), final_state (B,H,P,N) | None).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    assert s % chunk == 0, f"seq {s} % chunk {chunk}"
    nc = s // chunk
    xc = shard(xh.reshape(b, nc, chunk, h, p),
               "batch", None, None, "ssm_heads", None)
    dtc = dt.reshape(b, nc, chunk, h).astype(jnp.float32)
    bc = bmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, chunk, n).astype(jnp.float32)

    da = dtc * a_neg[None, None, None, :]                  # (B,nc,Q,H) ≤ 0
    cum = jnp.cumsum(da, axis=2)                           # running log-decay
    seg_end = cum[:, :, -1:, :]                            # (B,nc,1,H)

    # intra-chunk: y_i += Σ_{j<=i} exp(cum_i - cum_j) dt_j (C_i·B_j) x_j
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(causal[None, None, :, :, None], decay, 0.0)
    decay = shard(decay, "batch", None, None, None, "ssm_heads")
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)             # (B,nc,Q,Q)
    w_ij = cb[..., None] * decay * dtc[:, :, None, :, :]   # (B,nc,Q,Q,H)
    y_intra = shard(
        jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xc.astype(jnp.float32)),
        "batch", None, None, "ssm_heads", None)

    # chunk states: S_c = Σ_j exp(seg_end - cum_j) dt_j B_j ⊗ x_j (B,nc,H,P,N)
    state_w = jnp.exp(seg_end - cum) * dtc                 # (B,nc,Q,H)
    states = shard(
        jnp.einsum("bcqh,bcqn,bcqhp->bchpn", state_w, bc,
                   xc.astype(jnp.float32)),
        "batch", None, "ssm_heads", None, None)

    # inter-chunk recurrence over nc
    seg_decay = jnp.exp(seg_end[:, :, 0, :])               # (B,nc,H)

    def step(carry, inp):
        st, dec = inp                                      # (B,H,P,N), (B,H)
        prev = carry
        new = shard(prev * dec[:, :, None, None] + st,
                    "batch", "ssm_heads", None, None)
        return new, prev                                   # emit state BEFORE chunk

    init = shard(
        jnp.zeros((b, h, p, n), jnp.float32) if initial_state is None
        else initial_state.astype(jnp.float32),
        "batch", "ssm_heads", None, None)
    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4),
                     seg_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # y_inter_i = exp(cum_i) * C_i · S_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(cum), cc, prev_states)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y.astype(xh.dtype), (final if return_state else None)


def _project(x, p, cfg):
    """Unfused projections + separate depthwise convs."""
    z = x @ p["w_z"]
    xi = causal_conv1d(x @ p["w_x"], p["conv_x"], p["conv_bx"])
    bmat = causal_conv1d(x @ p["w_B"], p["conv_B"], p["conv_bB"])
    cmat = causal_conv1d(x @ p["w_C"], p["conv_C"], p["conv_bC"])
    xi, bmat, cmat = jax.nn.silu(xi), jax.nn.silu(bmat), jax.nn.silu(cmat)
    dt = jax.nn.softplus((x @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    return z, xi, bmat, cmat, dt


def mamba2_block(x, p, cfg, chunk: int = 128, return_state: bool = False):
    """Full Mamba-2 mixer. x (B,S,D) -> (B,S,D) [, final ssd state]."""
    d_inner, n_heads = ssm_dims(cfg)
    z, xi, bmat, cmat, dt = _project(x, p, cfg)
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xi.reshape(*xi.shape[:2], n_heads, cfg.ssm_headdim)
    y, state = ssd_chunked(xh, dt, a_neg, bmat, cmat, chunk=chunk,
                           return_state=return_state)
    y = y + xh * p["d_skip"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(*x.shape[:2], d_inner)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    out = (y @ p["out_proj"]).astype(x.dtype)
    return (out, state) if return_state else out


def mamba2_decode(x, p, cfg, conv_state, ssd_state):
    """One-token decode. x (B,1,D); conv_state (B,K-1,C_all);
    ssd_state (B,H,P,N).  C_all = d_inner + 2N (x|B|C stacked).
    Returns (y (B,1,D), conv_state, ssd_state)."""
    d_inner, n_heads = ssm_dims(cfg)
    n = cfg.ssm_state
    x0 = x[:, 0]
    z = x0 @ p["w_z"]
    new_col = jnp.concatenate(
        [x0 @ p["w_x"], x0 @ p["w_B"], x0 @ p["w_C"]], axis=-1)
    window = jnp.concatenate([conv_state, new_col[:, None]], 1)  # (B,K,C_all)
    conv_state = window[:, 1:]
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=1)
    conv_b = jnp.concatenate([p["conv_bx"], p["conv_bB"], p["conv_bC"]])
    col = jnp.einsum("bkc,kc->bc", window, conv_w) + conv_b
    col = jax.nn.silu(col)
    xi = col[:, :d_inner]
    bmat = col[:, d_inner:d_inner + n].astype(jnp.float32)
    cmat = col[:, d_inner + n:].astype(jnp.float32)
    dt = jax.nn.softplus((x0 @ p["w_dt"]).astype(jnp.float32) + p["dt_bias"])
    a_neg = -jnp.exp(p["a_log"].astype(jnp.float32))
    xh = xi.reshape(-1, n_heads, cfg.ssm_headdim).astype(jnp.float32)
    decay = jnp.exp(dt * a_neg)                            # (B,H)
    upd = jnp.einsum("bh,bhp,bn->bhpn", dt, xh, bmat)
    ssd_state = ssd_state * decay[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", ssd_state, cmat)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(-1, d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm_w"])
    return (y @ p["out_proj"])[:, None], conv_state, ssd_state
