"""GQA attention: online-softmax chunked training/prefill path + cached
decode path.  Pure JAX — XLA fuses the streaming softmax; memory stays
O(S · chunk) instead of O(S²), which is what lets prefill_32k compile
inside a v5e HBM budget.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope, rmsnorm
from repro.parallel.annotate import shard

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    """(B, S, Hkv, Dh) -> (B, S, Hkv*n_rep, Dh)."""
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, h, n_rep, d)).reshape(
        b, s, h * n_rep, d)


def online_attention(q, k, v, *, causal: bool, q_offset=0,
                     kv_len=None, k_chunk: int = 1024):
    """Streaming-softmax attention.

    q: (B, Sq, H, Dh);  k, v: (B, Skv, H, Dh) (already GQA-expanded).
    ``q_offset``: absolute position of q[0] (causal masking for decode /
    chunked prefill).  ``kv_len``: #valid kv entries (cache may be padded).
    """
    b, sq, h, dh = q.shape
    skv = k.shape[1]
    scale = 1.0 / np.sqrt(dh)
    qf = (q * scale).astype(jnp.float32).transpose(0, 2, 1, 3)   # B,H,Sq,Dh
    kf = k.astype(jnp.float32).transpose(0, 2, 3, 1)             # B,H,Dh,Skv
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)             # B,H,Skv,Dh
    sp = "q_seq" if sq >= 2048 else None  # never split tiny/decode queries
    qf = shard(qf, "batch", "heads", sp, None)
    kf = shard(kf, "batch", "heads", None, None)
    vf = shard(vf, "batch", "heads", None, None)

    n_chunks = max(1, (skv + k_chunk - 1) // k_chunk)
    pad = n_chunks * k_chunk - skv
    if pad:
        kf = jnp.pad(kf, ((0, 0), (0, 0), (0, 0), (0, pad)))
        vf = jnp.pad(vf, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kf = kf.reshape(b, h, dh, n_chunks, k_chunk)
    vf = vf.reshape(b, h, n_chunks, k_chunk, dh)

    q_pos = q_offset + jnp.arange(sq)

    def body(carry, inputs):
        m, l, acc = carry
        kc, vc, c_idx = inputs
        s = shard(jnp.einsum("bhqd,bhdk->bhqk", qf, kc),
                  "batch", "heads", sp, None)
        kv_pos = c_idx * k_chunk + jnp.arange(k_chunk)
        mask = jnp.ones((sq, k_chunk), bool)
        if causal:
            mask = mask & (kv_pos[None, :] <= q_pos[:, None])
        if kv_len is not None:
            mask = mask & (kv_pos[None, :] < kv_len)
        else:
            mask = mask & (kv_pos[None, :] < skv)
        s = jnp.where(mask[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vc)
        m_new = shard(m_new, "batch", "heads", sp)
        l = shard(l, "batch", "heads", sp)
        acc = shard(acc, "batch", "heads", sp, None)
        return (m_new, l, acc), None

    init = (shard(jnp.full((b, h, sq), NEG_INF, jnp.float32),
                  "batch", "heads", sp),
            shard(jnp.zeros((b, h, sq), jnp.float32), "batch", "heads", sp),
            shard(jnp.zeros((b, h, sq, dh), jnp.float32),
                  "batch", "heads", sp, None))
    (m, l, acc), _ = jax.lax.scan(
        body, init,
        (kf.transpose(3, 0, 1, 2, 4), vf.transpose(2, 0, 1, 3, 4),
         jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)          # B,Sq,H,Dh


def qkv_project(x, p, cfg, positions):
    """x (B,S,D) -> q (B,S,H,Dh), k/v (B,S,Hkv,Dh) with rope + qk-norm.

    Projections are annotated on the FLATTENED out-dim (always model-
    shardable when divisible); the head reshape then reshards as the
    attention layout requires."""
    b, s, _ = x.shape
    q = shard(x @ p["wq"], "batch", None, "attn_out")
    k = shard(x @ p["wk"], "batch", None, "kv_out")
    v = shard(x @ p["wv"], "batch", None, "kv_out")
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head)
    if cfg.qkv_bias:
        q = q + p["bq"].reshape(1, 1, cfg.n_heads, cfg.d_head).astype(q.dtype)
        k = k + p["bk"].reshape(1, 1, cfg.n_kv_heads, cfg.d_head).astype(k.dtype)
        v = v + p["bv"].reshape(1, 1, cfg.n_kv_heads, cfg.d_head).astype(v.dtype)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(q, "batch", None, "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def attention_block(x, p, cfg, *, causal=True, k_chunk=1024):
    """Full-sequence attention (training / prefill)."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = qkv_project(x, p, cfg, positions)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = online_attention(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep),
                           causal=causal, k_chunk=k_chunk)
    out = shard(out.reshape(b, s, cfg.n_heads * cfg.d_head),
                "batch", None, "attn_out")
    return out @ p["wo"]


def decode_attend(q, kf, vf, pos, *, out_dtype):
    """Single-token grouped-head attention over a materialized KV window.

    q (B,1,Hq,Dh) (rope applied); kf/vf (B,S,Hkv,Dh) float32 (cache may be
    padded past ``pos``); pos (B,) int32 — entries with index > pos mask
    out.  Returns (B, 1, Hq*Dh) in ``out_dtype`` (pre-``wo``).

    DIRECT grouped-head attention (no KV repeat, no chunk scan): with the
    cache sequence dim sharded over ``model``, scores stay sharded and only
    the (B,Hkv,G,1)-sized softmax stats and output partials all-reduce —
    vs. all-gathering the full cache per layer (§Perf iteration: cut decode
    collective bytes by ~3 orders of magnitude).
    """
    b, _, hq, dh = q.shape
    hkv = kf.shape[2]
    g = hq // hkv
    smax = kf.shape[1]
    scale = 1.0 / np.sqrt(dh)
    qg = (q * scale).reshape(b, hkv, g, dh).astype(jnp.float32)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, kf)             # (B,Hkv,G,S)
    valid = jnp.arange(smax)[None, :] <= pos[:, None]     # (B,S)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    m = s.max(-1, keepdims=True)
    pexp = jnp.exp(s - m)
    l = pexp.sum(-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", pexp / l, vf)     # (B,Hkv,G,Dh)
    return out.reshape(b, 1, hq * dh).astype(out_dtype)


def decode_attend_paged(q, pos, n_chunks: int, fetch_chunk, *, n_kv_heads,
                        out_dtype):
    """Single-token online-softmax attention over lazily fetched KV chunks.

    The serving engine's quantized paged KV cache reads through this:
    ``fetch_chunk(j) -> (kf, vf, kv_pos)`` with kf/vf (B,C,Hkv,Dh) float32
    and kv_pos (C,) absolute positions — the caller dequantizes exactly one
    page per iteration, so raw-f32 KV for the other pages never
    materializes.  Chunk 0 must contain position 0 (always valid), so the
    running max never stays at ``NEG_INF`` after the first iteration.
    """
    b, _, hq, dh = q.shape
    hkv = n_kv_heads
    g = hq // hkv
    scale = 1.0 / np.sqrt(dh)
    qg = (q * scale).reshape(b, hkv, g, dh).astype(jnp.float32)

    def body(carry, j):
        m, l, acc = carry
        kf, vf, kv_pos = fetch_chunk(j)
        s = jnp.einsum("bkgd,bckd->bkgc", qg, kf)         # (B,Hkv,G,C)
        valid = kv_pos[None, :] <= pos[:, None]           # (B,C)
        s = jnp.where(valid[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + p.sum(-1)
        acc = acc * corr[..., None] + jnp.einsum("bkgc,bckd->bkgd", p, vf)
        return (m_new, l, acc), None

    init = (jnp.full((b, hkv, g), NEG_INF, jnp.float32),
            jnp.zeros((b, hkv, g), jnp.float32),
            jnp.zeros((b, hkv, g, dh), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, 1, hq * dh).astype(out_dtype)


def attention_decode(x, p, cfg, cache_k, cache_v, pos):
    """One-token decode. x (B,1,D); cache (B,Smax,Hkv,Dh); pos (B,) int32.

    Projects q/k/v, writes the new KV row at ``pos``, and attends via
    :func:`decode_attend` (the shared score/softmax core the serving
    engine's paged cache also feeds).  Returns (out (B,1,D), new_k, new_v).
    """
    positions = pos[:, None]
    q, k, v = qkv_project(x, p, cfg, positions)
    cache_k = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0, 0)))(cache_k, k, pos)
    cache_v = jax.vmap(lambda c, u, i: jax.lax.dynamic_update_slice(
        c, u, (i, 0, 0)))(cache_v, v, pos)
    kf = cache_k.astype(jnp.float32)                      # (B,S,Hkv,Dh)
    vf = cache_v.astype(jnp.float32)
    out = decode_attend(q, kf, vf, pos, out_dtype=x.dtype)
    return out @ p["wo"], cache_k, cache_v


def cross_attention_block(x, p, cfg, enc_out):
    """Decoder→encoder cross attention (no rope on encoder keys)."""
    b, s, _ = x.shape
    se = enc_out.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (enc_out @ p["wk"]).reshape(b, se, cfg.n_kv_heads, cfg.d_head)
    v = (enc_out @ p["wv"]).reshape(b, se, cfg.n_kv_heads, cfg.d_head)
    n_rep = cfg.n_heads // cfg.n_kv_heads
    out = online_attention(q, _repeat_kv(k, n_rep), _repeat_kv(v, n_rep),
                           causal=False, k_chunk=1024)
    return out.reshape(b, s, cfg.n_heads * cfg.d_head) @ p["wo"]
