"""Top-k MoE FFN with scatter-based per-sequence-capacity dispatch.

TPU-native formulation (DESIGN.md §6): tokens scatter into a per-sequence
``(E, C, D)`` expert buffer (k small scatters — no (S·k, D) token replication
and no global sort), experts run as one batched einsum (MXU-friendly,
EP-shardable: E lives on the ``model`` mesh axis), outputs gather back with
renormalized gates.  Capacity is per sequence (GShard groups == sequences);
overflow tokens drop to a dummy row, underflow rows are zero.

Router math in fp32; aux load-balance loss returned alongside.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.annotate import shard


def capacity(seq_len: int, n_experts: int, top_k: int,
             capacity_factor: float = 1.25, multiple: int = 8) -> int:
    c = int(seq_len * top_k * capacity_factor / n_experts) + 1
    return max(multiple, ((c + multiple - 1) // multiple) * multiple)


def moe_ffn(x, p, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, norm_topk: bool = True):
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar).

    p: router (D, E), w_gate/w_up (E, D, F), w_down (E, F, D).
    """
    b, s, d = x.shape
    e, k = n_experts, top_k
    c = capacity(s, e, k, capacity_factor)

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                       # (B,S,E)
    gates, idx = jax.lax.top_k(probs, k)                          # (B,S,k)
    if norm_topk:
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # aux loss (Switch-style): E/k * Σ_e f_e · P_e
    sel = jax.nn.one_hot(idx, e, dtype=jnp.float32).sum(2)        # (B,S,E)
    f_e = sel.mean((0, 1))
    p_e = probs.mean((0, 1))
    aux = e / k * jnp.sum(f_e * p_e)

    # position-in-expert per sequence, GATHER formulation: GSPMD shards
    # batched gathers natively, while the scatter form forced an all-gather
    # of the full (B,S,D) activations (§Perf cell-B iteration 2).
    e_flat = idx.reshape(b, s * k)
    order = jnp.argsort(e_flat, axis=1, stable=True)     # sorted-by-expert
    inv = jnp.argsort(order, axis=1)                     # inverse perm
    sorted_e = jnp.take_along_axis(e_flat, order, axis=1)
    seg_start = jax.vmap(
        lambda row: jnp.searchsorted(row, jnp.arange(e), side="left"))(sorted_e)
    pos_sorted = jnp.arange(s * k)[None, :] - jnp.take_along_axis(
        seg_start, sorted_e, axis=1)
    pos = jnp.take_along_axis(pos_sorted, inv, axis=1).reshape(b, s, k)
    keep = pos < c
    slot = jnp.where(keep, idx * c + pos, e * c)                  # dummy = e*c

    # dispatch: expert slot (e, pos) reads token order[seg_start[e]+pos]//k
    flat_c = jnp.arange(e * c)
    slot_e = flat_c // c                                          # (E*C,)
    slot_pos = flat_c % c
    sorted_idx = seg_start[:, slot_e] + slot_pos[None, :]         # (B, E*C)
    seg_end = jnp.concatenate(
        [seg_start[:, 1:], jnp.full((b, 1), s * k)], axis=1)
    slot_valid = sorted_idx < seg_end[:, slot_e]
    sorted_idx = jnp.minimum(sorted_idx, s * k - 1)
    src_tok = jnp.take_along_axis(order, sorted_idx, axis=1) // k  # (B, E*C)
    xe = jnp.take_along_axis(x, src_tok[:, :, None], axis=1)
    xe = xe * slot_valid[:, :, None].astype(x.dtype)
    xe = shard(xe.reshape(b, e, c, d), "batch", "experts", None, None)

    # batched expert SwiGLU
    h_gate = jnp.einsum("becd,edf->becf", xe, p["w_gate"])
    h_up = jnp.einsum("becd,edf->becf", xe, p["w_up"])
    hidden = shard(jax.nn.silu(h_gate) * h_up, "batch", "experts", None, None)
    ye = shard(jnp.einsum("becf,efd->becd", hidden, p["w_down"]),
               "batch", "experts", None, None)

    # combine: gather each slot's output, gate-weight, sum over k
    ye_flat = jnp.concatenate(
        [ye.reshape(b, e * c, d), jnp.zeros((b, 1, d), ye.dtype)], axis=1)
    y = jnp.zeros((b, s, d), jnp.float32)
    for j in range(k):
        yj = jnp.take_along_axis(ye_flat, slot[:, :, j][:, :, None], axis=1)
        y = y + yj.astype(jnp.float32) * (gates[:, :, j] * keep[:, :, j])[..., None]
    return y.astype(x.dtype), aux
