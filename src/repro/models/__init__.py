"""LM substrate: transformer / MoE / SSM / hybrid / enc-dec model zoo."""
from repro.models.transformer import Model, init_model

__all__ = ["Model", "init_model"]
