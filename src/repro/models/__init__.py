"""LM substrate: transformer / MoE / SSM / hybrid / enc-dec model zoo."""
from repro.models.transformer import Model

__all__ = ["Model"]
