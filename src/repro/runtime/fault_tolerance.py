"""Fault-tolerant training runner: checkpoint/auto-resume, failure
injection (for tests), and straggler detection.

On a real multi-host deployment the runner wraps each step in the process
coordinator's barrier; here the same control flow is exercised
single-process — the tests kill a run mid-flight and assert bitwise
continuation from the atomic checkpoint.
"""
from __future__ import annotations

import time

import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


class StragglerMonitor:
    """EWMA step-time monitor.

    A step slower than ``threshold``x the EWMA marks a straggler event; the
    callback is the integration point for mitigation (on a cluster: data
    re-balancing / hot-standby swap; documented in DESIGN.md §9)."""

    def __init__(self, alpha: float = 0.2, threshold: float = 2.5,
                 warmup: int = 3, callback=None):
        self.alpha, self.threshold, self.warmup = alpha, threshold, warmup
        self.callback = callback
        self.ewma = None
        self.n = 0
        self.events: list[tuple[int, float, float]] = []

    def record(self, step: int, dt: float):
        self.n += 1
        if self.ewma is None:
            self.ewma = dt
            return False
        is_straggler = (self.n > self.warmup
                        and dt > self.threshold * self.ewma)
        if is_straggler:
            self.events.append((step, dt, self.ewma))
            if self.callback:
                self.callback(step, dt, self.ewma)
        else:
            # stragglers don't poison the mean
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler


class TrainRunner:
    """step_fn(state, batch) -> (state, metrics); state is any pytree."""

    def __init__(self, step_fn, make_batch, ckpt_dir, *,
                 ckpt_every: int = 50, async_ckpt: bool = True,
                 fail_at_step: int | None = None,
                 monitor: StragglerMonitor | None = None):
        self.step_fn = step_fn
        self.make_batch = make_batch
        self.ckpt_dir = ckpt_dir
        self.ckpt_every = ckpt_every
        self.async_ckpt = async_ckpt
        self.fail_at_step = fail_at_step
        self.monitor = monitor or StragglerMonitor()
        self._pending = None

    def resume_or_init(self, init_state):
        step = latest_step(self.ckpt_dir)
        if step is None:
            return init_state, 0
        state = load_checkpoint(self.ckpt_dir, step, init_state)
        return state, step

    def run(self, init_state, n_steps: int, start_step: int | None = None):
        state, step0 = self.resume_or_init(init_state)
        if start_step is not None:
            step0 = start_step
        metrics_hist = []
        for step in range(step0, n_steps):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.perf_counter()
            batch = self.make_batch(step)
            state, metrics = self.step_fn(state, batch)
            dt = time.perf_counter() - t0
            self.monitor.record(step, dt)
            metrics_hist.append({**{k: float(v) for k, v in metrics.items()},
                                 "step": step, "dt": dt})
            if (step + 1) % self.ckpt_every == 0 or step + 1 == n_steps:
                if self._pending is not None:
                    self._pending.join()
                self._pending = save_checkpoint(
                    self.ckpt_dir, step + 1, state,
                    async_write=self.async_ckpt)
        if self._pending is not None:
            self._pending.join()
        return state, metrics_hist
