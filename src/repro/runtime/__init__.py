from repro.runtime.fault_tolerance import StragglerMonitor, TrainRunner

__all__ = ["StragglerMonitor", "TrainRunner"]
