"""Quantization-health telemetry: the opt-in per-layer in-graph stats
channel (``ObsPolicy(quant_stats=True)``).

For every compressed layer the probe replays, on the live params, exactly
the stash pipeline training runs — the linear input, RP at the layer's
``rp_ratio`` under the forward pass's own seed derivation, regrouped into
the layer's quantization blocks, stochastically rounded onto its level
table — and reduces it in-graph to a handful of scalars per layer:

* block range moments (``E[r]``, ``E[r²]`` — the allocator's sensitivity
  scale),
* clip/saturation rate (fraction of elements landing on the endpoint
  codes 0 / B),
* the **measured** SR dequantization variance ``Σ(x̂ − x)²`` — the
  realized value of the quantity the paper's Eq. 10 predicts.

All layers' stats ship to the host through ONE batched
``jax.debug.callback`` (:func:`tap` — the lint-sanctioned host-callback
route), so the channel is a single stacked ``(L, K)`` transfer per probe
and never touches the training step's jaxpr: obs-on trajectories are
bit-identical to obs-off by construction.

:func:`health_rows` reports measured-vs-predicted side by side (the
runtime validation of the paper's variance-model correction), and
:func:`measured_sensitivity` turns the measured variance into the
``grad_sens``-style per-layer scale :class:`AutoprecController` can use
instead of the two-seed gradient probe
(``PrecisionPolicy(calibration="obs")``).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import quant as quantmod
from repro.core import random_projection as rpmod
from repro.core.autoprec import (LayerStats, expected_layer_variance,
                                 normalized_sr_variance)
from repro.engine.seeds import layer_seed

#: Order of the per-layer stat vector :func:`layer_health` emits.
STAT_FIELDS = ("n_valid", "n_blocks", "sq_err", "rng_mean", "rng_sq_mean",
               "sat_rate")


def tap(fn, *args) -> None:
    """Ship ``*args`` to host callback ``fn`` from inside jitted code.

    The ONE sanctioned spelling of a host callback in traced code: the
    seed-lint ``host-callback-tap`` rule flags raw ``jax.debug.callback``
    / ``pure_callback`` / ``io_callback`` calls in jit-reachable
    functions outside this module, and the ``obs-tap-dataflow`` rule
    keeps :func:`tap` itself off the residual/stash dataflow path
    (``engine/forward.py`` and the offload store) — taps are read-only
    observers, never part of the gradient contract.
    """
    jax.debug.callback(fn, *args)


def layer_health(x, comp, seed, li: int):
    """In-graph health stats of one layer's stash (:data:`STAT_FIELDS`).

    Replays the compress path on ``x`` exactly as
    ``compressed_matmul`` stashes it: per-layer seed
    ``layer_seed(seed, li)``, RP seed ``^ 0xA5A5_A5A5`` (the derivation
    ``core.compressor.compress`` applies), the layer's own group_size /
    level table.  The padded tail ``group_reshape`` replicates is masked
    out of the error and saturation sums, so ``sq_err`` is the measured
    SR dequantization variance of the ``n_valid`` real elements.
    """
    ls = layer_seed(jnp.uint32(seed), li)
    xs = x
    if comp.rp_ratio > 1:
        rp_seed = ls ^ jnp.uint32(0xA5A5_A5A5)
        xs = rpmod.rp(x, rp_seed, max(1, x.shape[1] // comp.rp_ratio))
    blocks, n_valid = quantmod.group_reshape(xs, comp.group_size)
    lv = comp.levels()
    if lv is None:
        lv = quantmod.uniform_levels(comp.bits)
    codes, zero, rng = quantmod.quantize_grouped(blocks, comp.bits, ls, lv)
    deq = quantmod.dequantize_grouped(codes, zero, rng, comp.bits, lv)
    valid = (jnp.arange(blocks.size, dtype=jnp.uint32).reshape(blocks.shape)
             < jnp.uint32(n_valid)).astype(jnp.float32)
    sat = ((codes == 0) | (codes == lv.shape[0] - 1)).astype(jnp.float32)
    rngf = rng.astype(jnp.float32)
    return jnp.stack([
        jnp.float32(n_valid),
        jnp.float32(blocks.shape[0]),
        jnp.sum(((deq - blocks) ** 2) * valid),
        jnp.mean(rngf),
        jnp.mean(rngf ** 2),
        jnp.sum(sat * valid) / jnp.float32(n_valid),
    ])


def _compressed_layers(cfg) -> list[int]:
    return [li for li, c in enumerate(cfg.layer_compression())
            if c is not None]


def _stacked_health(params, gt, cfg, seed):
    """(L_compressed, K) stacked stats over the network, in-graph."""
    # lazy: the graph package imports the engine at module load
    from repro.graph.analysis import _iter_layer_inputs

    per_layer = cfg.layer_compression()
    rows = []
    for li, x in _iter_layer_inputs(params, gt, cfg):
        comp = per_layer[li]
        if comp is not None:
            rows.append(layer_health(x, comp, seed, li))
    if not rows:
        return jnp.zeros((0, len(STAT_FIELDS)), jnp.float32)
    return jnp.stack(rows)


def _unpack(cfg, arr) -> list[dict | None]:
    """One measured dict per network layer (None where uncompressed)."""
    out: list[dict | None] = [None] * len(cfg.layer_compression())
    for li, row in zip(_compressed_layers(cfg), np.asarray(arr)):
        n_valid, n_blocks, sq_err, rmean, rsq, sat = (float(v) for v in row)
        out[li] = {"layer": li, "n_elements": int(n_valid),
                   "n_blocks": int(n_blocks), "measured_var": sq_err,
                   "rng_mean": rmean, "rng_sq_mean": rsq, "sat_rate": sat}
    return out


def measure_quant_health(params, gt, cfg, seed: int = 0) -> list[dict | None]:
    """Run the telemetry probe once, eagerly; per-layer measured dicts.

    The same jitted probe + :func:`tap` channel the runtime monitor uses
    (one spelling of the measurement), drained synchronously — this is
    what ``AutoprecController`` calls under ``calibration="obs"``.
    """
    box: dict = {}

    def sink(stats):
        box["stats"] = np.asarray(stats)

    def probe(params, gt, seed):
        tap(sink, _stacked_health(params, gt, cfg, seed))

    jax.jit(probe)(params, gt, jnp.uint32(seed))
    jax.effects_barrier()
    return _unpack(cfg, box["stats"])


def health_rows(measured, templates) -> list[dict]:
    """Measured rows merged with the Eq. 10 prediction, side by side.

    The prediction is priced from the probe's *own* observed range
    moments — ``n_blocks · G · E[r²] · normalized_sr_variance`` — so the
    ratio column isolates the distribution-model error (CN_[1/D] vs the
    empirical activations), not the range estimate.
    """
    rows = []
    for m, tmpl in zip(measured, templates):
        if m is None or tmpl is None:
            continue
        stat = LayerStats(shape=(m["n_elements"],), n_blocks=m["n_blocks"],
                          rng_sq_mean=m["rng_sq_mean"])
        pred = expected_layer_variance(stat, tmpl)
        rows.append({**m, "bits": tmpl.bits, "predicted_var": pred,
                     "ratio": (m["measured_var"] / pred if pred > 0
                               else float("inf"))})
    return rows


def measured_sensitivity(measured, templates) -> list[float | None]:
    """Per-layer sensitivity from the measured dequant variance.

    Divides out the template width's bit-scaling curve so any candidate
    width re-prices as ``sens * normalized_sr_variance(candidate)`` —
    the exact contract :class:`repro.core.autoprec.LayerStats.grad_sens`
    carries, sourced from telemetry instead of the two-seed grad probe.
    """
    out: list[float | None] = []
    for m, tmpl in zip(measured, templates):
        if m is None or tmpl is None:
            out.append(None)
            continue
        out.append(m["measured_var"]
                   / max(normalized_sr_variance(tmpl), 1e-30))
    return out


class QuantHealthMonitor:
    """The runtime channel: one jitted probe per cfg, records appended by
    the batched callback, merged rows on demand."""

    def __init__(self, cfg, seed: int = 0):
        self.cfg = cfg
        self.seed = seed
        self.templates = cfg.layer_compression()
        self.records: list[tuple[int, np.ndarray]] = []

        def sink(epoch, stats):
            self.records.append((int(epoch), np.asarray(stats)))

        def probe(params, gt, epoch):
            tap(sink, epoch, _stacked_health(params, gt, cfg, self.seed))

        self._probe_fn = jax.jit(probe)

    def probe(self, params, gt, epoch: int) -> None:
        self._probe_fn(params, gt, jnp.asarray(epoch, jnp.int32))

    def rows(self) -> list[dict]:
        """Latest probe's measured-vs-Eq.10 rows (flushes the channel)."""
        jax.effects_barrier()
        if not self.records:
            return []
        epoch, arr = self.records[-1]
        rows = health_rows(_unpack(self.cfg, arr), self.templates)
        for r in rows:
            r["epoch"] = epoch
        return rows

    def history(self) -> list[tuple[int, list[dict]]]:
        jax.effects_barrier()
        return [(e, health_rows(_unpack(self.cfg, a), self.templates))
                for e, a in self.records]
