"""ObsPolicy: the plan-composable observability contract.

The fifth :class:`~repro.engine.plan.ExecutionPlan` policy.  Default is
fully disabled — a disabled policy costs nothing at runtime (the runner
binds the shared null session, every span/metric call is a no-op method
on a singleton) and keeps plan hashes/trajectories untouched.

``enabled=True`` turns on the host-side layer: spans around plan
compile, epochs, mesh rounds, autoprec re-solves and pager fetch waits
(``trace``), and the counters/gauges/histograms registry (``metrics``).
Neither enters jitted code, so trajectories stay **bit-identical** to a
disabled run — gated in ``tests/test_obs.py`` and by the CI overhead
check (obs-on/obs-off epoch-time ratio < 1.05).

``quant_stats=True`` additionally runs the per-layer quantization-health
probe every ``quant_stats_every`` epochs: a *separate* jitted pass
(:mod:`repro.obs.quantstats`) that replays each compressed layer's
RP → block → SR pipeline on the live params and ships block range
moments, saturation rate, and the measured SR dequantization variance to
the host through one batched ``jax.debug.callback`` — the training
step's jaxpr is untouched.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ObsPolicy:
    enabled: bool = False
    trace: bool = True
    metrics: bool = True
    quant_stats: bool = False
    quant_stats_every: int = 10

    def __post_init__(self):
        # Validation errors name the offending field as ``policy.field=value``
        # (the ExecutionPlan convention; plan_verify re-raises these verbatim).
        if self.quant_stats_every < 1:
            raise ValueError(f"obs.quant_stats_every={self.quant_stats_every} "
                             "must be >= 1")
        if self.quant_stats and not self.enabled:
            raise ValueError("obs.quant_stats=True is incompatible with "
                             "obs.enabled=False (the telemetry channel rides "
                             "the obs session; enable it)")
