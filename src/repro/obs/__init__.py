"""Runtime observability: tracing, metrics, quantization-health telemetry.

Three layers, all default-off and all read-only taps (obs-on training is
bit-identical to obs-off — gated in ``tests/test_obs.py``):

* :mod:`~repro.obs.trace` — nested spans (plan compile, epochs, mesh
  rounds, autoprec re-solves, pager fetch waits), exported as JSONL and
  Chrome ``trace_event`` JSON (Perfetto-loadable), plus the repo-wide
  :func:`~repro.obs.trace.stopwatch` timing idiom;
* :mod:`~repro.obs.metrics` — counters / gauges / windowed histograms
  with shared null singletons when disabled (arena occupancy, pager
  overlap, halo bytes, autotune cache hits, recompile counts);
* :mod:`~repro.obs.quantstats` — the opt-in per-layer in-graph stats
  channel: measured SR dequantization variance, range moments and
  saturation rate per layer, shipped through one batched
  ``jax.debug.callback`` and reported side-by-side with the Eq. 10
  prediction; doubles as the ``calibration="obs"`` source for autoprec.

:class:`~repro.obs.policy.ObsPolicy` composes it all onto
:class:`~repro.engine.plan.ExecutionPlan` as the fifth policy;
:class:`~repro.obs.session.ObsSession` is one run's bundle of the three.

Import shape: policy/trace/metrics are stdlib-only and load eagerly
(``engine.plan`` pulls :class:`ObsPolicy` at import time); the
jax-facing session/quantstats modules resolve lazily via PEP 562.
"""
from __future__ import annotations

import importlib

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry, get_metrics, set_metrics)
from repro.obs.policy import ObsPolicy  # noqa: F401
from repro.obs.trace import (Span, Tracer, get_tracer,  # noqa: F401
                             set_tracer, span, stopwatch)

_LAZY = {
    "ObsSession": "repro.obs.session",
    "NULL_SESSION": "repro.obs.session",
    "QuantHealthMonitor": "repro.obs.quantstats",
    "measure_quant_health": "repro.obs.quantstats",
    "health_rows": "repro.obs.quantstats",
    "measured_sensitivity": "repro.obs.quantstats",
    "tap": "repro.obs.quantstats",
}

__all__ = ["ObsPolicy", "Tracer", "Span", "span", "stopwatch", "set_tracer",
           "get_tracer", "MetricsRegistry", "Counter", "Gauge", "Histogram",
           "get_metrics", "set_metrics", *_LAZY]


def __getattr__(name: str):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(f"module 'repro.obs' has no attribute {name!r}")
