"""Host-side span tracer: nested wall-clock spans, exported as JSONL and
Chrome ``trace_event`` JSON (loadable at https://ui.perfetto.dev, and
composable with ``jax.profiler`` device traces — same timeline format).

Everything here is host-side ``time.perf_counter`` bookkeeping; none of
it may run inside jitted code (the seed-lint ``jit-host-nondeterminism``
rule enforces that repo-wide).  The module keeps one *active* tracer
(:func:`set_tracer` / :func:`get_tracer`): producers call the
module-level :func:`span` / :func:`stopwatch` and emit spans only when a
tracer is installed — with none installed both are shared no-op objects,
so instrumented code paths cost a dict-free attribute check when
observability is off.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time


@dataclasses.dataclass
class Span:
    """One closed (or still-open) span; times are seconds relative to the
    tracer's origin."""

    name: str
    t0: float
    dur: float
    depth: int
    parent: int  # index into Tracer.spans, -1 for roots
    args: dict


class Tracer:
    """Nested-span recorder.  Single-threaded by design: spans nest on
    one stack, matching the engine's single-process epoch loop."""

    def __init__(self):
        self._origin = time.perf_counter()
        #: wall-clock epoch of the origin, for aligning with external traces
        self.origin_unix_s = time.time()
        self.spans: list[Span] = []
        self._stack: list[int] = []

    @contextlib.contextmanager
    def span(self, name: str, **args):
        idx = len(self.spans)
        s = Span(name, time.perf_counter() - self._origin, 0.0,
                 depth=len(self._stack),
                 parent=self._stack[-1] if self._stack else -1,
                 args=args)
        self.spans.append(s)
        self._stack.append(idx)
        try:
            yield s
        finally:
            self._stack.pop()
            s.dur = time.perf_counter() - self._origin - s.t0

    # ------------------------------------------------------------- export
    def jsonl_events(self) -> list[dict]:
        return [{"name": s.name, "ts_s": s.t0, "dur_s": s.dur,
                 "depth": s.depth, "parent": s.parent, "args": s.args}
                for s in self.spans]

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` dict: complete ("X") events in µs."""
        events = [{"name": s.name, "cat": "repro", "ph": "X",
                   "ts": s.t0 * 1e6, "dur": s.dur * 1e6,
                   "pid": 0, "tid": 0, "args": s.args}
                  for s in self.spans]
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_jsonl(self, path) -> None:
        lines = [json.dumps(e) for e in self.jsonl_events()]
        pathlib.Path(path).write_text("\n".join(lines) + "\n")

    def export_chrome(self, path) -> None:
        pathlib.Path(path).write_text(json.dumps(self.chrome_trace()))


_ACTIVE: Tracer | None = None
_NULL_CM = contextlib.nullcontext()


def set_tracer(tracer: Tracer | None) -> Tracer | None:
    """Install ``tracer`` as the process-wide active tracer; returns the
    previous one (restore it when the session ends)."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, tracer
    return prev


def get_tracer() -> Tracer | None:
    return _ACTIVE


def span(name: str, **args):
    """Span on the active tracer; a shared no-op when none is installed."""
    return _ACTIVE.span(name, **args) if _ACTIVE is not None else _NULL_CM


class stopwatch:
    """The repo-wide timing idiom (replaces ad-hoc ``time.perf_counter``
    pairs): always measures ``elapsed_s``; when given a name *and* a
    tracer is active, the measured interval is also emitted as a span —
    benchmarks and the engine loop emit trace spans for free.

    >>> with stopwatch("epoch", epoch=3) as sw:
    ...     work()
    >>> sw.elapsed_s
    """

    def __init__(self, name: str | None = None, **args):
        self._name, self._args = name, args
        self.elapsed_s = 0.0

    def __enter__(self) -> "stopwatch":
        self._cm = span(self._name, **self._args) if self._name else None
        if self._cm is not None:
            self._cm.__enter__()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.elapsed_s = time.perf_counter() - self._t0
        if self._cm is not None:
            self._cm.__exit__(*exc)
        return False
