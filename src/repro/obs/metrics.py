"""Counters / gauges / windowed histograms with near-zero cost when
disabled.

A disabled :class:`MetricsRegistry` hands out shared null singletons
whose methods are empty — producers instrument unconditionally
(``registry.counter("autotune/cache_hit").inc()``) and pay one no-op
method call when observability is off.  Like the tracer, the module
keeps one *active* registry (:func:`set_metrics` / :func:`get_metrics`,
default disabled) for producers that have no session handy (the
autotuner's trace-time cache reads, the forward builder's recompile
counter).

:class:`Histogram` keeps cumulative moments **and** a bounded window of
the most recent observations — the fix for the pager's ``overlap_frac``,
which as a single end-of-run scalar hid early-epoch stalls behind a
steady-state average.
"""
from __future__ import annotations

from collections import deque


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def max(self, v: float) -> None:
        if v > self.value:
            self.value = v


class Histogram:
    """Cumulative count/total/min/max plus a sliding window of the last
    ``window`` observations (recent behavior vs lifetime average)."""

    __slots__ = ("count", "total", "vmin", "vmax", "_window")

    def __init__(self, window: int = 64):
        self.count = 0
        self.total = 0.0
        self.vmin = float("inf")
        self.vmax = float("-inf")
        self._window: deque = deque(maxlen=max(1, int(window)))

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.vmin:
            self.vmin = v
        if v > self.vmax:
            self.vmax = v
        self._window.append(v)

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def window_size(self) -> int:
        return len(self._window)

    @property
    def window_mean(self) -> float:
        return sum(self._window) / len(self._window) if self._window else 0.0

    @property
    def window_min(self) -> float:
        return min(self._window) if self._window else 0.0

    @property
    def window_max(self) -> float:
        return max(self._window) if self._window else 0.0

    def snapshot(self) -> dict:
        return {"count": self.count, "mean": self.mean,
                "min": self.vmin if self.count else 0.0,
                "max": self.vmax if self.count else 0.0,
                "window_mean": self.window_mean,
                "window_min": self.window_min,
                "window_max": self.window_max,
                "window_size": self.window_size}


class _NullCounter:
    __slots__ = ()
    value = 0

    def inc(self, n: int = 1) -> None:
        pass


class _NullGauge:
    __slots__ = ()
    value = 0.0

    def set(self, v: float) -> None:
        pass

    def max(self, v: float) -> None:
        pass


class _NullHistogram:
    __slots__ = ()
    count = 0
    mean = 0.0
    window_size = 0
    window_mean = 0.0
    window_min = 0.0
    window_max = 0.0

    def observe(self, v: float) -> None:
        pass

    def snapshot(self) -> dict:
        return {}


NULL_COUNTER = _NullCounter()
NULL_GAUGE = _NullGauge()
NULL_HISTOGRAM = _NullHistogram()


class MetricsRegistry:
    """Name → metric map.  Disabled registries never allocate: every
    accessor returns the shared null singleton."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        if not self.enabled:
            return NULL_COUNTER
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def gauge(self, name: str) -> Gauge:
        if not self.enabled:
            return NULL_GAUGE
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge()
        return g

    def histogram(self, name: str, window: int = 64) -> Histogram:
        if not self.enabled:
            return NULL_HISTOGRAM
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram(window=window)
        return h

    def snapshot(self) -> dict:
        out: dict = {}
        out.update({k: c.value for k, c in self._counters.items()})
        out.update({k: g.value for k, g in self._gauges.items()})
        out.update({k: h.snapshot() for k, h in self._hists.items()})
        return out


#: Process-wide registry for producers without a session handle; disabled
#: until an :class:`~repro.obs.session.ObsSession` activates its own.
_ACTIVE = MetricsRegistry(enabled=False)


def set_metrics(registry: MetricsRegistry) -> MetricsRegistry:
    """Install the active registry; returns the previous one."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, registry
    return prev


def get_metrics() -> MetricsRegistry:
    return _ACTIVE
