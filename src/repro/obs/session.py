"""ObsSession: one run's observability state, built from the plan's
:class:`~repro.obs.policy.ObsPolicy`.

Bundles the tracer, the metrics registry, and the quant-health monitor;
``activate()`` installs the tracer/registry as the process-wide actives
(so producers without a session handle — the autotuner, the forward
builder, benchmark stopwatches — land in the same sinks) and restores
the previous ones on exit.  The shared :data:`NULL_SESSION` serves every
disabled run: all of its span/metric methods are no-ops, so the engine
instruments unconditionally.
"""
from __future__ import annotations

import contextlib
import dataclasses
import pathlib

from repro.obs import metrics as metricsmod
from repro.obs import trace as tracemod
from repro.obs.metrics import (NULL_COUNTER, NULL_GAUGE, NULL_HISTOGRAM,
                               MetricsRegistry)
from repro.obs.policy import ObsPolicy
from repro.obs.trace import Tracer

_NULL_CM = contextlib.nullcontext()


class ObsSession:
    def __init__(self, policy: ObsPolicy):
        self.policy = policy
        self.enabled = policy.enabled
        self.tracer: Tracer | None = (Tracer() if policy.enabled
                                      and policy.trace else None)
        self.registry: MetricsRegistry | None = (
            MetricsRegistry() if policy.enabled and policy.metrics else None)
        self._quant = None

    @classmethod
    def from_policy(cls, policy: ObsPolicy | None) -> "ObsSession":
        if policy is None or not policy.enabled:
            return NULL_SESSION
        return cls(policy)

    # ------------------------------------------------------------ lifetime
    @contextlib.contextmanager
    def activate(self):
        """Install this session's tracer/registry as the process actives
        for the duration (restoring the previous ones after)."""
        prev_t = tracemod.set_tracer(self.tracer) if self.tracer else None
        prev_m = (metricsmod.set_metrics(self.registry)
                  if self.registry else None)
        try:
            yield self
        finally:
            if self.tracer is not None:
                tracemod.set_tracer(prev_t)
            if self.registry is not None:
                metricsmod.set_metrics(prev_m)

    # --------------------------------------------------------------- spans
    def span(self, name: str, **args):
        return (self.tracer.span(name, **args) if self.tracer is not None
                else _NULL_CM)

    # ------------------------------------------------------------- metrics
    def counter(self, name: str):
        return (self.registry.counter(name) if self.registry is not None
                else NULL_COUNTER)

    def gauge(self, name: str):
        return (self.registry.gauge(name) if self.registry is not None
                else NULL_GAUGE)

    def histogram(self, name: str, window: int = 64):
        return (self.registry.histogram(name, window=window)
                if self.registry is not None else NULL_HISTOGRAM)

    # -------------------------------------------------------- quant health
    def quant_due(self, epoch: int) -> bool:
        p = self.policy
        return (p.enabled and p.quant_stats
                and epoch % p.quant_stats_every == 0)

    def quant_probe(self, params, gt, epoch: int, cfg) -> None:
        """Run the telemetry probe (rebuilt when autoprec swaps cfg)."""
        from repro.obs.quantstats import QuantHealthMonitor

        if self._quant is None or self._quant.cfg != cfg:
            self._quant = QuantHealthMonitor(cfg)
        self._quant.probe(params, gt, epoch)

    def quant_rows(self) -> list[dict]:
        return self._quant.rows() if self._quant is not None else []

    # -------------------------------------------------------------- export
    def export(self, base_path) -> dict:
        """Write the trace as ``<base>.jsonl`` + ``<base>.trace.json``
        (the latter loads directly in Perfetto); returns the paths."""
        if self.tracer is None:
            return {}
        p = pathlib.Path(base_path)
        if p.suffix in (".jsonl", ".json"):
            p = p.with_suffix("")
        p.parent.mkdir(parents=True, exist_ok=True)
        jsonl = p.with_suffix(".jsonl")
        chrome = p.with_suffix(".trace.json")
        self.tracer.export_jsonl(jsonl)
        self.tracer.export_chrome(chrome)
        return {"jsonl": str(jsonl), "chrome": str(chrome)}

    def summary(self) -> dict:
        out: dict = {"policy": dataclasses.asdict(self.policy)}
        if self.tracer is not None:
            out["n_spans"] = len(self.tracer.spans)
        if self.registry is not None:
            out["metrics"] = self.registry.snapshot()
        if self._quant is not None:
            out["quant_health"] = self.quant_rows()
        return out


#: The shared disabled session every obs-off run binds.
NULL_SESSION = ObsSession(ObsPolicy())
