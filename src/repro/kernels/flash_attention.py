"""Flash attention (Pallas, TPU target) — §Perf cell-B3 follow-up.

The roofline analysis showed prefill_32k memory terms dominated by
attention-score HBM traffic (S² tiles materialized by the pure-XLA online
softmax under CPU-backend fusion).  This kernel keeps the (BLK_Q, BLK_K)
score tile and the running (m, l, acc) statistics in VMEM scratch across
the K-block grid dimension, so score traffic never reaches HBM — the
classic FlashAttention dataflow mapped to MXU tiles.

Grid: (B·H, Sq/BLK_Q, Skv/BLK_K), K innermost.  Causal masking by global
block indices; fully-masked K blocks are skipped via ``pl.when``.
Validated in interpret mode against ``ref.py``'s softmax oracle.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, blk_q: int, blk_k: int, n_k: int, causal: bool,
                  scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qi = pl.program_id(1)
    # with causal masking, K blocks strictly above the diagonal contribute
    # nothing — skip their compute entirely
    live = (not causal) or (ki * blk_k < (qi + 1) * blk_q)

    @pl.when(live)
    def _step():
        q = q_ref[0].astype(jnp.float32)                 # (blk_q, dh)
        k = k_ref[0].astype(jnp.float32)                 # (blk_k, dh)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                    # (blk_q, blk_k)
        if causal:
            rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
                + qi * blk_q
            cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1) \
                + ki * blk_k
            s = jnp.where(cols <= rows, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_call(q, k, v, *, causal: bool = True,
                         blk_q: int = 128, blk_k: int = 128,
                         interpret: bool = False):
    """q (BH, Sq, Dh), k/v (BH, Skv, Dh) — heads pre-flattened into BH.

    Sq % blk_q == 0 and Skv % blk_k == 0 (pad outside).
    """
    bh, sq, dh = q.shape
    skv = k.shape[1]
    assert sq % blk_q == 0 and skv % blk_k == 0, (sq, skv)
    n_q, n_k = sq // blk_q, skv // blk_k
    scale = 1.0 / np.sqrt(dh)
    kern = functools.partial(_flash_kernel, blk_q=blk_q, blk_k=blk_k,
                             n_k=n_k, causal=causal, scale=scale)
    return pl.pallas_call(
        kern,
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, blk_k, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_q, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            # (blk_q, 1) running max / denom and (blk_q, dh) accumulator,
            # carried in VMEM across the K-block grid dimension
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
