"""Fused quantize-in-epilogue matmul / dequantize-in-prologue matmul.

The two kernels that put the Pallas backend on the training hot path
(ROADMAP item 1; ActNN/GACT compress activations *as they are produced*):

* :func:`matmul_quant_call` — ``y = x @ w`` whose **epilogue** computes
  per-block (zero, range) stats over the ``x`` tile, stochastically
  rounds, and bit-packs the codes while the tile is still in VMEM.  The
  unfused path reads ``x`` from HBM twice (matmul, then the separate
  compress pass) and writes the f32 normalized intermediate back out;
  fused, ``x`` is read once and only the packed words leave the chip.
* :func:`dequant_matmul_call` — ``dw = x̂ᵀ @ g`` whose **prologue**
  unpacks + dequantizes the stashed codes tile straight into the matmul
  operand, removing the HBM materialization of the f32 reconstruction
  between the unfused dequantize and the backward matmul.

Bit-parity contract
-------------------
SR codes are bit-identical to the unfused ``ref`` path by construction:
per-block stats are the same lane reductions, SR noise is the same
murmur3 counter hash on the *global* element index (the fused grid offsets
block ids by ``i * blocks_per_row_tile``), and the strided pack layout is
shared with :mod:`repro.kernels.quant_blockwise` (whose ``_sr_codes`` /
``_levels_value`` helpers are reused verbatim).  The forward matmul tile
``(TM, D) @ (D, TN)`` keeps the full contraction in one dot, so ``y`` is
the same per-element reduction as the unfused ``x @ w``.  The backward
contraction over rows is exact when run as a single row tile
(``tile_rows == M``, the default everywhere bit-parity is gated).
Tiling rows splits the accumulation into per-tile partials combined by a
**fixed-order pairwise tree** (:func:`_tree_sum`): bit-stable
run-to-run and across backends/grid schedules (the order is a pure
function of the tile count), agreeing with the single-tile order to
float tolerance — so the autotuner may pick tiled backward candidates
off-TPU too, whenever they actually win.

Eligibility (quantization blocks must coincide with whole row tiles) is
owned by :func:`repro.core.backend.supports_fused`; these kernels assert
the same invariants.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.prng import uniform_from_counter
from repro.core.quant import EPS as _EPS
from repro.kernels.quant_blockwise import _levels_value, _sr_codes


def _quant_epilogue(x, seed, row0_blocks, bits: int, group_size: int,
                    levels):
    """Quantize+pack a (rows, D) tile whose flat layout is whole blocks.

    Returns (packed (nb, W), zero (nb, 1), rng (nb, 1)) with nb =
    rows * D // group_size — exactly the rows this tile owns of the
    global packed array.
    """
    rows, d = x.shape
    nb = rows * d // group_size
    xb = x.reshape(nb, group_size)
    B = jnp.float32(2**bits - 1)
    zero = jnp.min(xb, axis=1, keepdims=True)
    rng = jnp.max(xb, axis=1, keepdims=True) - zero
    h = jnp.clip((xb - zero) / jnp.maximum(rng, _EPS) * B, 0.0, B)
    rid = jax.lax.broadcasted_iota(jnp.uint32, xb.shape, 0) + row0_blocks
    cid = jax.lax.broadcasted_iota(jnp.uint32, xb.shape, 1)
    u = uniform_from_counter(seed, rid * jnp.uint32(group_size) + cid)
    codes = _sr_codes(h, u, bits, levels)
    vpw = 32 // bits
    w = group_size // vpw
    packed = jnp.zeros((nb, w), jnp.uint32)
    for k in range(vpw):
        packed = packed | (codes[:, k * w:(k + 1) * w] << jnp.uint32(k * bits))
    return packed, zero, rng


def _matmul_quant_kernel(seed_ref, x_ref, w_ref, y_ref, packed_ref,
                         zero_ref, rng_ref, *, bits: int, group_size: int,
                         blocks_per_tile: int, levels):
    x = x_ref[...].astype(jnp.float32)                       # (TM, D)
    y_ref[...] = jnp.dot(x, w_ref[...].astype(jnp.float32),
                         preferred_element_type=jnp.float32)

    # the stash outputs depend only on the row tile i: emit once, on the
    # first N-tile visit (the blocks stay resident across j).  program_id
    # must be read outside the pl.when body — inside the cond jaxpr it is
    # not rewritten by interpret mode.
    row0 = (pl.program_id(0) * blocks_per_tile).astype(jnp.uint32)

    @pl.when(pl.program_id(1) == 0)
    def _epilogue():
        packed, zero, rng = _quant_epilogue(
            x, seed_ref[0, 0], row0, bits, group_size, levels)
        packed_ref[...] = packed
        zero_ref[...] = zero
        rng_ref[...] = rng


def _build_matmul_quant(m, d, n, bits, group_size, levels, tm, tn,
                        interpret):
    assert m % tm == 0 and n % tn == 0, (m, n, tm, tn)
    assert (tm * d) % group_size == 0, (tm, d, group_size)
    vpw = 32 // bits
    assert group_size % vpw == 0, (group_size, vpw)
    bpt = tm * d // group_size          # packed rows owned by one row tile
    nb = m * d // group_size
    wpb = group_size // vpw
    kern = functools.partial(_matmul_quant_kernel, bits=bits,
                             group_size=group_size, blocks_per_tile=bpt,
                             levels=levels)
    return pl.pallas_call(
        kern,
        grid=(m // tm, n // tn),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((tm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, tn), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
            pl.BlockSpec((bpt, wpb), lambda i, j: (i, 0)),
            pl.BlockSpec((bpt, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bpt, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.float32),
            jax.ShapeDtypeStruct((nb, wpb), jnp.uint32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
            jax.ShapeDtypeStruct((nb, 1), jnp.float32),
        ],
        interpret=interpret,
    )


def matmul_quant_call(x2d, w, bits: int, seed, levels=None, *,
                      group_size: int, tm: int = 128, tn: int = 128,
                      interpret: bool = False):
    """Fused forward: ``y = x @ w`` + quantize/pack ``x`` in the epilogue.

    Returns ``(y (M, N) f32, packed (M*D/G, G*bits/32) u32,
    zero (M*D/G, 1) f32, rng (M*D/G, 1) f32)`` — the stash triplet is
    bit-identical to ``quant_pack_call`` / the jnp reference on the same
    ``x``.
    """
    m, d = x2d.shape
    n = w.shape[1]
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    call = _build_matmul_quant(m, d, n, bits, group_size,
                               levels, tm, tn, interpret)
    return call(seed_arr, x2d, w)


def _dequant_matmul_kernel(packed_ref, zero_ref, rng_ref, g_ref, dw_ref,
                           *, bits: int, group_size: int, rows: int,
                           d: int, levels):
    words = packed_ref[...]                                  # (nb, W)
    vpw = 32 // bits
    mask = jnp.uint32(2**bits - 1)
    parts = [(words >> jnp.uint32(kk * bits)) & mask for kk in range(vpw)]
    codes = jnp.concatenate(parts, axis=1)                   # (nb, G)
    vals = _levels_value(codes, bits, levels)
    B = jnp.float32(2**bits - 1)
    x_hat = (vals * (rng_ref[...] / B) + zero_ref[...]).reshape(rows, d)
    g = g_ref[...].astype(jnp.float32)                       # (rows, TN)
    # each row tile writes its own partial — no cross-iteration += whose
    # summation order the grid schedule would own.  The fixed-order tree
    # reduction over the K partials happens outside the kernel.
    dw_ref[...] = jnp.dot(x_hat.T, g,
                          preferred_element_type=jnp.float32)[None]


def _tree_sum(parts):
    """Fixed-order pairwise reduction over the leading axis.

    Deterministic by construction: level l adds partial ``2i`` to partial
    ``2i+1`` (odd tails ride along unadded), independent of grid schedule
    or backend — the accumulation order is a pure function of K.
    """
    k = parts.shape[0]
    while k > 1:
        half = k // 2
        paired = parts[: 2 * half]
        parts = jnp.concatenate(
            [paired[0::2] + paired[1::2], parts[2 * half:]], axis=0)
        k = parts.shape[0]
    return parts[0]


def dequant_matmul_call(packed, zero, rng, g2d, bits: int, group_size: int,
                        d: int, levels=None, *, tile_rows: int | None = None,
                        tn: int = 128, interpret: bool = False):
    """Fused backward: ``dw = dequant(packed)ᵀ @ g`` (D, N).

    ``packed`` (M*D/G, W) + (zero, rng) (M*D/G, 1) are the stash of an
    (M, D) activation; ``g2d`` is (M, N).  ``tile_rows`` tiles the row
    contraction — ``None`` (default) runs it as ONE tile, whose single
    dot keeps the per-element reduction identical to the unfused
    ``x̂ᵀ @ g`` (the bit-parity configuration).  Smaller tiles (real-TPU
    VMEM sizing via the autotuner) emit one ``(D, TN)`` partial per row
    tile and combine them with :func:`_tree_sum`: bit-stable run-to-run
    and across grid schedules, float-tolerance vs the single-tile order.
    """
    m, n = g2d.shape
    tile_rows = m if tile_rows is None else tile_rows
    assert m % tile_rows == 0 and n % tn == 0, (m, n, tile_rows, tn)
    assert (tile_rows * d) % group_size == 0, (tile_rows, d, group_size)
    bpt = tile_rows * d // group_size
    k_tiles = m // tile_rows
    kern = functools.partial(_dequant_matmul_kernel, bits=bits,
                             group_size=group_size, rows=tile_rows, d=d,
                             levels=levels)
    wpb = group_size // (32 // bits)
    parts = pl.pallas_call(
        kern,
        grid=(n // tn, k_tiles),
        in_specs=[
            pl.BlockSpec((bpt, wpb), lambda j, k: (k, 0)),
            pl.BlockSpec((bpt, 1), lambda j, k: (k, 0)),
            pl.BlockSpec((bpt, 1), lambda j, k: (k, 0)),
            pl.BlockSpec((tile_rows, tn), lambda j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((1, d, tn), lambda j, k: (k, 0, j)),
        out_shape=jax.ShapeDtypeStruct((k_tiles, d, n), jnp.float32),
        interpret=interpret,
    )(packed, zero, rng, g2d)
    if k_tiles == 1:
        return parts[0]
    return _tree_sum(parts)
