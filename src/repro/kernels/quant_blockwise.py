"""Fused block-wise quantize(+SR+pack) / dequantize(+unpack) Pallas kernels.

TPU adaptation of the paper's CUDA quantizer (DESIGN.md §4):

* one VMEM round-trip per direction — stats, normalize, stochastic round,
  and bit-pack all happen on the (ROWS, G) tile in registers/VMEM, vs. the
  four HBM-materializing steps of the reference path;
* blocks ARE the tile rows: ``G`` is the lane dimension, so per-block
  min/max are lane reductions and the strided packing is a shift/or over
  full-lane slices (word ``j`` holds codes ``[j, j+W, ...]``, matching
  ``repro.core.pack``);
* SR noise comes from the murmur3 counter hash on the *global* element
  index, so codes are bit-identical to ``repro.kernels.ref`` for any grid.

VM levels (paper §3.2) arrive as a static tuple and are unrolled into
compare/select chains (≤16 levels, i.e. bits ≤ 4; uniform levels use the
closed-form floor path for any width).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.prng import uniform_from_counter
from repro.core.quant import EPS as _EPS  # single shared clamp constant


def _sr_codes(h, u, bits: int, levels):
    """Stochastic-round normalized h in [0,B] to level indices (uint32)."""
    B = 2**bits - 1
    if levels is None:
        lo = jnp.floor(h)
        p_up = h - lo
        return lo.astype(jnp.uint32) + (u < p_up).astype(jnp.uint32)
    # non-uniform (VM) levels: unrolled bin search over a static table
    idx = jnp.zeros(h.shape, jnp.uint32)
    for lv in levels[1:-1]:
        idx = idx + (h >= jnp.float32(lv)).astype(jnp.uint32)
    lo = jnp.full(h.shape, jnp.float32(levels[0]))
    hi = jnp.full(h.shape, jnp.float32(levels[-1]))
    for i, lv in enumerate(levels[:-1]):
        sel = idx == jnp.uint32(i)
        lo = jnp.where(sel, jnp.float32(levels[i]), lo)
        hi = jnp.where(sel, jnp.float32(levels[i + 1]), hi)
    p_up = (h - lo) / jnp.maximum(hi - lo, _EPS)
    return idx + (u < p_up).astype(jnp.uint32)


def _levels_value(codes, bits: int, levels):
    """Map level indices back to level values (f32)."""
    if levels is None:
        return codes.astype(jnp.float32)
    out = jnp.zeros(codes.shape, jnp.float32)
    for i, lv in enumerate(levels):
        out = jnp.where(codes == jnp.uint32(i), jnp.float32(lv), out)
    return out


def _quant_pack_kernel(seed_ref, x_ref, packed_ref, zero_ref, rng_ref,
                       *, bits: int, group_size: int, rows: int, levels):
    x = x_ref[...].astype(jnp.float32)                      # (rows, G)
    B = jnp.float32(2**bits - 1)
    zero = jnp.min(x, axis=1, keepdims=True)
    rng = jnp.max(x, axis=1, keepdims=True) - zero
    h = jnp.clip((x - zero) / jnp.maximum(rng, _EPS) * B, 0.0, B)

    row0 = (pl.program_id(0) * rows).astype(jnp.uint32)
    rid = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 0) + row0
    cid = jax.lax.broadcasted_iota(jnp.uint32, x.shape, 1)
    u = uniform_from_counter(seed_ref[0, 0], rid * jnp.uint32(group_size) + cid)

    codes = _sr_codes(h, u, bits, levels)
    vpw = 32 // bits
    w = group_size // vpw
    packed = jnp.zeros((x.shape[0], w), jnp.uint32)
    for k in range(vpw):
        packed = packed | (codes[:, k * w:(k + 1) * w] << jnp.uint32(k * bits))
    packed_ref[...] = packed
    zero_ref[...] = zero
    rng_ref[...] = rng


def _dequant_unpack_kernel(packed_ref, zero_ref, rng_ref, out_ref,
                           *, bits: int, group_size: int, levels):
    words = packed_ref[...]                                  # (rows, W)
    vpw = 32 // bits
    mask = jnp.uint32(2**bits - 1)
    parts = [(words >> jnp.uint32(k * bits)) & mask for k in range(vpw)]
    codes = jnp.concatenate(parts, axis=1)                   # (rows, G)
    vals = _levels_value(codes, bits, levels)
    B = jnp.float32(2**bits - 1)
    out_ref[...] = vals * (rng_ref[...] / B) + zero_ref[...]


def quant_pack_call(x2d, bits: int, seed, levels=None, *,
                    rows_per_tile: int = 8, interpret: bool = False):
    """x2d (n_blocks, G) -> (packed, zero(n,1), rng(n,1)); n_blocks % rows == 0."""
    n, g = x2d.shape
    vpw = 32 // bits
    assert g % vpw == 0, f"group_size {g} must be a multiple of {vpw}"
    assert n % rows_per_tile == 0
    w = g // vpw
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    kern = functools.partial(_quant_pack_kernel, bits=bits, group_size=g,
                             rows=rows_per_tile, levels=levels)
    grid = (n // rows_per_tile,)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((rows_per_tile, g), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((rows_per_tile, w), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, w), jnp.uint32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.float32),
        ],
        interpret=interpret,
    )(seed_arr, x2d)


def dequant_unpack_call(packed, zero, rng, bits: int, group_size: int,
                        levels=None, *, rows_per_tile: int = 8,
                        interpret: bool = False):
    """(packed, zero(n,1), rng(n,1)) -> x_hat (n_blocks, G) f32."""
    n, w = packed.shape
    assert w * (32 // bits) == group_size
    assert n % rows_per_tile == 0
    kern = functools.partial(_dequant_unpack_kernel, bits=bits,
                             group_size=group_size, levels=levels)
    grid = (n // rows_per_tile,)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_per_tile, w), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, 1), lambda i: (i, 0)),
            pl.BlockSpec((rows_per_tile, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_per_tile, group_size), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, group_size), jnp.float32),
        interpret=interpret,
    )(packed, zero, rng)
