"""Pallas TPU kernels for the paper's compute hot-spot: activation
quantize/dequantize (+pack/unpack) and the seeded random projection.

``ops``  — public jit'd wrappers (impl = pallas | interp | jnp | auto)
``ref``  — pure-jnp oracles (bit-identical codes; dequant allclose @ 1e-5)
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
