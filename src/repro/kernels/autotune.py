"""Tile-size autotuning for the fused matmul-quant kernels.

Two layers:

* a **roofline model** (hardware constants shared with
  :mod:`benchmarks.roofline` when importable) ranks every legal
  ``(tm, tn)`` candidate by predicted time — max of the compute term and
  the HBM term, where larger ``tm`` cuts repeated ``w`` reads and larger
  ``tn`` cuts repeated ``x`` reads, subject to a VMEM budget;
* an optional **measurement pass** (:func:`autotune`) times the real
  kernel over the model's top candidates and persists the winner in a
  JSON cache keyed on ``(shape, bits, group_size, backend)`` —
  ``results/autotune/fused_tiles.json`` by default, overridable via
  ``REPRO_AUTOTUNE_CACHE``.

:func:`get_tiles` is the trace-time read path the dispatch layer uses:
cache hit → cached tiles; miss → roofline-best default.  It never
measures (measurement re-jits; ``scripts/refresh_experiments.py --bench``
refreshes the cache deliberately).

Legality: a row tile must own whole quantization blocks —
``(tm * d) % group_size == 0`` — which is the same invariant
:func:`repro.core.backend.supports_fused` enforces for the shape overall.
"""
from __future__ import annotations

import functools
import json
import math
import os
import pathlib

_REPO = pathlib.Path(__file__).resolve().parents[3]
_DEFAULT_CACHE = _REPO / "results" / "autotune" / "fused_tiles.json"

try:  # single source for the hardware constants when the bench dir is on path
    from benchmarks.roofline import HBM_BW, PEAK_FLOPS
except Exception:  # pragma: no cover - library use without the bench dir
    PEAK_FLOPS = 197e12
    HBM_BW = 819e9

#: VMEM working-set budget per kernel invocation (bytes); v5e has 128 MB
#: of VMEM but leave generous headroom for double-buffering + the packed
#: epilogue outputs.
VMEM_BUDGET = 8 << 20


def cache_path() -> pathlib.Path:
    return pathlib.Path(os.environ.get("REPRO_AUTOTUNE_CACHE",
                                       str(_DEFAULT_CACHE)))


def _cache_key(kind: str, m: int, d: int, n: int, bits: int,
               group_size: int, backend: str) -> str:
    return f"{kind}/{m}x{d}x{n}/b{bits}/g{group_size}/{backend}"


@functools.lru_cache(maxsize=1)
def _load_cache() -> dict:
    p = cache_path()
    if p.exists():
        try:
            return json.loads(p.read_text())
        except Exception:
            return {}
    return {}


def invalidate_cache() -> None:
    _load_cache.cache_clear()


def row_tile_step(d: int, group_size: int) -> int:
    """Smallest row-tile increment keeping whole blocks per tile."""
    return group_size // math.gcd(group_size, d)


def fwd_candidates(m: int, d: int, n: int, group_size: int):
    """Legal (tm, tn) pairs for the fused forward, VMEM-feasible."""
    step = row_tile_step(d, group_size)
    out = []
    for base in (8, 16, 32, 64, 128, 256, 512):
        tm = max(step, step * (base // step)) if step <= base else step
        tm = min(tm, ((m + step - 1) // step) * step)
        for tn in (128, 256, 512):
            tn = min(tn, n)
            vmem = 4 * (tm * d + d * tn + tm * tn + tm * d // 8)
            if vmem <= VMEM_BUDGET and (tm, tn) not in out:
                out.append((tm, tn))
    return out or [(step, min(128, n))]


def fwd_roofline_us(m: int, d: int, n: int, tm: int, tn: int,
                    bits: int = 2) -> float:
    """Predicted fused-forward time (µs) for one (tm, tn) choice."""
    gi = -(-m // tm)
    gj = -(-n // tn)
    flops = 2.0 * m * d * n
    # x read once per N tile, w once per M tile, y written once, packed out
    bytes_moved = (4.0 * m * d * gj + 4.0 * d * n * gi + 4.0 * m * n
                   + m * d * bits / 8 + 8.0 * m * d / 64)
    return max(flops / PEAK_FLOPS, bytes_moved / HBM_BW) * 1e6


def bwd_candidates(m: int, d: int, n: int, group_size: int):
    """Legal (tile_rows, tn) pairs for the fused backward.

    ``tile_rows = m`` (single tile) leads — it is the bit-exact
    configuration; row-tiled candidates follow for VMEM-constrained
    deployment shapes.
    """
    step = row_tile_step(d, group_size)
    out = []
    for tile_rows in (m, 512, 256, 128):
        if tile_rows > m or tile_rows % step or m % tile_rows:
            continue
        for tn in (128, 256):
            tn = min(tn, n)
            vmem = 4 * (tile_rows * d + tile_rows * tn + d * tn
                        + tile_rows * d // 8)
            if vmem <= VMEM_BUDGET or tile_rows == m:
                if (tile_rows, tn) not in out:
                    out.append((tile_rows, tn))
    return out or [(m, min(128, n))]


def get_tiles(kind: str, m: int, d: int, n: int, bits: int,
              group_size: int, backend: str):
    """Tiles for one fused call: cache hit, else roofline-best legal pick.

    kind "fwd" → (tm, tn); kind "bwd" → (tile_rows, tn) with tile_rows
    == m outside the cache (the bit-exact default).
    """
    from repro.obs.metrics import get_metrics

    hit = _load_cache().get(_cache_key(kind, m, d, n, bits, group_size,
                                       backend))
    if hit:
        get_metrics().counter("autotune/cache_hit").inc()
        return tuple(hit)
    get_metrics().counter("autotune/cache_miss").inc()
    if kind == "bwd":
        return m, min(128, n)
    cands = fwd_candidates(m, d, n, group_size)
    best = min(cands, key=lambda c: fwd_roofline_us(m, d, n, *c, bits=bits))
    return best


def autotune(cases, *, impl: str = "auto", repeats: int = 3,
             write: bool = True) -> dict:
    """Measure the fused kernels over roofline-ranked candidates and
    persist the winners.

    ``cases``: iterable of ``(m, d, n, bits, group_size)``.  Returns the
    updated cache dict.  Measurement runs whatever ``impl`` resolves to
    on this host (interp on CPU), so a cache written on CPU carries
    interp-mode winners; the backend component of the key keeps TPU and
    CPU entries separate.
    """
    import time

    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    backend = jax.default_backend()
    cache = dict(_load_cache())
    if ops._resolve(impl) == "jnp":
        # the jnp reference composition never tiles — "measuring" it would
        # persist pure timing noise as winners.  Record the same roofline
        # defaults the trace-time read path would pick, so a CPU-refreshed
        # cache is consistent instead of misleading.
        for (m, d, n, bits, group_size) in cases:
            cache[_cache_key("fwd", m, d, n, bits, group_size, backend)] = \
                list(min(fwd_candidates(m, d, n, group_size),
                         key=lambda c: fwd_roofline_us(m, d, n, *c,
                                                       bits=bits)))
            cache[_cache_key("bwd", m, d, n, bits, group_size, backend)] = \
                [m, min(128, n)]
        if write:
            p = cache_path()
            p.parent.mkdir(parents=True, exist_ok=True)
            p.write_text(json.dumps(cache, indent=2, sort_keys=True))
            invalidate_cache()
        return cache
    for (m, d, n, bits, group_size) in cases:
        x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (d, n), jnp.float32)
        g = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)

        def _time(f):
            jax.block_until_ready(f())
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(f())
            return (time.perf_counter() - t0) / repeats * 1e6

        best_f, best_f_us = None, float("inf")
        for (tm, tn) in fwd_candidates(m, d, n, group_size):
            us = _time(lambda tm=tm, tn=tn: ops.matmul_quantize_packed(
                x, w, bits, 7, None, impl=impl, group_size=group_size,
                tm=tm, tn=tn))
            if us < best_f_us:
                best_f, best_f_us = (tm, tn), us
        cache[_cache_key("fwd", m, d, n, bits, group_size, backend)] = \
            list(best_f)

        _, packed, zero, rng = ops.matmul_quantize_packed(
            x, w, bits, 7, None, impl=impl, group_size=group_size)
        cands = bwd_candidates(m, d, n, group_size)
        best_b, best_b_us = None, float("inf")
        best_single, best_single_us = None, float("inf")
        for (tr, tn) in cands:
            us = _time(lambda tr=tr, tn=tn: ops.dequant_matmul_packed(
                packed, zero, rng, g, bits, group_size, d, None,
                impl=impl, tile_rows=tr, tn=tn))
            if tr == m and us < best_single_us:
                best_single, best_single_us = (tr, tn), us
            if us < best_b_us:
                best_b, best_b_us = (tr, tn), us
        if (best_b[0] != m and best_single is not None
                and not best_b_us < 0.9 * best_single_us):
            # the row-tiled backward is deterministic (fixed-order tree
            # reduction) but not bit-equal to the single-tile order —
            # persist a split-accumulation winner only on a clear (>10%)
            # measured win, never on timing noise
            best_b = best_single
        cache[_cache_key("bwd", m, d, n, bits, group_size, backend)] = \
            list(best_b)
    if write:
        p = cache_path()
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(cache, indent=2, sort_keys=True))
        invalidate_cache()
    return cache
