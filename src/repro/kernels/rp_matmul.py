"""Seeded Rademacher random-projection matmul (beyond-paper optimization).

``y = x @ R(seed)`` and ``y = x @ R(seed)ᵀ`` where R is *never materialized
in HBM*: each (TK, TN) tile of R is regenerated inside the kernel from the
murmur3 counter hash (bit-identical to ``repro.core.random_projection.
rp_matrix``), scaled 1/√r, and fed straight to the MXU.  Removes the D×R
fp32 parameter from memory and its HBM reads on every projection — on the
roofline this converts RP from memory-bound to compute-bound.

Grid is (M/TM, N/TN, D/TK) with K innermost; the f32 output tile accumulates
across K steps (init at k == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.prng import rademacher_from_counter


def _rp_kernel(seed_ref, x_ref, o_ref, *, tk: int, tn: int,
               r_dim: int, transpose: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.float32)                        # (TM, TK)
    r0 = (k * tk)
    c0 = (pl.program_id(1) * tn)
    rid = jax.lax.broadcasted_iota(jnp.uint32, (tk, tn), 0) + jnp.uint32(r0)
    cid = jax.lax.broadcasted_iota(jnp.uint32, (tk, tn), 1) + jnp.uint32(c0)
    if transpose:
        # tile of Rᵀ: element (p, d) = R[d, p] = sign(hash(d * r_dim + p))
        counter = cid * jnp.uint32(r_dim) + rid
    else:
        # tile of R: element (d, p) = sign(hash(d * r_dim + p))
        counter = rid * jnp.uint32(r_dim) + cid
    signs = rademacher_from_counter(seed_ref[0, 0], counter)
    r = signs.astype(jnp.float32) * jnp.float32(1.0 / (r_dim ** 0.5))
    o_ref[...] += jnp.dot(x, r, preferred_element_type=jnp.float32)


def _call(x2d, seed, n_out: int, r_dim: int, transpose: bool,
          tm: int, tn: int, tk: int, interpret: bool):
    m, d = x2d.shape
    assert m % tm == 0 and d % tk == 0 and n_out % tn == 0, (m, d, n_out)
    seed_arr = jnp.asarray(seed, jnp.uint32).reshape(1, 1)
    kern = functools.partial(_rp_kernel, tk=tk, tn=tn, r_dim=r_dim,
                             transpose=transpose)
    return pl.pallas_call(
        kern,
        grid=(m // tm, n_out // tn, d // tk),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n_out), jnp.float32),
        interpret=interpret,
    )(seed_arr, x2d)


def rp_project_call(x2d, seed, d_out: int, *, tm=128, tn=128, tk=128,
                    interpret: bool = False):
    """x (M, D) @ R(seed) (D, d_out);  R normalized by 1/√d_out."""
    return _call(x2d, seed, d_out, d_out, False, tm, tn, tk, interpret)


def irp_project_call(x2d, seed, d_in: int, *, tm=128, tn=128, tk=128,
                     interpret: bool = False):
    """x (M, r) @ R(seed)ᵀ (r, d_in);  same R as the forward projection."""
    r_dim = x2d.shape[1]
    return _call(x2d, seed, d_in, r_dim, True, tm, tn, tk, interpret)
