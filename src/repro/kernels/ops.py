"""Public jit'd entry points for the compression kernels.

``impl`` selects the path:
  * "pallas"  — real TPU lowering (the deployment path)
  * "interp"  — Pallas interpret mode (CPU correctness validation)
  * "jnp"     — the pure-jnp reference (fast on CPU; same bits)
  * "auto"    — pallas on TPU, jnp elsewhere

All paths return bit-identical packed words / codes — the SR noise is a
counter hash and the pack layout is shared (see quant_blockwise.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from repro.kernels import fused_matmul as fk
from repro.kernels import ref as refmod
from repro.kernels import quant_blockwise as qk
from repro.kernels import rp_matmul as rk


@functools.lru_cache(maxsize=1)
def _platform() -> str:
    # memoized: the platform cannot change within a process, and this
    # sits on every trace of every dispatched primitive
    return jax.default_backend()


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _platform() == "tpu" else "jnp"
    return impl


def static_levels(levels):
    """Coerce a VM level table to a static hashable tuple of floats.

    The kernels unroll the table into compare/select chains at trace time —
    it must be a compile-time constant; a traced array here would silently
    bake in garbage or fail deep inside Pallas, so reject it with a usable
    error instead.  This is the single definition (``core.backend``
    re-exports it as ``normalize_levels``) so the jnp and Pallas paths
    cannot drift.
    """
    if levels is None:
        return None
    if isinstance(levels, jax.core.Tracer):
        raise TypeError(
            "VM level tables must be static (tuple of floats), not traced "
            "arrays — pass CompressionConfig.levels() through unchanged.")
    if isinstance(levels, (tuple, list)):
        return tuple(float(l) for l in levels)
    import numpy as np

    return tuple(float(l) for l in np.asarray(levels).reshape(-1))


def _pad_rows(x, multiple: int):
    """Zero-pad whole rows up to ``multiple``.

    Rows are quantization *blocks*: padding only appends fake blocks whose
    stats live entirely in the sliced-off region ``[n:]`` — it can never
    touch a real block's (zero, range).  Within-block tail padding (which
    CAN widen the last real block's envelope if done with zeros) is the
    caller's job via replicate-padding, see ``core.backend.to_blocks``.
    """
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x, n


def quantize_packed(x2d, bits: int, seed, levels=None, *, impl: str = "auto",
                    rows_per_tile: int = 8):
    """(n_blocks, G) -> (packed u32, zero (n,), rng (n,))."""
    impl = _resolve(impl)
    levels = static_levels(levels)
    if impl == "jnp":
        return refmod.quantize_packed(x2d, bits, seed, levels)
    xp, n = _pad_rows(x2d, rows_per_tile)
    packed, zero, rng = qk.quant_pack_call(
        xp, bits, seed, levels, rows_per_tile=rows_per_tile,
        interpret=(impl == "interp"))
    return packed[:n], zero[:n, 0], rng[:n, 0]


def dequantize_packed(packed, zero, rng, bits: int, group_size: int,
                      levels=None, *, impl: str = "auto",
                      rows_per_tile: int = 8):
    """(packed, zero (n,), rng (n,)) -> (n_blocks, G) f32."""
    impl = _resolve(impl)
    levels = static_levels(levels)
    if impl == "jnp":
        return refmod.dequantize_packed(packed, zero, rng, bits, group_size, levels)
    p, n = _pad_rows(packed, rows_per_tile)
    z, _ = _pad_rows(zero[:, None], rows_per_tile)
    r, _ = _pad_rows(rng[:, None], rows_per_tile)
    out = qk.dequant_unpack_call(p, z, r, bits, group_size, levels,
                                 rows_per_tile=rows_per_tile,
                                 interpret=(impl == "interp"))
    return out[:n]


# ------------------------------------------------------- fused matmul+quant
def _fused_tiles(kind: str, m: int, d: int, n: int, bits: int,
                 group_size: int, tm, tn):
    """Resolve tile sizes: explicit args win, else the autotune cache /
    roofline default (lazy import keeps ops light for non-fused callers)."""
    from repro.kernels import autotune

    auto_tm, auto_tn = autotune.get_tiles(kind, m, d, n, bits, group_size,
                                          jax.default_backend())
    return (tm if tm is not None else auto_tm,
            tn if tn is not None else auto_tn)


def matmul_quantize_packed(x2d, w, bits: int, seed, levels=None, *,
                           impl: str = "auto", group_size: int,
                           tm: int | None = None, tn: int | None = None):
    """Fused forward: ``y = x @ w`` with ``x`` quantized+packed in the
    epilogue.  Returns ``(y (M, N), packed u32, zero (nb,), rng (nb,))``
    — the stash triplet bit-identical to ``quantize_packed`` on the same
    ``x`` reshaped to whole blocks.

    Caller guarantees eligibility (``core.backend.supports_fused``):
    ``x.size % group_size == 0`` and blocks never straddle rows unless
    rows evenly divide into blocks (``d % G == 0`` or ``G % d == 0``).
    """
    impl = _resolve(impl)
    levels = static_levels(levels)
    m, d = x2d.shape
    n = w.shape[1]
    assert (m * d) % group_size == 0, (x2d.shape, group_size)
    if impl == "jnp":
        # reference composition — bit-identical by definition (this IS the
        # unfused path in one call)
        y = x2d.astype(jnp.float32) @ w.astype(jnp.float32)
        packed, zero, rng = refmod.quantize_packed(
            x2d.astype(jnp.float32).reshape(-1, group_size), bits, seed,
            levels)
        return y, packed, zero, rng
    tm, tn = _fused_tiles("fwd", m, d, n, bits, group_size, tm, tn)
    step = group_size // math.gcd(group_size, d)
    tm = max(step, (tm // step) * step)
    xp, _ = _pad_rows(x2d, tm)
    wp, _ = _pad_cols(w, tn)
    y, packed, zero, rng = fk.matmul_quant_call(
        xp, wp, bits, seed, levels, group_size=group_size, tm=tm, tn=tn,
        interpret=(impl == "interp"))
    nb = m * d // group_size
    return y[:m, :n], packed[:nb], zero[:nb, 0], rng[:nb, 0]


def dequant_matmul_packed(packed, zero, rng, g2d, bits: int,
                          group_size: int, d: int, levels=None, *,
                          impl: str = "auto", tile_rows: int | None = None,
                          tn: int | None = None):
    """Fused backward: ``dw = dequant(packed)ᵀ @ g`` for an (M, d) stash.

    The kernel unpacks+dequantizes the stashed tile as the prologue of
    the backward matmul.  With the default single row tile the result is
    bit-identical (up to the sign of exact zeros) to the unfused
    ``dequantize_packed`` → reshape → ``x̂ᵀ @ g``.
    """
    impl = _resolve(impl)
    levels = static_levels(levels)
    m, n = g2d.shape
    assert packed.shape[0] * group_size == m * d, (packed.shape, m, d)
    if impl == "jnp":
        x_hat = refmod.dequantize_packed(packed, zero, rng, bits,
                                         group_size, levels)
        return x_hat.reshape(m, d).T @ g2d.astype(jnp.float32)
    tile_rows, tn = _fused_tiles("bwd", m, d, n, bits, group_size,
                                 tile_rows, tn)
    step = group_size // math.gcd(group_size, d)
    tile_rows = max(step, (tile_rows // step) * step)
    gp, _ = _pad_rows(g2d, tile_rows)
    gp, _ = _pad_cols(gp, tn)
    pad_blocks = (gp.shape[0] - m) * d // group_size
    if pad_blocks:
        # zero-filled fake blocks decode to exact zeros -> zero dw terms
        p = _pad_rows_to(packed, packed.shape[0] + pad_blocks)
        z = _pad_rows_to(zero[:, None], zero.shape[0] + pad_blocks)
        r = _pad_rows_to(rng[:, None], rng.shape[0] + pad_blocks)
    else:
        p, z, r = packed, zero[:, None], rng[:, None]
    dw = fk.dequant_matmul_call(p, z, r, gp, bits, group_size, d, levels,
                                tile_rows=tile_rows, tn=tn,
                                interpret=(impl == "interp"))
    return dw[:, :n]


def _pad_cols(x, multiple: int):
    n = x.shape[1]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate(
            [x, jnp.zeros((x.shape[0], pad), x.dtype)], 1)
    return x, n


def _pad_rows_to(x, target: int):
    """Zero-pad rows up to an exact row count (not a multiple)."""
    pad = target - x.shape[0]
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x


def _pad2d(x, tm, tk):
    m, d = x.shape
    pm, pd = (-m) % tm, (-d) % tk
    if pm or pd:
        x = jnp.pad(x, ((0, pm), (0, pd)))
    return x, m


def rp_project(x2d, seed, d_out: int, *, impl: str = "auto",
               tm: int = 128, tn: int = 128, tk: int = 128):
    impl = _resolve(impl)
    if impl == "jnp":
        return refmod.rp_project(x2d, seed, d_out)
    assert d_out % tn == 0 and x2d.shape[1] % tk == 0, \
        "rp_project pallas path needs D, d_out multiples of the tile"
    xp, m = _pad2d(x2d, tm, tk)
    out = rk.rp_project_call(xp, seed, d_out, tm=tm, tn=tn, tk=tk,
                             interpret=(impl == "interp"))
    return out[:m]


def irp_project(x2d, seed, d_in: int, *, impl: str = "auto",
                tm: int = 128, tn: int = 128, tk: int = 128):
    impl = _resolve(impl)
    if impl == "jnp":
        return refmod.irp_project(x2d, seed, d_in)
    assert d_in % tn == 0 and x2d.shape[1] % tk == 0, \
        "irp_project pallas path needs r, D multiples of the tile"
    xp, m = _pad2d(x2d, tm, tk)
    out = rk.irp_project_call(xp, seed, d_in, tm=tm, tn=tn, tk=tk,
                              interpret=(impl == "interp"))
    return out[:m]
