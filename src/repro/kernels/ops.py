"""Public jit'd entry points for the compression kernels.

``impl`` selects the path:
  * "pallas"  — real TPU lowering (the deployment path)
  * "interp"  — Pallas interpret mode (CPU correctness validation)
  * "jnp"     — the pure-jnp reference (fast on CPU; same bits)
  * "auto"    — pallas on TPU, jnp elsewhere

All paths return bit-identical packed words / codes — the SR noise is a
counter hash and the pack layout is shared (see quant_blockwise.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref as refmod
from repro.kernels import quant_blockwise as qk
from repro.kernels import rp_matmul as rk


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    return impl


def static_levels(levels):
    """Coerce a VM level table to a static hashable tuple of floats.

    The kernels unroll the table into compare/select chains at trace time —
    it must be a compile-time constant; a traced array here would silently
    bake in garbage or fail deep inside Pallas, so reject it with a usable
    error instead.  This is the single definition (``core.backend``
    re-exports it as ``normalize_levels``) so the jnp and Pallas paths
    cannot drift.
    """
    if levels is None:
        return None
    if isinstance(levels, jax.core.Tracer):
        raise TypeError(
            "VM level tables must be static (tuple of floats), not traced "
            "arrays — pass CompressionConfig.levels() through unchanged.")
    if isinstance(levels, (tuple, list)):
        return tuple(float(l) for l in levels)
    import numpy as np

    return tuple(float(l) for l in np.asarray(levels).reshape(-1))


def _pad_rows(x, multiple: int):
    """Zero-pad whole rows up to ``multiple``.

    Rows are quantization *blocks*: padding only appends fake blocks whose
    stats live entirely in the sliced-off region ``[n:]`` — it can never
    touch a real block's (zero, range).  Within-block tail padding (which
    CAN widen the last real block's envelope if done with zeros) is the
    caller's job via replicate-padding, see ``core.backend.to_blocks``.
    """
    n = x.shape[0]
    pad = (-n) % multiple
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, *x.shape[1:]), x.dtype)], 0)
    return x, n


def quantize_packed(x2d, bits: int, seed, levels=None, *, impl: str = "auto",
                    rows_per_tile: int = 8):
    """(n_blocks, G) -> (packed u32, zero (n,), rng (n,))."""
    impl = _resolve(impl)
    levels = static_levels(levels)
    if impl == "jnp":
        return refmod.quantize_packed(x2d, bits, seed, levels)
    xp, n = _pad_rows(x2d, rows_per_tile)
    packed, zero, rng = qk.quant_pack_call(
        xp, bits, seed, levels, rows_per_tile=rows_per_tile,
        interpret=(impl == "interp"))
    return packed[:n], zero[:n, 0], rng[:n, 0]


def dequantize_packed(packed, zero, rng, bits: int, group_size: int,
                      levels=None, *, impl: str = "auto",
                      rows_per_tile: int = 8):
    """(packed, zero (n,), rng (n,)) -> (n_blocks, G) f32."""
    impl = _resolve(impl)
    levels = static_levels(levels)
    if impl == "jnp":
        return refmod.dequantize_packed(packed, zero, rng, bits, group_size, levels)
    p, n = _pad_rows(packed, rows_per_tile)
    z, _ = _pad_rows(zero[:, None], rows_per_tile)
    r, _ = _pad_rows(rng[:, None], rows_per_tile)
    out = qk.dequant_unpack_call(p, z, r, bits, group_size, levels,
                                 rows_per_tile=rows_per_tile,
                                 interpret=(impl == "interp"))
    return out[:n]


def _pad2d(x, tm, tk):
    m, d = x.shape
    pm, pd = (-m) % tm, (-d) % tk
    if pm or pd:
        x = jnp.pad(x, ((0, pm), (0, pd)))
    return x, m


def rp_project(x2d, seed, d_out: int, *, impl: str = "auto",
               tm: int = 128, tn: int = 128, tk: int = 128):
    impl = _resolve(impl)
    if impl == "jnp":
        return refmod.rp_project(x2d, seed, d_out)
    assert d_out % tn == 0 and x2d.shape[1] % tk == 0, \
        "rp_project pallas path needs D, d_out multiples of the tile"
    xp, m = _pad2d(x2d, tm, tk)
    out = rk.rp_project_call(xp, seed, d_out, tm=tm, tn=tn, tk=tk,
                             interpret=(impl == "interp"))
    return out[:m]


def irp_project(x2d, seed, d_in: int, *, impl: str = "auto",
                tm: int = 128, tn: int = 128, tk: int = 128):
    impl = _resolve(impl)
    if impl == "jnp":
        return refmod.irp_project(x2d, seed, d_in)
    assert d_in % tn == 0 and x2d.shape[1] % tk == 0, \
        "irp_project pallas path needs r, D multiples of the tile"
    xp, m = _pad2d(x2d, tm, tk)
    out = rk.irp_project_call(xp, seed, d_in, tm=tm, tn=tn, tk=tk,
                              interpret=(impl == "interp"))
    return out[:m]
