"""Pure-jnp oracles for every Pallas kernel in this package.

Semantics are defined once in ``repro.core``; these wrappers present them
with the exact same signatures as ``repro.kernels.ops`` so tests can diff
kernel-vs-ref bit-exactly (codes and packed words included — both paths draw
SR noise from the same counter hash and pack with the same strided layout).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import pack as packmod
from repro.core import quant as quantmod
from repro.core import random_projection as rpmod


def quantize_packed(x2d, bits: int, seed, levels=None):
    """(n_blocks, G) f32 -> (packed u32 (n_blocks, G*bits/32), zero, rng)."""
    lv = None if levels is None else jnp.asarray(levels, jnp.float32)
    codes, zero, rng = quantmod.quantize_grouped(x2d, bits, seed, lv)
    return packmod.pack(codes, bits), zero, rng


def dequantize_packed(packed, zero, rng, bits: int, group_size: int, levels=None):
    """Inverse of :func:`quantize_packed` -> (n_blocks, G) f32."""
    lv = None if levels is None else jnp.asarray(levels, jnp.float32)
    codes = packmod.unpack(packed, bits, group_size)
    return quantmod.dequantize_grouped(codes, zero, rng, bits, lv)


def flash_attention(q, k, v, causal: bool = True):
    """Plain softmax attention oracle for the flash kernel.

    q (BH, Sq, Dh), k/v (BH, Skv, Dh)."""
    import jax
    import numpy as np

    dh = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(dh)
    if causal:
        m = jnp.tril(jnp.ones((q.shape[1], k.shape[1]), bool))
        s = jnp.where(m[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def rp_project(x2d, seed, d_out: int):
    """x (M, D) @ R(seed) (D, d_out) — R materialized here, never in ops."""
    return rpmod.rp(x2d, seed, d_out)


def irp_project(x2d, seed, d_in: int):
    """x (M, R) @ R(seed).T (R, d_in)."""
    return rpmod.irp(x2d, seed, d_in)
