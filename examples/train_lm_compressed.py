"""Beyond-paper: activation-compressed training of a transformer LM.

Trains a reduced qwen3-32b-family config twice — plain remat vs ACT
(INT2 block-quantized residual stash) — and compares losses + stash bytes.

  PYTHONPATH=src python examples/train_lm_compressed.py [--steps 40]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_for_smoke
from repro.core import CompressionConfig
from repro.core.pack import packed_nbytes
from repro.data import batch_for_step
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=40)
ap.add_argument("--arch", default="qwen3-32b")
args = ap.parse_args()

B, S = 4, 128
for mode in ("remat", "act"):
    cfg = dataclasses.replace(
        reduce_for_smoke(ARCHS[args.arch]), act_mode=mode,
        act_compression=CompressionConfig(bits=2, group_size=64))
    model = Model(cfg)
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, state_bits=8)  # 8-bit Adam too
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
    params = model.init(jax.random.PRNGKey(0))
    state = adamw_init(params, opt)
    losses = []
    for s in range(args.steps):
        toks = jnp.asarray(batch_for_step(cfg.vocab, B, S, s))
        params, state, m = step(params, state, {"tokens": toks})
        losses.append(float(m["loss"]))
    full = B * S * cfg.d_model * 2
    stash = full if mode == "remat" else packed_nbytes(
        (B, S, cfg.d_model), 2, 64)
    print(f"{mode:6s} loss {losses[0]:.4f} -> {losses[-1]:.4f} | "
          f"residual stash/layer: {stash} B "
          f"({100 * (1 - stash / full):.1f}% less than bf16)")
print("\nboth modes train; ACT stores the per-layer residual stream at "
      "INT2 instead of recomputing from bf16 (remat) — compose them for "
      "the full memory ladder.")
