"""Serving example: prefill a batch of prompts, then greedy-decode with the
KV/state cache — runs any of the 10 assigned architectures (reduced config).

  PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_for_smoke
from repro.launch.steps import make_serve_step
from repro.models import Model

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="zamba2-1.2b", choices=sorted(ARCHS))
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen-len", type=int, default=32)
args = ap.parse_args()

cfg = dataclasses.replace(reduce_for_smoke(ARCHS[args.arch]), act_mode="none")
model = Model(cfg)
params = model.init(jax.random.PRNGKey(0))
prompts = jax.random.randint(jax.random.PRNGKey(1),
                             (args.batch, args.prompt_len), 0, cfg.vocab)

max_seq = args.prompt_len + args.gen_len
kwargs = {}
if cfg.family == "encdec":
    kwargs["enc_embeds"] = jax.random.normal(
        jax.random.PRNGKey(2), (args.batch, args.prompt_len, cfg.d_model),
        jnp.bfloat16)
t0 = time.perf_counter()
logits, cache = model.prefill(params, prompts, max_seq=max_seq, **kwargs)
print(f"prefill {args.batch}x{args.prompt_len}: "
      f"{time.perf_counter() - t0:.2f}s (cache pos={int(cache['pos'][0])})")

serve = jax.jit(make_serve_step(model))
tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
generated = [tok]
t0 = time.perf_counter()
for _ in range(args.gen_len - 1):
    tok, _, cache = serve(params, cache, tok)
    generated.append(tok)
dt = time.perf_counter() - t0
out = jnp.concatenate(generated, axis=1)
print(f"decoded {args.gen_len - 1} steps in {dt:.2f}s "
      f"({(args.gen_len - 1) * args.batch / dt:.1f} tok/s)")
print("sample token ids:", out[0, :16].tolist())
