"""End-to-end driver: train GraphSAGE on the arxiv-like graph for a few
hundred steps under FP32 / EXACT-INT2 / i-EXACT block-wise INT2(+VM) and
reproduce the paper's Table-1 trends (accuracy parity, memory reduction).

  PYTHONPATH=src python examples/train_gnn_iexact.py [--epochs 150] [--scale 0.02]

``--batches N`` additionally runs the partition-sampled mini-batch engine
(Cluster-GCN flavor) on the block+VM config and reports the per-batch peak
saved-activation bytes against the full-graph run — the regime where the
paper's memory wins open graphs that full-graph training can't touch.
"""
import argparse

from repro.core import CompressionConfig
from repro.graph import (GNNConfig, arxiv_like, train_gnn, train_gnn_batched,
                         activation_memory_report)

ap = argparse.ArgumentParser()
ap.add_argument("--epochs", type=int, default=150)
ap.add_argument("--scale", type=float, default=0.02)
ap.add_argument("--batches", type=int, default=0,
                help="also run the mini-batch engine with this many "
                     "subgraph partitions")
args = ap.parse_args()

g = arxiv_like(scale=args.scale)
print(f"arxiv-like stand-in: {g.n_nodes} nodes, {len(g.edge_src)} edges, "
      f"{g.n_feats} feats, {g.num_classes} classes\n")

rows = []
for name, comp in [
    ("FP32 baseline", None),
    ("EXACT INT2 (per-row, D/R=8)", CompressionConfig(2, 32, 8)),
    ("i-EXACT block G/R=8", CompressionConfig(2, 256, 8)),
    ("i-EXACT block G/R=64", CompressionConfig(2, 2048, 8)),
    ("i-EXACT block + VM", CompressionConfig(2, 256, 8, vm=True)),
]:
    cfg = GNNConfig(arch="sage", hidden=(256, 256),
                    n_classes=g.num_classes, compression=comp)
    r = train_gnn(g, cfg, n_epochs=args.epochs, seed=0)
    mem = activation_memory_report(g, cfg)
    mb = mem.get("compressed_bytes", mem["fp32_bytes"]) / 1e6
    rows.append((name, r["test_acc"], r["epochs_per_sec"], mb))
    print(f"{name:32s} acc={r['test_acc']:.4f} "
          f"S={r['epochs_per_sec']:5.2f} e/s  M={mb:8.2f} MB")

fp32_acc, fp32_m = rows[0][1], rows[0][3]
best = rows[3]
print(f"\nblock-wise G/R=64 vs FP32: Δacc={best[1] - fp32_acc:+.4f}, "
      f"memory -{100 * (1 - best[3] / fp32_m):.1f}%")

if args.batches:
    comp = CompressionConfig(2, 256, 8, vm=True)
    cfg = GNNConfig(arch="sage", hidden=(256, 256),
                    n_classes=g.num_classes, compression=comp)
    r = train_gnn_batched(g, cfg, n_parts=args.batches,
                          n_epochs=args.epochs, seed=0)
    rep = activation_memory_report(g, cfg, n_parts=args.batches,
                                   batch_nodes=r["batch_nodes"])
    print(f"\nmini-batch engine ({args.batches} partitions of "
          f"{r['batch_nodes']} padded nodes):")
    if "batched" in rep:
        b = rep["batched"]
        peak = (f"peak M={b['peak_saved_bytes'] / 1e6:8.2f} MB "
                f"({b['peak_reduction_vs_full']:.1f}x below full-graph)")
    else:  # --batches 1: the peak IS the full graph
        peak = f"peak M={rep['compressed_bytes'] / 1e6:8.2f} MB (full graph)"
    print(f"  block+VM batched acc={r['test_acc']:.4f} "
          f"S={r['epochs_per_sec']:5.2f} e/s  {peak}")
