"""End-to-end driver: train GraphSAGE on the arxiv-like graph for a few
hundred steps under FP32 / EXACT-INT2 / i-EXACT block-wise INT2(+VM) and
reproduce the paper's Table-1 trends (accuracy parity, memory reduction).

  PYTHONPATH=src python examples/train_gnn_iexact.py [--epochs 150] [--scale 0.02]
"""
import argparse

from repro.core import CompressionConfig
from repro.graph import (GNNConfig, arxiv_like, train_gnn,
                         activation_memory_report)

ap = argparse.ArgumentParser()
ap.add_argument("--epochs", type=int, default=150)
ap.add_argument("--scale", type=float, default=0.02)
args = ap.parse_args()

g = arxiv_like(scale=args.scale)
print(f"arxiv-like stand-in: {g.n_nodes} nodes, {len(g.edge_src)} edges, "
      f"{g.n_feats} feats, {g.num_classes} classes\n")

rows = []
for name, comp in [
    ("FP32 baseline", None),
    ("EXACT INT2 (per-row, D/R=8)", CompressionConfig(2, 32, 8)),
    ("i-EXACT block G/R=8", CompressionConfig(2, 256, 8)),
    ("i-EXACT block G/R=64", CompressionConfig(2, 2048, 8)),
    ("i-EXACT block + VM", CompressionConfig(2, 256, 8, vm=True)),
]:
    cfg = GNNConfig(arch="sage", hidden=(256, 256),
                    n_classes=g.num_classes, compression=comp)
    r = train_gnn(g, cfg, n_epochs=args.epochs, seed=0)
    mem = activation_memory_report(g, cfg)
    mb = mem.get("compressed_bytes", mem["fp32_bytes"]) / 1e6
    rows.append((name, r["test_acc"], r["epochs_per_sec"], mb))
    print(f"{name:32s} acc={r['test_acc']:.4f} "
          f"S={r['epochs_per_sec']:5.2f} e/s  M={mb:8.2f} MB")

fp32_acc, fp32_m = rows[0][1], rows[0][3]
best = rows[3]
print(f"\nblock-wise G/R=64 vs FP32: Δacc={best[1] - fp32_acc:+.4f}, "
      f"memory -{100 * (1 - best[3] / fp32_m):.1f}%")
