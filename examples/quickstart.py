"""Quickstart: compress/decompress an activation map with block-wise INT2
stochastic-rounding quantization + random projection (the paper's core),
and see the unbiasedness + memory properties.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (CompressionConfig, compress, decompress,
                        expected_sr_variance, expected_sr_variance_uniform,
                        optimize_levels)

x = jax.random.normal(jax.random.PRNGKey(0), (1024, 256)) * 2.0 + 0.5
print(f"activation map: {x.shape}, {x.nbytes / 1e6:.2f} MB fp32")

for desc, cfg in [
    ("per-row INT2 (EXACT)", CompressionConfig(bits=2, group_size=32, rp_ratio=8)),
    ("block-wise INT2 G=256 (i-EXACT)", CompressionConfig(bits=2, group_size=256, rp_ratio=8)),
    ("block-wise + variance-minimized levels", CompressionConfig(bits=2, group_size=256, rp_ratio=8, vm=True)),
]:
    ct = compress(x, cfg, seed=0)
    xh = decompress(ct)
    single = float(jnp.abs(xh - x).mean())
    # SR (+RP) is unbiased: the mean over seeds converges to x as 1/sqrt(n)
    mean = sum(decompress(compress(x, cfg, s)) for s in range(20)) / 20.0
    bias = float(jnp.abs(mean - x).mean())
    print(f"{desc:42s} stored {ct.nbytes / 1e6:6.3f} MB "
          f"({100 * (1 - ct.nbytes / x.nbytes):.1f}% smaller); "
          f"|err| 1 seed = {single:.3f}, mean of 20 = {bias:.3f} "
          f"(-> 0 as 1/sqrt n: unbiased)")

lv = optimize_levels(256, bits=2)
print(f"\nVM levels for D=256: α*={lv[1]:.4f}, β*={lv[2]:.4f} "
      f"(uniform would be 1, 2)")
print(f"expected SR variance: uniform={expected_sr_variance_uniform(256):.5f} "
      f"optimized={expected_sr_variance(lv, 256):.5f}")
