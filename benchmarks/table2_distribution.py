"""Paper Table 2: Jensen-Shannon divergence of uniform vs clipped-normal
models against observed normalized projected activations, per layer, plus
empirical VM variance reduction (Eq. 19)."""
from __future__ import annotations

from repro.graph import GNNConfig, arxiv_like, flickr_like, train_gnn
from repro.graph.analysis import collect_projected_activations, table2_row
from repro.graph.models import graph_tuple


def run(scale: float = 0.02, epochs: int = 40):
    rows = []
    for gname, maker in (("arxiv", arxiv_like), ("flickr", flickr_like)):
        g = maker(scale=scale)
        cfg = GNNConfig(arch="sage", hidden=(256, 256),
                        n_classes=g.num_classes)
        r = train_gnn(g, cfg, n_epochs=epochs, seed=0)
        caps = collect_projected_activations(r["params"], graph_tuple(g),
                                             cfg, rp_ratio=8)
        for li, c in enumerate(caps):
            row = table2_row(c)
            row.update(dataset=gname, layer=li + 1)
            rows.append(row)
    return rows


def main():
    out = []
    for r in run():
        out.append((f"table2/{r['dataset']}/layer{r['layer']}", 0.0,
                    f"R={r['R']};js_U={r['js_uniform']:.4f};"
                    f"js_CN={r['js_clipnorm']:.4f};"
                    f"var_red={r['var_reduction_pct']:.2f}%"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
