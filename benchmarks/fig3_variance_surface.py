"""Paper Fig. 3: SR variance for INT2 as a function of the interior
quantization boundaries [α, β]; uniform ([1,2]) vs optimized."""
from __future__ import annotations

import numpy as np

from repro.core.variance import (expected_sr_variance,
                                 expected_sr_variance_uniform,
                                 optimize_levels)


def run(D: int = 64, grid: int = 9):
    alphas = np.linspace(0.5, 1.45, grid)
    betas = np.linspace(1.55, 2.5, grid)
    surface = []
    for a in alphas:
        for b in betas:
            v = expected_sr_variance((0.0, float(a), float(b), 3.0), D, 2)
            surface.append((float(a), float(b), v))
    vu = expected_sr_variance_uniform(D, 2)
    lv = optimize_levels(D, 2)
    vo = expected_sr_variance(lv, D, 2)
    best = min(surface, key=lambda t: t[2])
    return {"surface": surface, "uniform": vu, "opt_levels": lv,
            "opt_var": vo, "grid_best": best}


def main():
    r = run()
    a, b, v = r["grid_best"]
    return [
        ("fig3/uniform_var", 0.0, f"var={r['uniform']:.6f};alpha=1;beta=2"),
        ("fig3/optimized_var", 0.0,
         f"var={r['opt_var']:.6f};alpha={r['opt_levels'][1]:.4f};"
         f"beta={r['opt_levels'][2]:.4f}"),
        ("fig3/grid_best", 0.0, f"var={v:.6f};alpha={a:.3f};beta={b:.3f}"),
    ]


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
