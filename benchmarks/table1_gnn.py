"""Paper Table 1: accuracy / speed / memory across quantization configs
(FP32, EXACT-style per-row INT2, block-wise INT2 at G/R ∈ {2..64}, +VM)
on the arxiv-like and flickr-like synthetic stand-ins.

On this CPU container "S" (epochs/s) measures interpreter-level overhead,
not the paper's GPU-bandwidth effect; the byte-accounting M column is the
hardware-independent claim and is what we validate (paper: >15% reduction
vs EXACT at G/R=64, >95% vs FP32).
"""
from __future__ import annotations

from repro.core import CompressionConfig
from repro.graph import (GNNConfig, arxiv_like, flickr_like, train_gnn,
                         activation_memory_report)
from repro.obs.trace import stopwatch


def run(scale: float = 0.02, epochs: int = 60, seeds=(0,)):
    rows = []
    for gname, maker in (("arxiv", arxiv_like), ("flickr", flickr_like)):
        g = maker(scale=scale)
        # RP target dim for layer-0 (sage concat doubles feats)
        base_r = (2 * g.n_feats) // 8
        configs = [("FP32", None, "-")]
        configs.append(
            ("INT2 (EXACT, per-row)", CompressionConfig(2, base_r, 8), "-"))
        for gr in (2, 4, 8, 16, 32, 64):
            configs.append((f"INT2 block", CompressionConfig(
                2, min(base_r * gr, 4096), 8), str(gr)))
        configs.append(("INT2+VM", CompressionConfig(2, base_r, 8, vm=True),
                        "-"))
        for name, comp, gr in configs:
            cfg = GNNConfig(arch="sage", hidden=(256, 256),
                            n_classes=g.num_classes, compression=comp)
            accs, eps = [], []
            for seed in seeds:
                with stopwatch("bench/table1", dataset=gname, quant=name,
                               seed=seed):
                    r = train_gnn(g, cfg, n_epochs=epochs, seed=seed)
                accs.append(r["test_acc"])
                eps.append(r["epochs_per_sec"])
            mem = activation_memory_report(g, cfg)
            rows.append({
                "dataset": gname, "quant": name, "G/R": gr,
                "accuracy": sum(accs) / len(accs),
                "epochs_per_sec": sum(eps) / len(eps),
                "mem_MB": (mem.get("compressed_bytes", mem["fp32_bytes"])
                           / 1e6),
                "fp32_MB": mem["fp32_bytes"] / 1e6,
            })
    return rows


def main(fast: bool = True):
    rows = run(scale=0.02 if fast else 0.1, epochs=40 if fast else 150)
    out = []
    for r in rows:
        us = 1e6 / max(r["epochs_per_sec"], 1e-9)
        out.append((f"table1/{r['dataset']}/{r['quant'].replace(' ', '_')}"
                    f"/GR={r['G/R']}", us,
                    f"acc={r['accuracy']:.4f};mem_MB={r['mem_MB']:.2f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
