"""Kernel micro-bench: quant/dequant/RP wall time (jnp path on CPU; the
Pallas path runs in interpret mode and is correctness-only here) plus the
bytes-moved model that determines TPU-side speedup."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops


def _time(f, *args, n=5):
    f(*args)[0].block_until_ready() if isinstance(f(*args), tuple) else None
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def main():
    out = []
    for (nb, g) in ((4096, 256), (16384, 256), (4096, 1024)):
        x = jax.random.normal(jax.random.PRNGKey(0), (nb, g), jnp.float32)
        qf = jax.jit(lambda x: ops.quantize_packed(x, 2, 7, impl="jnp"))
        us = _time(qf, x)
        in_bytes = x.size * 4
        out_bytes = x.size // 16 * 4 + nb * 8
        out.append((f"kernel/quant2_pack/{nb}x{g}", us,
                    f"in_MB={in_bytes / 1e6:.1f};out_MB={out_bytes / 1e6:.2f};"
                    f"compress={in_bytes / out_bytes:.1f}x"))
        packed, zero, rng = qf(x)
        df = jax.jit(lambda p, z, r: ops.dequantize_packed(
            p, z, r, 2, g, impl="jnp"))
        us = _time(df, packed, zero, rng)
        out.append((f"kernel/dequant2_unpack/{nb}x{g}", us, ""))
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024), jnp.float32)
    rp = jax.jit(lambda x: ops.rp_project(x, 3, 128, impl="jnp"))
    us = _time(rp, x)
    # seeded RP saves materializing + reading R: D x r fp32 per call
    saved = 1024 * 128 * 4
    out.append((f"kernel/rp_project/8192x1024->128", us,
                f"R_bytes_never_materialized={saved / 1e6:.2f}MB"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
