"""Kernel micro-bench: quant/dequant/RP wall time plus the bytes-moved model
that determines TPU-side speedup.

Two tiers:

* raw kernel calls (legacy rows, kept for trend continuity);
* the *dispatched* public compressor API (``compress``/``decompress``)
  swept over ``impl in {"jnp", "interp"}`` — this is the path training
  actually runs, so the perf trajectory tracks the dispatch layer, not
  hand-wired kernel calls.  Results land in ``BENCH_compressor.json``.

On CPU the Pallas path runs in interpret mode and is correctness-priced
only; the jnp rows are the meaningful CPU numbers.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, compress, decompress
from repro.kernels import ops

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_compressor.json"


def _time(f, *args, n=5):
    jax.block_until_ready(f(*args))  # compile + warm
    t0 = time.perf_counter()
    for _ in range(n):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def _raw_kernel_rows():
    out = []
    for (nb, g) in ((4096, 256), (16384, 256), (4096, 1024)):
        x = jax.random.normal(jax.random.PRNGKey(0), (nb, g), jnp.float32)
        qf = jax.jit(lambda x: ops.quantize_packed(x, 2, 7, impl="jnp"))
        us = _time(qf, x)
        in_bytes = x.size * 4
        out_bytes = x.size // 16 * 4 + nb * 8
        out.append((f"kernel/quant2_pack/{nb}x{g}", us,
                    f"in_MB={in_bytes / 1e6:.1f};out_MB={out_bytes / 1e6:.2f};"
                    f"compress={in_bytes / out_bytes:.1f}x"))
        packed, zero, rng = qf(x)
        df = jax.jit(lambda p, z, r: ops.dequantize_packed(
            p, z, r, 2, g, impl="jnp"))
        us = _time(df, packed, zero, rng)
        out.append((f"kernel/dequant2_unpack/{nb}x{g}", us, ""))
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024), jnp.float32)
    rp = jax.jit(lambda x: ops.rp_project(x, 3, 128, impl="jnp"))
    us = _time(rp, x)
    # seeded RP saves materializing + reading R: D x r fp32 per call
    saved = 1024 * 128 * 4
    out.append((f"kernel/rp_project/8192x1024->128", us,
                f"R_bytes_never_materialized={saved / 1e6:.2f}MB"))
    return out


def _dispatched_compressor_rows(impls=("jnp", "interp")):
    """Sweep the public compressor API across backends."""
    rows, records = [], []
    cases = [
        ("int2_g256", CompressionConfig(bits=2, group_size=256), (4096, 256)),
        ("int2_g256_vm", CompressionConfig(bits=2, group_size=256, vm=True),
         (4096, 256)),
        ("int2_g256_rp8", CompressionConfig(bits=2, group_size=256,
                                            rp_ratio=8), (2048, 1024)),
    ]
    for tag, cfg, shape in cases:
        x = jax.random.normal(jax.random.PRNGKey(7), shape, jnp.float32)
        for impl in impls:
            cf = jax.jit(lambda x, c=cfg, i=impl: compress(x, c, 7, impl=i))
            us_c = _time(cf, x, n=3)
            ct = cf(x)
            df = jax.jit(decompress)
            us_d = _time(df, ct, n=3)
            derived = (f"impl={impl};stored_MB={ct.nbytes / 1e6:.3f};"
                       f"ratio={ct.uncompressed_nbytes / ct.nbytes:.1f}x")
            rows.append((f"compressor/{tag}/compress[{impl}]", us_c, derived))
            rows.append((f"compressor/{tag}/decompress[{impl}]", us_d, ""))
            records.append({
                "case": tag, "impl": impl, "shape": list(shape),
                "bits": cfg.bits, "group_size": cfg.group_size,
                "rp_ratio": cfg.rp_ratio, "vm": cfg.vm,
                "compress_us": us_c, "decompress_us": us_d,
                "stored_bytes": ct.nbytes,
                "uncompressed_bytes": ct.uncompressed_nbytes,
            })
    return rows, records


def main(json_path: pathlib.Path | str | None = JSON_PATH):
    rows = _raw_kernel_rows()
    dispatched, records = _dispatched_compressor_rows()
    rows += dispatched
    if json_path:
        payload = {"backend": jax.default_backend(), "records": records}
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=2))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {JSON_PATH}")
