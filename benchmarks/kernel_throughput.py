"""Kernel micro-bench: quant/dequant/RP wall time plus the bytes-moved model
that determines TPU-side speedup.

Two tiers:

* raw kernel calls (legacy rows, kept for trend continuity);
* the *dispatched* public compressor API (``compress``/``decompress``)
  swept over ``impl in {"jnp", "interp"}`` — this is the path training
  actually runs, so the perf trajectory tracks the dispatch layer, not
  hand-wired kernel calls.  Results land in ``BENCH_compressor.json``.

On CPU the Pallas path runs in interpret mode and is correctness-priced
only; the jnp rows are the meaningful CPU numbers.

The impl sweep covers {jnp, interp, auto} everywhere and adds real
"pallas" rows on TPU hosts only (off-TPU they are skipped with a note
row instead of crashing — ``auto`` already records what this host's
training would dispatch to).  The ``fused/*`` rows time the
quantize-in-epilogue matmul pair against the two-pass spelling it
replaces (XLA/kernel matmul + dispatched quant), recording the
machine-portable ``speedup`` ratio the CI regression gate tracks.
"""
from __future__ import annotations

import json
import pathlib

import jax
import jax.numpy as jnp

from repro.core import CompressionConfig, compress, decompress
from repro.core import backend
from repro.kernels import ops
from repro.obs.trace import stopwatch

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_compressor.json"

#: impls every host sweeps; "pallas" joins on TPU (guarded in the sweeps)
SWEEP_IMPLS = ("jnp", "interp", "auto")


def _sweep_impls():
    if jax.default_backend() == "tpu":
        return SWEEP_IMPLS + ("pallas",)
    return SWEEP_IMPLS


def _time(f, *args, n=5):
    jax.block_until_ready(f(*args))  # compile + warm
    with stopwatch("bench/kernel", repeats=n) as sw:
        for _ in range(n):
            out = f(*args)
            jax.block_until_ready(out)
    return sw.elapsed_s / n * 1e6


def _raw_kernel_rows():
    out = []
    for (nb, g) in ((4096, 256), (16384, 256), (4096, 1024)):
        x = jax.random.normal(jax.random.PRNGKey(0), (nb, g), jnp.float32)
        qf = jax.jit(lambda x: ops.quantize_packed(x, 2, 7, impl="jnp"))
        us = _time(qf, x)
        in_bytes = x.size * 4
        out_bytes = x.size // 16 * 4 + nb * 8
        out.append((f"kernel/quant2_pack/{nb}x{g}", us,
                    f"in_MB={in_bytes / 1e6:.1f};out_MB={out_bytes / 1e6:.2f};"
                    f"compress={in_bytes / out_bytes:.1f}x"))
        packed, zero, rng = qf(x)
        df = jax.jit(lambda p, z, r: ops.dequantize_packed(
            p, z, r, 2, g, impl="jnp"))
        us = _time(df, packed, zero, rng)
        out.append((f"kernel/dequant2_unpack/{nb}x{g}", us, ""))
    x = jax.random.normal(jax.random.PRNGKey(1), (8192, 1024), jnp.float32)
    rp = jax.jit(lambda x: ops.rp_project(x, 3, 128, impl="jnp"))
    us = _time(rp, x)
    # seeded RP saves materializing + reading R: D x r fp32 per call
    saved = 1024 * 128 * 4
    out.append((f"kernel/rp_project/8192x1024->128", us,
                f"R_bytes_never_materialized={saved / 1e6:.2f}MB"))
    return out


def _dispatched_compressor_rows(impls=None):
    """Sweep the public compressor API across backends."""
    impls = _sweep_impls() if impls is None else impls
    rows, records = [], []
    cases = [
        ("int2_g256", CompressionConfig(bits=2, group_size=256), (4096, 256)),
        ("int2_g256_vm", CompressionConfig(bits=2, group_size=256, vm=True),
         (4096, 256)),
        ("int2_g256_rp8", CompressionConfig(bits=2, group_size=256,
                                            rp_ratio=8), (2048, 1024)),
    ]
    for tag, cfg, shape in cases:
        x = jax.random.normal(jax.random.PRNGKey(7), shape, jnp.float32)
        for impl in impls:
            cf = jax.jit(lambda x, c=cfg, i=impl: compress(x, c, 7, impl=i))
            us_c = _time(cf, x, n=3)
            ct = cf(x)
            df = jax.jit(decompress)
            us_d = _time(df, ct, n=3)
            derived = (f"impl={impl};stored_MB={ct.nbytes / 1e6:.3f};"
                       f"ratio={ct.uncompressed_nbytes / ct.nbytes:.1f}x")
            rows.append((f"compressor/{tag}/compress[{impl}]", us_c, derived))
            rows.append((f"compressor/{tag}/decompress[{impl}]", us_d, ""))
            records.append({
                "case": tag, "impl": impl, "shape": list(shape),
                "bits": cfg.bits, "group_size": cfg.group_size,
                "rp_ratio": cfg.rp_ratio, "vm": cfg.vm,
                "compress_us": us_c, "decompress_us": us_d,
                "stored_bytes": ct.nbytes,
                "uncompressed_bytes": ct.uncompressed_nbytes,
            })
    return rows, records


def fused_cases():
    """(tag, m, d, n, bits, group_size, levels) shapes the fused rows
    sweep — also the shapes ``refresh_experiments.py --bench`` feeds the
    tile autotuner, so the recorded rows use the tiles training gets."""
    return [
        ("b2_g256", 4096, 256, 256, 2, 256, None),
        ("b4_g128", 2048, 256, 256, 4, 128, None),
        ("b2_g64_vm", 1024, 256, 256, 2, 64,
         CompressionConfig(bits=2, group_size=64, vm=True).levels()),
    ]


def _fused_matmul_rows(impls=None):
    """Fused quantize-in-epilogue matmul pair vs the two-pass spelling.

    For each (shape, bits, G, impl): the forward row times
    ``matmul_quantize_packed`` against separate ``x @ w`` + dispatched
    ``quantize_packed`` (the exact pair it replaces in the engine), and
    the backward row times ``dequant_matmul_packed`` against dispatched
    ``dequantize_packed`` + ``x̂ᵀ @ g``.  The recorded ``speedup``
    (unfused/fused) is machine-portable — the CI regression gate tracks
    it rather than raw wall time.
    """
    impls = _sweep_impls() if impls is None else impls
    rows, records = [], []
    for tag, m, d, n, bits, g, levels in fused_cases():
        x = jax.random.normal(jax.random.PRNGKey(0), (m, d), jnp.float32)
        w = jax.random.normal(jax.random.PRNGKey(1), (d, n), jnp.float32)
        gy = jax.random.normal(jax.random.PRNGKey(2), (m, n), jnp.float32)
        assert backend.supports_fused((m, d), bits, g, levels), (tag,)
        for impl in impls:
            ff = jax.jit(lambda x, w, i=impl: ops.matmul_quantize_packed(
                x, w, bits, 7, levels, impl=i, group_size=g))
            us_f = _time(ff, x, w, n=3)
            uf = jax.jit(lambda x, w, i=impl: (
                x @ w,
                ops.quantize_packed(x.reshape(-1, g), bits, 7, levels,
                                    impl=i)))
            us_u = _time(uf, x, w, n=3)
            y, packed, zero, rng = ff(x, w)
            fb = jax.jit(lambda p, z, r, gy, i=impl: ops.dequant_matmul_packed(
                p, z, r, gy, bits, g, d, levels, impl=i))
            us_fb = _time(fb, packed, zero, rng, gy, n=3)
            ub = jax.jit(lambda p, z, r, gy, i=impl: ops.dequantize_packed(
                p, z, r, bits, g, levels, impl=i).reshape(m, d).T @ gy)
            us_ub = _time(ub, packed, zero, rng, gy, n=3)
            rows.append((f"fused/{tag}/fwd[{impl}]", us_f,
                         f"unfused_us={us_u:.1f};speedup={us_u / us_f:.2f}x"))
            rows.append((f"fused/{tag}/bwd[{impl}]", us_fb,
                         f"unfused_us={us_ub:.1f};"
                         f"speedup={us_ub / us_fb:.2f}x"))
            records.append({
                "case": f"fused_{tag}", "impl": impl,
                "shape": [m, d, n], "bits": bits, "group_size": g,
                "vm": levels is not None,
                "fused_fwd_us": us_f, "unfused_fwd_us": us_u,
                "fwd_speedup": us_u / us_f,
                "fused_bwd_us": us_fb, "unfused_bwd_us": us_ub,
                "bwd_speedup": us_ub / us_fb,
            })
    if jax.default_backend() != "tpu":
        rows.append(("fused/pallas", 0.0,
                     "skipped=real-pallas rows need a TPU host"))
    return rows, records


def main(json_path: pathlib.Path | str | None = JSON_PATH):
    rows = _raw_kernel_rows()
    dispatched, records = _dispatched_compressor_rows()
    rows += dispatched
    fused_rows, fused_records = _fused_matmul_rows()
    rows += fused_rows
    records += fused_records
    if json_path:
        payload = {"backend": jax.default_backend(), "records": records}
        pathlib.Path(json_path).write_text(json.dumps(payload, indent=2))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    print(f"# wrote {JSON_PATH}")
