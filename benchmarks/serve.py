"""Serving-engine benchmark → ``BENCH_serve.json``.

A deterministic heterogeneous load (seeded gen-length draws, staggered
arrivals) drives the paged-KV serving engine four ways:

* **fixed** — the legacy sequential fixed-batch loop, recovered as a
  scheduler configuration (``mode="fixed"``), raw bf16 KV pages;
* **continuous** — slot-refill continuous batching on the same load and
  the same raw pages.  The ``speedup_gate`` pins continuous >= 1.3x
  tokens/sec: every 4th request is a full-budget long generation amid
  short ones, so each fixed batch strands three slots behind its long
  member (head-of-line blocking) while the continuous scheduler streams
  the shorts through the freed slots;
* **kv sweep** — continuous at bits in {16, 8, 4, 2}: tokens/sec,
  p50/p99 request latency, and the KV arena footprint vs the same pool
  held as uncompressed f32 (``bytes_gate``: bits=4 >= 3x smaller);
* **parity** — one request decoded twice (bits=8 vs 16) with logits
  collected; step 0 comes from full-precision prefill (must be exact)
  and step 1 is the first read of the quantized prompt KV (must agree
  within tolerance).

Every arm runs twice on the same engine and reports the second, warm
run — jit compile time is excluded, page tables and schedules replay
deterministically.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import jax
import numpy as np

from repro.configs import ARCHS, reduce_for_smoke
from repro.models import Model
from repro.obs.trace import stopwatch
from repro.serving import KVCacheConfig, Request, ServeEngine

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serve.json"

N_REQ, PROMPT, GEN_CAP, PAGE_T, MAX_BATCH = 16, 16, 48, 8, 4
SWEEP_BITS = (16, 8, 4, 2)
SPEEDUP_MIN, BYTES_RATIO_MIN, PARITY_TOL = 1.3, 3.0, 0.5


def _load(vocab: int) -> list[Request]:
    """The deterministic benchmark load: first ``MAX_BATCH`` requests
    arrive at step 0, the rest trickle in every 2 decode steps.  Every
    4th request generates the full ``GEN_CAP`` budget; the rest draw
    short 4..12 budgets — the head-of-line-blocking mix where fixed
    batching idles three slots behind each long request."""
    rng = np.random.default_rng(0xC0FFEE)
    prompts = rng.integers(0, vocab, (N_REQ, PROMPT), dtype=np.int64)
    shorts = rng.integers(4, 13, N_REQ)
    return [Request(rid=i, prompt=prompts[i].astype(np.int32),
                    max_new=GEN_CAP if i % 4 == 0 else int(shorts[i]),
                    arrival=0 if i < MAX_BATCH else (i - MAX_BATCH + 1) * 2)
            for i in range(N_REQ)]


def _engine(model, params, bits: int, mode: str, **kw) -> ServeEngine:
    pages_per_req = -(-(PROMPT + GEN_CAP - 1) // PAGE_T)
    kv = KVCacheConfig(bits=bits, group_size=64, page_tokens=PAGE_T,
                       n_pages=MAX_BATCH * pages_per_req)
    return ServeEngine(model, params, kv=kv, max_batch=MAX_BATCH,
                       max_prompt=PROMPT, gen_cap=GEN_CAP, mode=mode, **kw)


def _arm(engine: ServeEngine, requests) -> dict:
    engine.run(requests)                      # warm: compile + caches
    out = engine.run(requests)
    assert out["rejected"] == 0, "benchmark load must fit the pool"
    return {
        "tokens_per_sec": out["tokens_per_sec"],
        "us_per_token": 1e6 * out["wall_s"] / max(out["gen_tokens"], 1),
        "wall_s": out["wall_s"],
        "gen_tokens": out["gen_tokens"],
        "decode_steps": out["decode_steps"],
        "p50_latency_ms": out["p50_latency_ms"],
        "p99_latency_ms": out["p99_latency_ms"],
        "ttft_mean_ms": out["ttft_mean_ms"],
        "tpot_mean_ms": out["tpot_mean_ms"],
        "kv_pool_bytes": out["kv_pool_bytes"],
        "kv_f32_pool_bytes": out["kv_f32_pool_bytes"],
        "f32_ratio": out["kv_f32_pool_bytes"] / out["kv_pool_bytes"],
    }


def _parity(model, params, requests) -> dict:
    outs = {}
    for bits in (16, 8):
        eng = _engine(model, params, bits, "continuous",
                      collect_logits=True)
        outs[bits] = eng.run(requests[:1])["logits"][requests[0].rid]
    d0 = float(np.max(np.abs(outs[8][0] - outs[16][0])))
    d1 = float(np.max(np.abs(outs[8][1] - outs[16][1])))
    return {"bits": [8, 16], "prefill_logit_diff": d0,
            "step1_logit_diff": d1, "tol": PARITY_TOL,
            "ok": bool(d0 == 0.0 and d1 < PARITY_TOL)}


def run() -> dict:
    # smoke config, scaled to where a decode step's compute dominates
    # per-call dispatch overhead (the regime the speedup gate measures)
    cfg = dataclasses.replace(reduce_for_smoke(ARCHS["qwen1.5-4b"]),
                              act_mode="none", n_layers=4, d_model=256,
                              d_head=64, d_ff=512)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    requests = _load(cfg.vocab)

    arms = {}
    for mode in ("fixed", "continuous"):
        with stopwatch(f"bench/serve_{mode}"):
            arms[mode] = _arm(_engine(model, params, 16, mode), requests)
    speedup = (arms["continuous"]["tokens_per_sec"]
               / arms["fixed"]["tokens_per_sec"])

    sweep = []
    for bits in SWEEP_BITS:
        with stopwatch("bench/serve_sweep", bits=bits):
            row = _arm(_engine(model, params, bits, "continuous"), requests)
        sweep.append({"bits": bits, **row})

    parity = _parity(model, params, requests)
    bits4 = next(r for r in sweep if r["bits"] == 4)
    out = {
        "config": {"arch": "qwen1.5-4b-smoke", "n_requests": N_REQ,
                   "prompt_len": PROMPT, "gen_cap": GEN_CAP,
                   "page_tokens": PAGE_T, "max_batch": MAX_BATCH,
                   "total_gen_tokens": sum(r.max_new for r in requests)},
        "fixed": arms["fixed"],
        "continuous": arms["continuous"],
        "speedup_tokens_per_sec": speedup,
        "kv_sweep": sweep,
        "parity": parity,
        "speedup_gate": {"min": SPEEDUP_MIN,
                         "ok": bool(speedup >= SPEEDUP_MIN)},
        "bytes_gate": {"bits4_f32_ratio": bits4["f32_ratio"],
                       "min": BYTES_RATIO_MIN,
                       "ok": bool(bits4["f32_ratio"] >= BYTES_RATIO_MIN)},
    }
    OUT.write_text(json.dumps(out, indent=2))
    return out


def main():
    out = run()
    rows = []
    for mode in ("fixed", "continuous"):
        m = out[mode]
        rows.append((
            f"serve/{mode}", m["us_per_token"],
            f"tok_s={m['tokens_per_sec']:.1f};"
            f"p99_ms={m['p99_latency_ms']:.0f};"
            f"kv_B={m['kv_pool_bytes']}"))
    rows.append(("serve/speedup", 0.0,
                 f"continuous_vs_fixed={out['speedup_tokens_per_sec']:.2f};"
                 f"gate_ok={out['speedup_gate']['ok']}"))
    for r in out["kv_sweep"]:
        rows.append((
            f"serve/kv{r['bits']}", r["us_per_token"],
            f"tok_s={r['tokens_per_sec']:.1f};kv_B={r['kv_pool_bytes']};"
            f"f32_ratio={r['f32_ratio']:.1f};"
            f"p99_ms={r['p99_latency_ms']:.0f}"))
    p = out["parity"]
    rows.append(("serve/parity", 0.0,
                 f"step1_diff={p['step1_logit_diff']:.3f};ok={p['ok']}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {OUT}")
