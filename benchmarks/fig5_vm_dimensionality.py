"""Paper Fig. 5 / App. C: variance reduction when the VM levels are
optimized assuming dimensionality D#, evaluated on CN_[1/D] samples —
the observed optimum should track the true D."""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core import quant as quantmod
from repro.core.variance import clipped_normal_params, optimize_levels


def _sample_cn(D: int, n: int, rng) -> np.ndarray:
    mu, sigma = clipped_normal_params(D, 2)
    return np.clip(rng.normal(mu, sigma, n), 0.0, 3.0)


def empirical_var_reduction(h: np.ndarray, levels, n_rep: int = 4) -> float:
    hj = jnp.asarray(h, jnp.float32)[None, :]
    lu = quantmod.uniform_levels(2)
    lo = jnp.asarray(levels, jnp.float32)
    eu = eo = 0.0
    for s in range(n_rep):
        cu = quantmod.stochastic_round_to_levels(hj, lu, s)
        co = quantmod.stochastic_round_to_levels(hj, lo, s + 77)
        eu += float(jnp.sum((hj - jnp.take(lu, cu)) ** 2))
        eo += float(jnp.sum((hj - jnp.take(lo, co)) ** 2))
    return 1.0 - eo / max(eu, 1e-30)


def run(true_ds=(16, 32, 64, 96, 128), assumed_ds=(8, 16, 32, 64, 96, 128, 256),
        n: int = 20000):
    rng = np.random.default_rng(0)
    rows = []
    for td in true_ds:
        h = _sample_cn(td, n, rng)
        reds = {ad: empirical_var_reduction(h, optimize_levels(ad, 2))
                for ad in assumed_ds}
        best = max(reds, key=reds.get)
        rows.append({"true_D": td, "best_assumed_D": best,
                     "red_at_true": reds.get(td, float("nan")),
                     "reductions": reds})
    return rows


def main():
    out = []
    for r in run():
        out.append((f"fig5/trueD={r['true_D']}", 0.0,
                    f"best_assumed_D={r['best_assumed_D']};"
                    f"red_at_true={100 * r['red_at_true']:.2f}%"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
