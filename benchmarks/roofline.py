"""Roofline report generator: reads results/dryrun/*.json and emits the
three-term table (compute / memory / collective, seconds per step per
device) with the dominant bottleneck per (arch × shape × mesh).

Hardware constants (TPU v5e-class, per chip):
  197 TFLOP/s bf16 · 819 GB/s HBM · ~50 GB/s/link ICI
"""
from __future__ import annotations

import json
from pathlib import Path

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def load_cells(include_act_variants: bool = False):
    cells = []
    for f in sorted(RESULTS.glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("act_mode") and not include_act_variants:
            continue  # act-mode variants are §Perf experiments, not baseline
        cells.append(r)
    return cells


def roofline_row(rec):
    """Three terms in seconds/step/device + bottleneck + model/hlo ratio."""
    if rec["status"] != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec["status"],
                "reason": rec.get("reason", "")}
    h = rec["hlo"]
    # CPU lowering promotes most bf16 math to f32: halve byte terms to model
    # the TPU bf16 layout (documented caveat; flops are dtype-agnostic).
    f32_factor = 0.5
    t_compute = h["dot_flops_per_device"] / PEAK_FLOPS
    t_memory = h["hbm_bytes_per_device"] * f32_factor / HBM_BW
    t_coll = h["collective_total_bytes"] * f32_factor / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    n_chips = 512 if rec["mesh"] == "multi" else 256
    hlo_global = h["dot_flops_per_device"] * n_chips
    ratio = rec["model_flops_global"] / max(hlo_global, 1)
    # roofline fraction: useful model flops vs what the bottleneck term
    # would allow in the same wall time
    t_bound = max(terms.values())
    t_model_ideal = rec["model_flops_global"] / n_chips / PEAK_FLOPS
    frac = t_model_ideal / t_bound if t_bound > 0 else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "bottleneck": bottleneck,
        "model_over_hlo_flops": ratio,
        "roofline_fraction": frac,
        "mem_temp_GB": (rec["memory"]["temp_bytes"] or 0) / 2 / 1e9,
        "compile_s": rec.get("compile_s"),
    }


def main():
    out = []
    for rec in load_cells():
        row = roofline_row(rec)
        if row.get("status") != "ok":
            out.append((f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
                        0.0, f"status={row['status']}"))
            continue
        out.append((
            f"roofline/{row['arch']}/{row['shape']}/{row['mesh']}",
            row["t_compute_s"] * 1e6,
            f"bottleneck={row['bottleneck']};"
            f"tc={row['t_compute_s']:.3e};tm={row['t_memory_s']:.3e};"
            f"tx={row['t_collective_s']:.3e};"
            f"frac={row['roofline_fraction']:.3f};"
            f"model/hlo={row['model_over_hlo_flops']:.3f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
