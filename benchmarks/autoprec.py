"""Adaptive bit-allocation benchmark: fixed INT2 vs variance-guided
mixed precision at equal (or lower) compressed bytes.

Two allocated arms against the fixed-INT2 baseline on the arxiv-like graph:

* ``autoprec`` — budget = 2.0 average stash bits (the fixed-INT2
  footprint).  The allocator splits the same byte ceiling with the
  improved variance model: equal-or-lower bytes, strictly lower total
  expected SR variance (Eq. 10 summed over layers), accuracy within noise.
* ``autoprec_low`` — budget = 1.5 average bits, below any uniform width
  except INT1: the solver returns a genuinely mixed per-layer allocation
  and is compared against the INT1 uniform fallback at the same budget.

Results land in ``BENCH_autoprec.json`` next to the repo root (same
convention as ``BENCH_compressor.json`` / ``BENCH_gnn_batched.json``).
Expected SR variance is computed with the paper's range-moment model on a
shared sensitivity basis (the fixed run's final params) so the column is
deterministic and comparable across arms.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.core import CompressionConfig, autoprec
from repro.engine import ExecutionPlan, PrecisionPolicy, run as engine_run
from repro.graph import (GNNConfig, activation_memory_report, arxiv_like,
                         collect_layer_stats, train_gnn)
from repro.graph.models import graph_tuple

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_autoprec.json"


def _arm(stats, cfg: GNNConfig, r, g, budget_avg_bits=None) -> dict:
    per = cfg.layer_compression()
    rep = activation_memory_report(g, cfg)
    arm = {
        "test_acc": r["test_acc"],
        "epochs_per_sec": r["epochs_per_sec"],
        "bits_per_layer": [c.bits if c is not None else None for c in per],
        "vm": [bool(c.vm) if c is not None else None for c in per],
        "stash_bytes": autoprec.total_stash_bytes(stats, per),
        "expected_sr_variance": autoprec.total_expected_variance(stats, per),
        "saved_bytes_with_masks": rep["compressed_bytes"],
    }
    if budget_avg_bits is not None:
        arm["budget_avg_bits"] = budget_avg_bits
        arm["bit_budget_bytes"] = r["bit_budget_bytes"]
    return arm


def run(scale: float = 0.01, epochs: int = 30, hidden=(64, 64),
        group_size: int = 256, seed: int = 0):
    g = arxiv_like(scale=scale)
    fixed_comp = CompressionConfig(bits=2, group_size=group_size, rp_ratio=8)
    cfg_fixed = GNNConfig(arch="sage", hidden=hidden,
                          n_classes=g.num_classes, compression=fixed_comp)
    # allocated arms start from the VM template — the allocator's whole
    # point is spending the improved variance model, tables included
    cfg_vm = GNNConfig(arch="sage", hidden=hidden, n_classes=g.num_classes,
                       compression=dataclasses.replace(fixed_comp, vm=True))

    # allocated arms are explicit precision-policy plans; the fixed arm is
    # the default plan (train_gnn's spelling of the same engine call)
    refresh = max(epochs // 2, 1)
    r_fixed = train_gnn(g, cfg_fixed, n_epochs=epochs, seed=seed)
    r_eq = engine_run(g, cfg_vm, ExecutionPlan(precision=PrecisionPolicy(
        kind="autoprec", bit_budget=2.0, refresh=refresh)),
        n_epochs=epochs, seed=seed)
    r_low = engine_run(g, cfg_vm, ExecutionPlan(precision=PrecisionPolicy(
        kind="autoprec", bit_budget=1.5, refresh=refresh)),
        n_epochs=epochs, seed=seed)

    # shared sensitivity basis: range moments at the fixed run's final params
    stats = collect_layer_stats(r_fixed["params"], graph_tuple(g), cfg_fixed)

    data = {"graph": {"name": g.name, "n_nodes": g.n_nodes,
                      "n_edges": g.n_edges, "hidden": list(hidden),
                      "group_size": group_size, "epochs": epochs},
            "fixed_int2": _arm(stats, cfg_fixed, r_fixed, g),
            "autoprec": _arm(stats, r_eq["cfg"], r_eq, g,
                             budget_avg_bits=2.0),
            "autoprec_low": _arm(stats, r_low["cfg"], r_low, g,
                                 budget_avg_bits=1.5)}

    # the INT1 uniform fallback is the only fixed width inside the low budget
    cfg_int1 = cfg_fixed.with_layer_bits([1] * cfg_fixed.n_layers)
    per1 = cfg_int1.layer_compression()
    data["autoprec_low"]["uniform_int1_fallback"] = {
        "stash_bytes": autoprec.total_stash_bytes(stats, per1),
        "expected_sr_variance": autoprec.total_expected_variance(stats, per1),
    }

    fx, eq = data["fixed_int2"], data["autoprec"]
    data["acceptance"] = {
        "equal_or_lower_bytes": eq["stash_bytes"] <= fx["stash_bytes"],
        "lower_expected_sr_variance":
            eq["expected_sr_variance"] < fx["expected_sr_variance"],
        "acc_delta_vs_fixed": eq["test_acc"] - fx["test_acc"],
    }
    JSON_PATH.write_text(json.dumps(data, indent=2))
    return data


def main(fast: bool = True):
    data = run(scale=0.01 if fast else 0.02, epochs=20 if fast else 60)
    out = []
    for arm in ("fixed_int2", "autoprec", "autoprec_low"):
        d = data[arm]
        us = 1e6 / max(d["epochs_per_sec"], 1e-9)
        out.append((
            f"autoprec/{arm}", us,
            f"acc={d['test_acc']:.4f};bytes={d['stash_bytes']};"
            f"evar={d['expected_sr_variance']:.3e};"
            f"bits={'-'.join(str(b) for b in d['bits_per_layer'])}"))
    ok = data["acceptance"]
    out.append((
        "autoprec/acceptance", 0.0,
        f"bytes_ok={ok['equal_or_lower_bytes']};"
        f"var_ok={ok['lower_expected_sr_variance']};"
        f"acc_delta={ok['acc_delta_vs_fixed']:+.4f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
