"""Beyond-paper: the paper's technique on transformer training — per-step
saved-activation bytes for none/remat/ACT modes on a reduced LM, plus loss
parity over a short run (unbiased-gradient check at model level)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduce_for_smoke
from repro.core import CompressionConfig
from repro.core.pack import packed_nbytes
from repro.data import batch_for_step
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.obs.trace import stopwatch
from repro.optim import AdamWConfig, adamw_init


def act_bytes_per_layer(cfg, batch, seq):
    """Residual-stream stash per layer: uncompressed vs block-INT2.

    The uncompressed baseline is sized from the config's actual
    activation dtype, not a hard-coded 2 bytes/elt, so an fp32 run
    doesn't under-report what compression is saving.  The residual
    stream is ``ArchConfig.act_dtype`` (the embed dtype) promoted
    against the bf16 dense weights — that promotion is what actually
    flows through the layer scan (e.g. float16 embeds still yield an
    f32 stream).
    """
    act = jnp.dtype(getattr(cfg, "act_dtype", "bfloat16"))
    itemsize = jnp.promote_types(act, jnp.bfloat16).itemsize
    full = batch * seq * cfg.d_model * itemsize
    comp = cfg.act_compression or CompressionConfig(2, 256)
    packed = packed_nbytes((batch, seq, cfg.d_model), comp.bits,
                           comp.group_size)
    return full, packed


def run(arch="qwen3-32b", steps=15, batch=4, seq=128):
    results = {}
    for mode in ("remat", "act"):
        cfg = dataclasses.replace(
            reduce_for_smoke(ARCHS[arch]), act_mode=mode,
            act_compression=CompressionConfig(bits=2, group_size=64))
        model = Model(cfg)
        opt = AdamWConfig(lr=3e-3)
        step = jax.jit(make_train_step(model, opt), donate_argnums=(0, 1))
        params = model.init(jax.random.PRNGKey(0))
        state = adamw_init(params, opt)
        losses = []
        with stopwatch("bench/lm_act", mode=mode, steps=steps) as sw:
            for s in range(steps):
                toks = jnp.asarray(batch_for_step(cfg.vocab, batch, seq, s))
                params, state, m = step(params, state, {"tokens": toks})
                losses.append(float(m["loss"]))
        dt = sw.elapsed_s / steps
        full, packed = act_bytes_per_layer(cfg, batch, seq)
        results[mode] = {"losses": losses, "s_per_step": dt,
                         "stash_bytes": full if mode == "remat" else packed,
                         "full_bytes": full}
    return results


def main():
    r = run()
    out = []
    for mode, d in r.items():
        out.append((f"lm_act/{mode}", d["s_per_step"] * 1e6,
                    f"loss0={d['losses'][0]:.3f};lossN={d['losses'][-1]:.3f};"
                    f"stash_B_per_layer={d['stash_bytes']};"
                    f"reduction={1 - d['stash_bytes'] / d['full_bytes']:.3f}"))
    dloss = abs(r["remat"]["losses"][-1] - r["act"]["losses"][-1])
    out.append(("lm_act/parity", 0.0, f"final_loss_gap={dloss:.4f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
