"""Batched-GNN smoke benchmark: the mini-batch subgraph engine vs the
full-graph loop — epochs/sec and peak saved-activation bytes at equal
compression config, swept over ``impl in {jnp, interp}``.

Both arms are explicit :class:`~repro.engine.plan.ExecutionPlan` objects
lowered by :func:`repro.engine.runner.run`, and the memory report reads
the *same* plan the engine executed — one source of truth for the peak
byte model.  Results land in ``BENCH_gnn_batched.json`` next to the repo
root (same convention as ``BENCH_compressor.json``).  On CPU the
throughput column measures interpreter overhead, not the paper's
bandwidth effect; the hardware-independent claim this bench tracks is
the *peak* byte model — one padded batch live at a time instead of the
whole graph.

The bench also measures the observability layer's epoch-time overhead
(``data["obs"]``: obs-on spans+metrics vs obs-off, interleaved repeats,
ratio of best epoch times) — the number ``scripts/bench_regression.py``
gates below 1.05.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

from repro.core import CompressionConfig
from repro.engine import (ExecutionPlan, KernelPolicy, ObsPolicy,
                          SamplingPolicy, run)
from repro.graph import (GNNConfig, activation_memory_report, arxiv_like,
                         make_subgraph_batches)

JSON_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_gnn_batched.json"


def measure_obs_overhead(g, cfg, plan, batches, *, epochs: int = 6,
                         repeats: int = 3) -> dict:
    """Epoch-time cost of spans+metrics (the always-on obs surface; the
    quant probe is opt-in and cadenced, so it is not part of the
    overhead contract).  Runs obs-on and obs-off interleaved and
    compares the *best* epoch rate of each arm — min-of-repeats is the
    standard defense against one-off scheduler noise on a shared CI
    box."""
    plan_on = dataclasses.replace(plan, obs=ObsPolicy(enabled=True))
    best = {"off": 0.0, "on": 0.0}
    for _ in range(repeats):
        for name, p in (("off", plan), ("on", plan_on)):
            r = run(g, cfg, p, n_epochs=epochs, seed=0, batches=batches)
            best[name] = max(best[name], r["epochs_per_sec"])
    on_s, off_s = 1.0 / best["on"], 1.0 / best["off"]
    return {"overhead_ratio": on_s / off_s,
            "on_epoch_s": on_s, "off_epoch_s": off_s,
            "epochs": epochs, "repeats": repeats}


def run_bench(scale: float = 0.02, epochs: int = 20, n_parts: int = 4,
              hidden=(64, 64), impls=("jnp", "interp"), interp_epochs: int = 4):
    g = arxiv_like(scale=scale)
    comp = CompressionConfig(bits=2, group_size=256, rp_ratio=8)
    batches = make_subgraph_batches(g, n_parts, method="bfs", seed=0)
    data = {"graph": {"name": g.name, "n_nodes": g.n_nodes,
                      "n_edges": g.n_edges, "n_parts": n_parts}}
    for impl in impls:
        cfg = GNNConfig(arch="sage", hidden=hidden,
                        n_classes=g.num_classes, compression=comp)
        ep = interp_epochs if impl == "interp" else epochs
        full_plan = ExecutionPlan(kernel=KernelPolicy(impl=impl))
        batch_plan = ExecutionPlan(
            sampling=SamplingPolicy(kind="partition", n_parts=n_parts),
            kernel=KernelPolicy(impl=impl))
        full = run(g, cfg, full_plan, n_epochs=ep, seed=0)
        bat = run(g, cfg, batch_plan, n_epochs=ep, seed=0, batches=batches)
        rep = activation_memory_report(g, cfg, plan=batch_plan,
                                       batch_nodes=bat["batch_nodes"])
        data[impl] = {
            "epochs": ep,
            "full_epochs_per_sec": full["epochs_per_sec"],
            "batched_epochs_per_sec": bat["epochs_per_sec"],
            "full_test_acc": full["test_acc"],
            "batched_test_acc": bat["test_acc"],
            "full_saved_bytes": rep["compressed_bytes"],
            "peak_saved_bytes": rep["batched"]["peak_saved_bytes"],
            "peak_reduction_vs_full":
                rep["batched"]["peak_reduction_vs_full"],
        }
    # obs overhead on the jnp batched plan (the fast arm): spans+metrics
    # must stay within 5% of obs-off epoch time
    cfg = GNNConfig(arch="sage", hidden=hidden,
                    n_classes=g.num_classes, compression=comp)
    batch_plan = ExecutionPlan(
        sampling=SamplingPolicy(kind="partition", n_parts=n_parts),
        kernel=KernelPolicy(impl="jnp"))
    data["obs"] = measure_obs_overhead(g, cfg, batch_plan, batches,
                                       epochs=max(4, epochs // 2))
    JSON_PATH.write_text(json.dumps(data, indent=2))
    return data


def main(fast: bool = True):
    data = run_bench(scale=0.01 if fast else 0.02, epochs=10 if fast else 40,
                     interp_epochs=3 if fast else 8)
    out = []
    for impl, d in data.items():
        if impl in ("graph", "obs"):
            continue
        for mode in ("full", "batched"):
            us = 1e6 / max(d[f"{mode}_epochs_per_sec"], 1e-9)
            out.append((
                f"gnn_batched/{impl}/{mode}", us,
                f"acc={d[f'{mode}_test_acc']:.4f};"
                f"peak_MB={d['peak_saved_bytes'] / 1e6:.2f};"
                f"peak_red={d['peak_reduction_vs_full']:.2f}"))
    ob = data["obs"]
    out.append(("gnn_batched/obs_overhead", ob["on_epoch_s"] * 1e6,
                f"ratio={ob['overhead_ratio']:.3f};"
                f"off_s={ob['off_epoch_s']:.4f}"))
    return out


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
