"""Distributed-GNN benchmark: the mesh-sharded partition-parallel engine
vs the single-device full-graph engine at equal compression config.

Run standalone (``PYTHONPATH=src python benchmarks/gnn_dist.py``) this
module forces an 8-device host platform *before* jax initializes, so the
4-partition arm actually shards over 4 devices with a live halo exchange
and feature pager; imported into ``benchmarks/run.py``'s in-process
suite it uses whatever devices exist (a 1-device mesh degenerates to the
round-sequential engine — every metric below still exists).

``BENCH_gnn_dist.json`` rows:

* per-epoch wall time, both arms;
* halo traffic bytes/epoch (the ``all_to_all`` volume the ledger model
  predicts — 0 on a 1-device mesh);
* feature-pager prefetch overlap fraction (copy time hidden behind
  round compute);
* per-device peak saved-activation bytes: the deterministic stash-plan
  ledger (`mesh_stash_plan` vs the full-graph plan — the ISSUE 7 >=2x
  acceptance gate is CI-checked on this number in
  ``tests/test_parallel.py``), plus best-effort *measured* live bytes.

The regression gate (``scripts/bench_regression.py``) reads only the
device-count-independent metrics (epoch times, ledger bytes).
"""
from __future__ import annotations

import os
import sys

if __name__ == "__main__" and "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import json
import pathlib

JSON_PATH = (pathlib.Path(__file__).resolve().parent.parent
             / "BENCH_gnn_dist.json")


def run_bench(scale: float = 5e-5, epochs: int = 8, n_parts: int = 4,
              hidden=(128,)):
    import jax

    from repro.core import CompressionConfig
    from repro.engine import ExecutionPlan, SamplingPolicy, run
    from repro.engine.forward import mesh_stash_plan, plan_gnn_stashes
    from repro.graph import GNNConfig, papers100m_like
    from repro.offload import measure_live_bytes
    from repro.parallel.halo import build_halo_program

    g = papers100m_like(scale)
    comp = CompressionConfig(bits=2, group_size=32)
    cfg = GNNConfig(arch="gcn", hidden=hidden, n_classes=g.num_classes,
                    compression=comp)

    full_plan = ExecutionPlan()
    full = run(g, cfg, full_plan, n_epochs=epochs, seed=0)
    full_live = measure_live_bytes()

    mesh_plan = ExecutionPlan(sampling=SamplingPolicy(
        kind="mesh", n_parts=n_parts, shuffle=False))
    mesh = run(g, cfg, mesh_plan, n_epochs=epochs, seed=0)
    mesh_live = measure_live_bytes()

    prog = build_halo_program(g, n_parts, mesh["mesh_devices"])
    full_ledger = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes).total_bytes
    dev_ledger = mesh_stash_plan(cfg, g.n_feats, prog.n_pad).total_bytes

    data = {
        "graph": {"name": g.name, "n_nodes": g.n_nodes,
                  "n_edges": g.n_edges, "n_feats": g.n_feats,
                  "n_parts": n_parts, "epochs": epochs},
        "devices": jax.device_count(),
        "mesh_devices": mesh["mesh_devices"],
        "rounds_per_epoch": mesh["updates_per_epoch"],
        "full_epoch_s": 1.0 / max(full["epochs_per_sec"], 1e-9),
        "mesh_epoch_s": 1.0 / max(mesh["epochs_per_sec"], 1e-9),
        "full_test_acc": full["test_acc"],
        "mesh_test_acc": mesh["test_acc"],
        "halo_width": mesh["halo_width"],
        "halo_bytes_per_epoch": mesh["halo_bytes_per_epoch"],
        "dropped_edges": mesh["dropped_edges"],
        "prefetch_overlap_frac": mesh["pager"]["overlap_frac"],
        "pager_host_bytes": mesh["pager"]["host_bytes"],
        "full_saved_bytes_ledger": full_ledger,
        "per_device_saved_bytes_ledger": dev_ledger,
        "per_device_peak_ratio": full_ledger / dev_ledger,
        # best-effort measured numbers (allocator-visible, CPU included)
        "full_measured_live_bytes": full_live,
        "mesh_measured_live_bytes": mesh_live,
    }
    JSON_PATH.write_text(json.dumps(data, indent=2))
    return data


def main(fast: bool = True):
    d = run_bench(scale=2e-5 if fast else 5e-5, epochs=4 if fast else 8)
    rows = []
    for arm in ("full", "mesh"):
        rows.append((
            f"gnn_dist/{arm}", d[f"{arm}_epoch_s"] * 1e6,
            f"acc={d[f'{arm}_test_acc']:.4f};"
            f"dev_peak_ratio={d['per_device_peak_ratio']:.2f};"
            f"halo_MB={d['halo_bytes_per_epoch'] / 1e6:.2f};"
            f"overlap={d['prefetch_overlap_frac']:.2f}"))
    return rows


if __name__ == "__main__":
    fast = "--fast" in sys.argv
    for name, us, derived in main(fast=fast):
        print(f"{name},{us:.1f},{derived}")
