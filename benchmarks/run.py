"""Benchmark driver: one harness per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV; the kernel suite additionally
sweeps the dispatched compressor API over ``impl in {jnp, interp}`` and
drops ``BENCH_compressor.json`` next to the repo root, and the gnn_batched
suite drops ``BENCH_gnn_batched.json`` (mini-batch vs full-graph engine).

Set ``REPRO_TRACE_OUT=<base>`` to trace the whole sweep: one obs span per
suite (plus every ``stopwatch``-timed region inside the harnesses),
exported to ``<base>.jsonl`` and ``<base>.trace.json`` (Perfetto)."""
from __future__ import annotations

import os
import sys
import traceback


def main() -> None:
    from benchmarks import (autoprec, fig3_variance_surface,
                            fig5_vm_dimensionality, gnn_batched, gnn_dist,
                            kernel_throughput, lm_act_compression, offload,
                            roofline, serve, table1_gnn, table2_distribution)

    suites = [
        ("fig3", fig3_variance_surface.main),
        ("fig5", fig5_vm_dimensionality.main),
        ("kernel", kernel_throughput.main),  # also writes BENCH_compressor.json
        ("table2", table2_distribution.main),
        ("lm_act", lm_act_compression.main),
        ("table1", table1_gnn.main),
        ("gnn_batched", gnn_batched.main),  # writes BENCH_gnn_batched.json
        ("gnn_dist", gnn_dist.main),  # writes BENCH_gnn_dist.json
        ("autoprec", autoprec.main),  # writes BENCH_autoprec.json
        ("offload", offload.main),  # writes BENCH_offload.json
        ("serve", serve.main),  # writes BENCH_serve.json
        ("roofline", roofline.main),
    ]
    trace_out = os.environ.get("REPRO_TRACE_OUT")
    tracer = prev = None
    if trace_out:
        from repro.obs.trace import Tracer, set_tracer

        tracer = Tracer()
        prev = set_tracer(tracer)

    print("name,us_per_call,derived")
    failures = 0
    try:
        for tag, fn in suites:
            try:
                if tracer is not None:
                    with tracer.span(f"suite/{tag}"):
                        rows = fn()
                else:
                    rows = fn()
                for name, us, derived in rows:
                    print(f"{name},{us:.1f},{derived}", flush=True)
            except Exception:
                failures += 1
                print(f"{tag}/ERROR,0,{traceback.format_exc(limit=2)!r}",
                      flush=True)
    finally:
        if tracer is not None:
            from repro.obs.trace import set_tracer

            set_tracer(prev)
            base = trace_out[:-6] if trace_out.endswith(".jsonl") else \
                trace_out[:-5] if trace_out.endswith(".json") else trace_out
            tracer.export_jsonl(base + ".jsonl")
            tracer.export_chrome(base + ".trace.json")
            print(f"# obs trace: {base}.jsonl + {base}.trace.json",
                  file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
