"""Benchmark driver: one harness per paper table/figure + system benches.
Prints ``name,us_per_call,derived`` CSV; the kernel suite additionally
sweeps the dispatched compressor API over ``impl in {jnp, interp}`` and
drops ``BENCH_compressor.json`` next to the repo root, and the gnn_batched
suite drops ``BENCH_gnn_batched.json`` (mini-batch vs full-graph engine)."""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (autoprec, fig3_variance_surface,
                            fig5_vm_dimensionality, gnn_batched, gnn_dist,
                            kernel_throughput, lm_act_compression, offload,
                            roofline, table1_gnn, table2_distribution)

    suites = [
        ("fig3", fig3_variance_surface.main),
        ("fig5", fig5_vm_dimensionality.main),
        ("kernel", kernel_throughput.main),  # also writes BENCH_compressor.json
        ("table2", table2_distribution.main),
        ("lm_act", lm_act_compression.main),
        ("table1", table1_gnn.main),
        ("gnn_batched", gnn_batched.main),  # writes BENCH_gnn_batched.json
        ("gnn_dist", gnn_dist.main),  # writes BENCH_gnn_dist.json
        ("autoprec", autoprec.main),  # writes BENCH_autoprec.json
        ("offload", offload.main),  # writes BENCH_offload.json
        ("roofline", roofline.main),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for tag, fn in suites:
        try:
            for name, us, derived in fn():
                print(f"{name},{us:.1f},{derived}", flush=True)
        except Exception:
            failures += 1
            print(f"{tag}/ERROR,0,{traceback.format_exc(limit=2)!r}",
                  flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
