"""Stash arena + host offload benchmark → ``BENCH_offload.json``.

Three INT2 configurations of the Cora-smoke GNN at identical compression
settings (so accuracy is equal by construction — the stash *bits* are
identical, only their storage differs):

* ``none``       — per-tensor ``CompressedTensor`` residuals (the
                   pre-arena baseline);
* ``arena``      — pooled arena, ``offload="device"``;
* ``arena_host`` — pooled arena, ``offload="host"`` (host store /
                   memory-kind segments, double-buffered backward
                   prefetch).

For each mode we report the ledger's device-resident stash bytes and a
*measured* device-peak column: the live-array high-water mark while a
``jax.vjp`` of the loss holds the saved-for-backward state (exactly the
window where training peaks), plus the host-store bytes the host policy
moved off device, plus jitted step time — so the offload overhead is
visible, not hidden.  Invariant asserted into the JSON:
``arena_host ≤ arena ≤ none`` on measured residual bytes, and the host
policy's loss trajectory equals the device policy's exactly.
"""
from __future__ import annotations

import gc
import json
import pathlib
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import CompressionConfig
from repro.obs.trace import stopwatch
from repro.engine import ExecutionPlan, StashPolicy, run as engine_run
from repro.graph import GNNConfig, cora_like
from repro.graph.models import graph_tuple, init_gnn_params
from repro.graph.train import _loss_fn, activation_memory_report
from repro.offload import (device_resident_stash_bytes, host_store_bytes,
                           measure_live_bytes)
from repro.offload.gnn import plan_gnn_stashes

OUT = pathlib.Path(__file__).resolve().parent.parent / "BENCH_offload.json"


def _residual_bytes(loss_fn, params, *args):
    """Measured device-side bytes held by the saved-for-backward state.

    ``jax.vjp`` (eager) runs the forward and returns with the residuals
    still alive inside the vjp closure — the live-array delta against
    the post-release baseline is exactly the stash footprint, measured,
    not modeled.  Host-store bytes are reported separately.
    """
    gc.collect()
    y, vjp = jax.vjp(lambda p: loss_fn(p, *args), params)
    jax.block_until_ready(y)
    gc.collect()
    with_res = measure_live_bytes()
    host = host_store_bytes()
    # drain the host store (and release residuals) by completing backward
    jax.block_until_ready(vjp(jnp.ones_like(y)))
    del vjp
    gc.collect()
    without = measure_live_bytes()
    return max(0, with_res - without), host


def run(scale: float = 0.3, epochs: int = 10):
    g = cora_like(scale=scale)
    comp = CompressionConfig(bits=2, group_size=64, rp_ratio=8)
    cfg = GNNConfig(arch="sage", hidden=(64, 64), n_classes=g.num_classes,
                    compression=comp)
    params = init_gnn_params(jax.random.PRNGKey(0), cfg, g.n_feats)
    gt = graph_tuple(g)
    labels, mask = g.labels, g.train_mask.astype(jnp.float32)
    plan = plan_gnn_stashes(cfg, g.n_feats, g.n_nodes)
    seed = jnp.uint32(7919)

    modes = {
        "none": dict(plan=None, offload=None, stash=StashPolicy()),
        "arena": dict(plan=plan, offload="device",
                      stash=StashPolicy(kind="arena", placement="device")),
        "arena_host": dict(plan=plan, offload="host",
                           stash=StashPolicy(kind="arena",
                                             placement="host")),
    }
    results = {}
    for name, kw in modes.items():
        loss_fn = partial(_loss_fn, plan=kw["plan"], offload=kw["offload"])
        dev_bytes, host_bytes = _residual_bytes(
            loss_fn, params, gt, labels, mask, cfg, seed)
        r = engine_run(g, cfg, ExecutionPlan(stash=kw["stash"]),
                       n_epochs=epochs, seed=0)
        results[name] = {
            "measured_residual_bytes": int(dev_bytes),
            "host_store_bytes": int(host_bytes),
            "ledger_device_bytes": (
                plan.total_bytes if kw["offload"] is None else
                device_resident_stash_bytes(plan, kw["offload"])),
            "step_time_us": 1e6 / r["epochs_per_sec"],
            "test_acc": r["test_acc"],
            "final_loss": (r["history"][-1][1] if r["history"] else None),
        }

    # exact host-vs-device parity on the same smoke config
    host_plan = ExecutionPlan(stash=StashPolicy(kind="arena",
                                                placement="host"))
    dev_plan = ExecutionPlan(stash=StashPolicy(kind="arena",
                                               placement="device"))
    r_dev = engine_run(g, cfg, dev_plan, n_epochs=3, seed=0,
                       verbose=True, eval_every=1)
    r_host = engine_run(g, cfg, host_plan, n_epochs=3, seed=0,
                        verbose=True, eval_every=1)
    traj_dev = [l for _, l, _ in r_dev["history"]]
    traj_host = [l for _, l, _ in r_host["history"]]

    # the report reads the exact plan object the host run executed
    rep = activation_memory_report(g, cfg, plan=host_plan)
    out = {
        "dataset": {"name": g.name, "n_nodes": g.n_nodes,
                    "n_edges": g.n_edges},
        "config": {"bits": comp.bits, "group_size": comp.group_size,
                   "rp_ratio": comp.rp_ratio, "hidden": list(cfg.hidden)},
        "plan": {"total_bytes": plan.total_bytes,
                 "u32_bytes": plan.u32_bytes, "f32_bytes": plan.f32_bytes,
                 "per_layer": plan.per_layer_rows()},
        "modes": results,
        "parity": {
            "host_vs_device_loss_gap": float(max(
                abs(a - b) for a, b in zip(traj_dev, traj_host))),
            "host_trajectory_exact": traj_dev == traj_host,
        },
        "ordering_ok": bool(
            results["arena_host"]["measured_residual_bytes"]
            <= results["arena"]["measured_residual_bytes"]
            and results["arena"]["measured_residual_bytes"]
            <= results["none"]["measured_residual_bytes"]),
        "report_arena": rep["arena"],
    }
    OUT.write_text(json.dumps(out, indent=2))
    return out


def main():
    with stopwatch("bench/offload") as sw:
        out = run()
    dt = sw.elapsed_s
    rows = []
    base = out["modes"]["none"]["measured_residual_bytes"]
    for name, m in out["modes"].items():
        rows.append((
            f"offload/{name}", m["step_time_us"],
            f"resid_B={m['measured_residual_bytes']};"
            f"host_B={m['host_store_bytes']};"
            f"ledger_B={m['ledger_device_bytes']};"
            f"acc={m['test_acc']:.3f};"
            f"vs_none={m['measured_residual_bytes'] / max(base, 1):.3f}"))
    rows.append(("offload/parity", dt * 1e6,
                 f"host_traj_exact={out['parity']['host_trajectory_exact']};"
                 f"ordering_ok={out['ordering_ok']}"))
    return rows


if __name__ == "__main__":
    for name, us, derived in main():
        print(f"{name},{us:.1f},{derived}")
    print(f"wrote {OUT}")
