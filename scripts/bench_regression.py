"""Bench-regression gate: diff fresh ``BENCH_gnn_batched.json`` /
``BENCH_offload.json`` / ``BENCH_compressor.json`` epoch-time,
peak-bytes, and fused-ratio columns against the committed baselines and
fail on >10% regression.

  PYTHONPATH=src python scripts/bench_regression.py \\
      --baseline-dir /tmp/bench-baseline [--threshold 0.10]

CI copies the committed JSONs aside *before* the benchmark steps rewrite
them in place, then runs this script against the copies.  Byte metrics
are deterministic models (the engine's StashPlan / report ledger) and
compare strictly; epoch-time metrics are wall-clock and inherit runner
noise, so ``--time-threshold`` may be widened when a queue-shared runner
proves jittery (the default honors the 10% contract).  Baselines are
refreshed intentionally with ``scripts/refresh_experiments.py --bench``.

Exit status: 0 when every metric holds, 1 with a per-metric report
otherwise.  A metric missing from either side fails loudly — schema
drift must be a conscious baseline refresh, not a silent skip.

Three gates are absolute rather than baseline-relative: the
observability layer's epoch-time overhead (``BENCH_gnn_batched.json``'s
``obs`` record) must keep obs-on within ``--obs-overhead-limit``
(default 1.05) of obs-off, and the serving engine
(``BENCH_serve.json``) must hold continuous batching at
``--serve-speedup-min`` (default 1.3) x fixed-batch tokens/sec and the
bits=4 KV arena at ``--serve-bytes-ratio-min`` (default 3.0) x smaller
than uncompressed f32 with the bits=8 parity probe in tolerance.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]

#: metric extractors: file -> {metric name: (getter, kind)} where kind is
#: "time" (lower is better, noisy) or "bytes" (lower is better, exact model)
def _gnn_batched_metrics(d: dict) -> dict:
    out = {}
    for impl, arm in d.items():
        if impl in ("graph", "obs"):
            # "obs" is gated absolutely (--obs-overhead-limit), not
            # diffed against a baseline
            continue
        for mode in ("full", "batched"):
            out[f"{impl}/{mode}/epoch_time_us"] = (
                1e6 / max(arm[f"{mode}_epochs_per_sec"], 1e-9), "time")
        out[f"{impl}/peak_saved_bytes"] = (arm["peak_saved_bytes"], "bytes")
        out[f"{impl}/full_saved_bytes"] = (arm["full_saved_bytes"], "bytes")
    return out


def _gnn_dist_metrics(d: dict) -> dict:
    """``BENCH_gnn_dist.json`` (mesh-sharded engine): the ledger bytes
    are deterministic and device-count-independent; the epoch times are
    wall clock.  Halo volume / overlap depend on the runner's forced
    device count, so they ride in the JSON but are not gated here —
    the >=2x per-device peak ratio is CI-gated deterministically in
    ``tests/test_parallel.py``."""
    return {
        "full/epoch_time_us": (d["full_epoch_s"] * 1e6, "time"),
        "mesh/epoch_time_us": (d["mesh_epoch_s"] * 1e6, "time"),
        "full_saved_bytes_ledger": (d["full_saved_bytes_ledger"], "bytes"),
        "per_device_saved_bytes_ledger": (
            d["per_device_saved_bytes_ledger"], "bytes"),
    }


def _offload_metrics(d: dict) -> dict:
    out = {"plan/total_bytes": (d["plan"]["total_bytes"], "bytes")}
    for name, m in d["modes"].items():
        out[f"{name}/step_time_us"] = (m["step_time_us"], "time")
        out[f"{name}/ledger_device_bytes"] = (m["ledger_device_bytes"],
                                              "bytes")
    return out


def _compressor_metrics(d: dict) -> dict:
    """``BENCH_compressor.json``: stored-bytes are the deterministic
    compression model (strict); the ``fused_*`` rows gate the
    fused/unfused time *ratio* — machine-portable compared to raw wall
    time, but still wall-clock-derived, so it shares the "time" kind
    (10% by default, widened via ``--time-threshold`` on noisy CI)."""
    out = {}
    for r in d["records"]:
        key = f"{r['case']}/{r['impl']}"
        if r["case"].startswith("fused_"):
            out[f"{key}/fwd_time_ratio"] = (
                r["fused_fwd_us"] / r["unfused_fwd_us"], "time")
            out[f"{key}/bwd_time_ratio"] = (
                r["fused_bwd_us"] / r["unfused_bwd_us"], "time")
        else:
            out[f"{key}/compress_us"] = (r["compress_us"], "time")
            out[f"{key}/decompress_us"] = (r["decompress_us"], "time")
            out[f"{key}/stored_bytes"] = (r["stored_bytes"], "bytes")
    return out


def _serve_metrics(d: dict) -> dict:
    """``BENCH_serve.json``: per-arm us/token and p99 latency are
    wall-clock ("time"); the KV arena footprints are the deterministic
    page-pool model ("bytes", strict).  The speedup / compression /
    parity contracts are absolute gates (``check_serve_contract``), not
    baseline diffs."""
    out = {}
    for mode in ("fixed", "continuous"):
        out[f"{mode}/us_per_token"] = (d[mode]["us_per_token"], "time")
        out[f"{mode}/p99_latency_ms"] = (d[mode]["p99_latency_ms"], "time")
    for r in d["kv_sweep"]:
        out[f"kv{r['bits']}/us_per_token"] = (r["us_per_token"], "time")
        out[f"kv{r['bits']}/kv_pool_bytes"] = (r["kv_pool_bytes"], "bytes")
    return out


EXTRACTORS = {
    "BENCH_gnn_batched.json": _gnn_batched_metrics,
    "BENCH_gnn_dist.json": _gnn_dist_metrics,
    "BENCH_offload.json": _offload_metrics,
    "BENCH_compressor.json": _compressor_metrics,
    "BENCH_serve.json": _serve_metrics,
}


def compare(fresh_dir: Path, baseline_dir: Path, threshold: float,
            time_threshold: float) -> list[str]:
    failures = []
    for fname, extract in EXTRACTORS.items():
        fresh_p, base_p = fresh_dir / fname, baseline_dir / fname
        if not base_p.exists():
            failures.append(f"{fname}: no committed baseline at {base_p}")
            continue
        if not fresh_p.exists():
            failures.append(f"{fname}: benchmark did not produce {fresh_p}")
            continue
        fresh = extract(json.loads(fresh_p.read_text()))
        base = extract(json.loads(base_p.read_text()))
        for key in sorted(set(fresh) | set(base)):
            if key not in fresh or key not in base:
                failures.append(f"{fname}:{key}: metric missing from "
                                f"{'fresh' if key not in fresh else 'baseline'}"
                                " run (schema drift needs a baseline refresh)")
                continue
            f_val, kind = fresh[key]
            b_val, _ = base[key]
            lim = time_threshold if kind == "time" else threshold
            if b_val > 0 and f_val > b_val * (1.0 + lim):
                failures.append(
                    f"{fname}:{key}: {f_val:.1f} vs baseline {b_val:.1f} "
                    f"(+{100 * (f_val / b_val - 1):.1f}% > {100 * lim:.0f}%)")
            else:
                rel = 0.0 if b_val == 0 else 100 * (f_val / b_val - 1)
                print(f"ok  {fname}:{key}: {f_val:.1f} "
                      f"({rel:+.1f}% vs baseline)")
    return failures


def check_obs_overhead(fresh_dir: Path, limit: float) -> list[str]:
    """Absolute gate on the obs layer's epoch-time overhead: the fresh
    ``BENCH_gnn_batched.json`` must carry an ``obs`` record with
    ``overhead_ratio`` (obs-on / obs-off best epoch time) under
    ``limit``.  Absolute, not baseline-relative — the contract is
    "spans+metrics cost < 5%", not "no worse than last time"."""
    p = fresh_dir / "BENCH_gnn_batched.json"
    if not p.exists():
        return [f"obs-overhead: benchmark did not produce {p}"]
    d = json.loads(p.read_text())
    ob = d.get("obs")
    if not ob or "overhead_ratio" not in ob:
        return ["obs-overhead: fresh BENCH_gnn_batched.json has no 'obs' "
                "record (the overhead arm of the bench did not run)"]
    ratio = ob["overhead_ratio"]
    if ratio > limit:
        return [f"obs-overhead: obs-on/obs-off epoch ratio {ratio:.3f} "
                f"exceeds the {limit:.2f} limit "
                f"(on={ob['on_epoch_s']:.4f}s off={ob['off_epoch_s']:.4f}s)"]
    print(f"ok  BENCH_gnn_batched.json:obs/overhead_ratio: {ratio:.3f} "
          f"(< {limit:.2f} absolute limit)")
    return []


def check_serve_contract(fresh_dir: Path, speedup_min: float,
                         bytes_ratio_min: float) -> list[str]:
    """Absolute gates on the serving engine: the fresh
    ``BENCH_serve.json`` must show continuous batching >=
    ``speedup_min`` x fixed-batch tokens/sec on the head-of-line
    blocking load, the bits=4 KV arena >= ``bytes_ratio_min`` x smaller
    than the same pool uncompressed f32, and the bits=8-vs-16 logit
    parity probe passing (exact prefill step, bounded first quantized
    read).  Absolute, not baseline-relative — these are the paper's
    serving claims, not drift checks."""
    p = fresh_dir / "BENCH_serve.json"
    if not p.exists():
        return [f"serve-contract: benchmark did not produce {p}"]
    d = json.loads(p.read_text())
    fails = []
    speedup = d["speedup_tokens_per_sec"]
    if speedup < speedup_min:
        fails.append(f"serve-contract: continuous/fixed tokens/sec "
                     f"speedup {speedup:.2f} below the "
                     f"{speedup_min:.2f} minimum")
    else:
        print(f"ok  BENCH_serve.json:speedup_tokens_per_sec: "
              f"{speedup:.2f} (>= {speedup_min:.2f} absolute minimum)")
    ratio = d["bytes_gate"]["bits4_f32_ratio"]
    if ratio < bytes_ratio_min:
        fails.append(f"serve-contract: bits=4 KV f32/pool byte ratio "
                     f"{ratio:.2f} below the {bytes_ratio_min:.2f} minimum")
    else:
        print(f"ok  BENCH_serve.json:bits4_f32_ratio: {ratio:.2f} "
              f"(>= {bytes_ratio_min:.2f} absolute minimum)")
    par = d["parity"]
    if not par["ok"]:
        fails.append(f"serve-contract: bits=8 parity probe failed "
                     f"(prefill_diff={par['prefill_logit_diff']:.3g} "
                     f"step1_diff={par['step1_logit_diff']:.3g} "
                     f"tol={par['tol']})")
    else:
        print(f"ok  BENCH_serve.json:parity: prefill exact, "
              f"step1_diff={par['step1_logit_diff']:.3g} "
              f"(< {par['tol']} tol)")
    return fails


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline-dir", type=Path, required=True,
                    help="directory holding the committed BENCH_*.json "
                         "(copied aside before the bench run rewrote them)")
    ap.add_argument("--fresh-dir", type=Path, default=REPO,
                    help="directory holding the freshly produced JSONs")
    ap.add_argument("--threshold", type=float, default=0.10,
                    help="max allowed relative regression on byte metrics")
    ap.add_argument("--time-threshold", type=float, default=None,
                    help="max allowed relative regression on epoch-time "
                         "metrics (defaults to --threshold)")
    ap.add_argument("--obs-overhead-limit", type=float, default=1.05,
                    help="absolute ceiling on the obs-on/obs-off epoch "
                         "time ratio reported by BENCH_gnn_batched.json")
    ap.add_argument("--serve-speedup-min", type=float, default=1.3,
                    help="absolute floor on continuous/fixed tokens/sec "
                         "speedup reported by BENCH_serve.json")
    ap.add_argument("--serve-bytes-ratio-min", type=float, default=3.0,
                    help="absolute floor on the bits=4 KV f32/compressed "
                         "byte ratio reported by BENCH_serve.json")
    args = ap.parse_args(argv)
    tt = args.time_threshold if args.time_threshold is not None \
        else args.threshold
    failures = compare(args.fresh_dir, args.baseline_dir, args.threshold, tt)
    failures += check_obs_overhead(args.fresh_dir, args.obs_overhead_limit)
    failures += check_serve_contract(args.fresh_dir, args.serve_speedup_min,
                                     args.serve_bytes_ratio_min)
    if failures:
        print("\nBENCH REGRESSIONS:", file=sys.stderr)
        for f in failures:
            print(f"  FAIL {f}", file=sys.stderr)
        return 1
    print("\nall benchmark metrics within threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
