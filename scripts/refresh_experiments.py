"""Regenerate derived experiment artifacts.

Default: the §Roofline table in EXPERIMENTS.md from results/dryrun.

``--bench``: refresh the committed ``BENCH_gnn_batched.json`` /
``BENCH_gnn_dist.json`` / ``BENCH_offload.json`` /
``BENCH_autoprec.json`` / ``BENCH_serve.json`` /
``BENCH_compressor.json`` baselines by re-running the plan-routed GNN
benchmark suites (each lowers explicit
:class:`repro.engine.plan.ExecutionPlan` objects through ``engine.run``,
so the refreshed numbers describe exactly what the engine executes) plus
the kernel-throughput sweep (which records the fused matmul-quant rows),
and re-measure the fused tile autotune cache
(``results/autotune/fused_tiles.json``) over the benchmark shapes.
Run this on the CI-class machine whenever an intentional change moves
the columns ``scripts/bench_regression.py`` gates.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from benchmarks.roofline import load_cells, roofline_row  # noqa: E402

ARCH_ORDER = [
    "seamless-m4t-large-v2", "qwen3-moe-235b-a22b", "arctic-480b",
    "qwen1.5-4b", "qwen1.5-32b", "mistral-nemo-12b", "qwen3-32b",
    "internvl2-2b", "mamba2-780m", "zamba2-1.2b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def fmt(x, p=3):
    if x is None:
        return "-"
    return f"{x:.{p}e}" if (abs(x) < 1e-3 or abs(x) >= 1e4) else f"{x:.{p}f}"


def refresh_bench_baselines():
    """Re-run the engine-routed bench suites; they rewrite the committed
    BENCH_*.json in place (the bench-regression gate's baselines).  The
    fused tile autotune cache is re-measured first so the kernel sweep's
    fused rows record the tiles training would actually dispatch with."""
    from benchmarks import (autoprec, gnn_batched, kernel_throughput,
                            offload, serve)
    from repro.kernels import autotune

    print("re-measuring fused tile autotune cache ...")
    cache = autotune.autotune([(m, d, n, bits, g) for (_, m, d, n, bits, g, _)
                               in kernel_throughput.fused_cases()])
    print(f"  {len(cache)} cache entries -> {autotune.cache_path()}")
    for tag, fn in [("gnn_batched", gnn_batched.main),
                    ("autoprec", autoprec.main), ("offload", offload.main),
                    ("serve", serve.main),
                    ("kernel", kernel_throughput.main)]:
        print(f"refreshing {tag} baseline ...")
        for name, us, derived in fn():
            print(f"  {name},{us:.1f},{derived}")
    # gnn_dist needs its forced-8-device XLA flag set BEFORE jax
    # initializes, so it refreshes in a subprocess (the script forces the
    # flag itself when run as __main__)
    import subprocess
    print("refreshing gnn_dist baseline (subprocess, forced 8 devices) ...")
    repo = Path(__file__).resolve().parents[1]
    out = subprocess.run(
        [sys.executable, str(repo / "benchmarks" / "gnn_dist.py")],
        capture_output=True, text=True, check=True,
        env={**__import__("os").environ,
             "PYTHONPATH": str(repo / "src") + ":" + str(repo)})
    for line in out.stdout.strip().splitlines():
        print(f"  {line}")


def main():
    rows = {(r["arch"], r["shape"], r["mesh"]): roofline_row(r)
            for r in load_cells()}
    lines = [
        "| arch | shape | t_compute (s) | t_memory (s) | t_collective (s) |"
        " bottleneck | roofline frac | MODEL/HLO flops | HBM temp GB/dev |"
        " compile (s) |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = rows.get((arch, shape, "single"))
            if r is None:
                continue
            if r.get("status") != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | "
                             f"*{r.get('reason', r.get('status'))}* | - | - |"
                             f" - | - |")
                continue
            lines.append(
                f"| {arch} | {shape} | {fmt(r['t_compute_s'])} | "
                f"{fmt(r['t_memory_s'])} | {fmt(r['t_collective_s'])} | "
                f"**{r['bottleneck']}** | {r['roofline_fraction']:.3f} | "
                f"{r['model_over_hlo_flops']:.3f} | "
                f"{r['mem_temp_GB']:.2f} | {r['compile_s']} |")
    # multi-pod summary: every cell must compile; report worst deltas
    ok_multi = sum(1 for (a, s, m), r in rows.items()
                   if m == "multi" and r.get("status") == "ok")
    skip_multi = sum(1 for (a, s, m), r in rows.items()
                     if m == "multi" and r.get("status") == "skipped")
    lines.append("")
    lines.append(f"Multi-pod `(2,16,16)` pass: {ok_multi} compiled ok, "
                 f"{skip_multi} designed skips (same gate). Per-cell "
                 f"multi-pod terms are in `results/dryrun/*__multi.json`; "
                 f"the pod axis adds cross-pod DP gradient all-reduce — "
                 f"visible as increased collective bytes on train cells.")
    table = "\n".join(lines)

    exp = Path(__file__).resolve().parents[1] / "EXPERIMENTS.md"
    text = exp.read_text()
    marker = "TABLE-PLACEHOLDER (filled by scripts/refresh_experiments.py)"
    if marker in text:
        text = text.replace(marker, table)
    else:
        import re
        text = re.sub(r"(## §Roofline\n\n.*?\n\n)\|.*?\n\n(?=##|Multi-pod)",
                      r"\1" + table + "\n\n", text, flags=re.S)
        if "| arch | shape |" not in text:
            print("WARNING: could not splice table; appending")
            text += "\n\n" + table
    exp.write_text(text)
    print(f"wrote roofline table: {len(lines)} lines")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", action="store_true",
                    help="refresh the committed BENCH_*.json baselines "
                         "instead of the EXPERIMENTS.md roofline table")
    args = ap.parse_args()
    if args.bench:
        refresh_bench_baselines()
    else:
        main()
